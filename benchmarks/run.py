"""Benchmark harness — one section per paper table/figure.

Also writes ``BENCH_summa.json`` (``--json`` to relocate): a
machine-readable record of the planned-sparse sweep — GF/s, modeled
per-device collective bytes from the ``MatmulPlan`` cost model, fill-in,
strategy, and the dense-vs-planned-sparse speedup at fills 0.1/0.3/1.0 —
so the perf trajectory is tracked across PRs.

Prints ``name,us_per_call,derived`` CSV rows:

* table1_*   — paper Table 1: min:max memory/work ratios of nonuniformly
               blocked matrices at the paper's exact sizes, plus the §4.4
               effective per-process imbalance (the 1:1.35 claim).
* fig4/5_*   — weak scaling (N grows with P), uniform vs nonuniform:
               GFLOP rate + wall time (paper Figs 4, 5).
* fig6/7_*   — strong scaling at fixed N (paper Figs 6, 7 commodity run).
* fig8_*     — efficiency relative to the single-device rate (paper Fig 8).
* summa_*    — strategy comparison (procedural vs task-based vs allgather):
               collective bytes/device from compiled HLO — the structural
               cost the roofline consumes.

Wall-clock caveat: this container exposes one physical core; emulated
multi-device wall times measure total work, not parallel speedup — the
HLO-derived per-device metrics are the scaling signal (EXPERIMENTS.md).

Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse

import numpy as np


def _row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _block(out):
    """Wait for device completion of a bench result (raw array or
    ``BlockSparseTensor``)."""
    data = getattr(out, "data", out)
    if hasattr(data, "block_until_ready"):
        data.block_until_ready()
    else:
        np.asarray(data)
    return out


def timed_split(fn, *args, iters: int = 3):
    """Split first-call from steady-state timing.

    Returns ``(out, compile_s, wall_s)``: ``compile_s`` is the first call
    (trace + compile + run — what a cold cache costs), ``wall_s`` the
    median of ``iters`` (>= 3) post-warmup calls — the dispatch-bound
    steady state the executable cache is accountable for.  Earlier
    BENCH_*.json trajectories conflated the two.
    """
    import time as _t

    t0 = _t.perf_counter()
    out = _block(fn(*args))
    compile_s = _t.perf_counter() - t0
    walls = []
    for _ in range(max(int(iters), 3)):
        t0 = _t.perf_counter()
        out = _block(fn(*args))
        walls.append(_t.perf_counter() - t0)
    return out, compile_s, float(np.median(walls))


def bench_table1():
    from repro.core.blocking import load_stats, nonuniform_tiling

    # paper's exact matrix sizes, average block 256
    for n in (32_768, 65_536, 98_304, 256_000):
        t0 = __import__("time").perf_counter()
        rt = nonuniform_tiling(n, n // 256, seed=n)
        it = nonuniform_tiling(n, n // 256, seed=n + 1)
        ct = nonuniform_tiling(n, n // 256, seed=n + 2)
        s = load_stats(rt, ct, it)
        us = (__import__("time").perf_counter() - t0) * 1e6
        _row(
            f"table1_N{n}", us,
            f"mem=1:{s.memory_min_max:.2f};work=1:{s.work_min_max:.2f}",
        )
    # §4.4 effective per-process imbalance, N=32768, 256 procs (16x16)
    rt = nonuniform_tiling(32_768, 128, seed=32_768)
    ct = nonuniform_tiling(32_768, 128, seed=32_769)
    eff = load_stats(rt, ct, grid=(16, 16))
    _row(
        "table1_effective_P256", 0.0,
        f"mem=1:{eff.memory_min_max:.2f} (paper: 1:1.35)",
    )


def bench_weak_scaling(quick: bool):
    from benchmarks.summa_scaling import run_config

    # weak scaling: per-device work constant (N ~ sqrt(P))
    cells = [((1, 1), 1024), ((2, 2), 2048), ((4, 4), 4096)]
    if quick:
        cells = cells[:2]
    for blocked in (False, True):
        tag = "nonuniform" if blocked else "uniform"
        for grid, n in cells:
            r = run_config(grid, n, nonuniform=blocked, repeats=2)
            _row(
                f"fig4_weak_{tag}_P{grid[0] * grid[1]}_N{n}",
                r["wall_s"] * 1e6,
                f"gflops={r['gflops']:.1f};coll_B/dev={r['coll_bytes_per_device']:.3g}",
            )
            _row(
                f"fig5_weak_wall_{tag}_P{grid[0] * grid[1]}_N{n}",
                r["wall_s"] * 1e6,
                f"wall_s={r['wall_s']:.3f}",
            )


def bench_strong_scaling(quick: bool):
    from benchmarks.summa_scaling import run_config

    n = 2048
    grids = [(1, 1), (2, 2), (4, 4)]
    if quick:
        grids = grids[:2]
    base_rate = None
    for blocked in (False, True):
        tag = "nonuniform" if blocked else "uniform"
        for grid in grids:
            p = grid[0] * grid[1]
            r = run_config(grid, n, nonuniform=blocked, repeats=2)
            _row(
                f"fig6_strong_{tag}_P{p}_N{n}",
                r["wall_s"] * 1e6,
                f"gflops={r['gflops']:.1f};flops/dev={r['flops_per_device_hlo']:.3g}",
            )
            _row(
                f"fig7_strong_wall_{tag}_P{p}_N{n}",
                r["wall_s"] * 1e6,
                f"wall_s={r['wall_s']:.3f}",
            )
            if not blocked:
                # fig8: per-device useful work vs P=1 (structural efficiency)
                if base_rate is None:
                    base_rate = r["flops_per_device_hlo"]
                eff = base_rate / (r["flops_per_device_hlo"] * p) * 100
                _row(
                    f"fig8_efficiency_P{p}_N{n}",
                    r["wall_s"] * 1e6,
                    f"structural_efficiency_pct={eff:.1f}",
                )


def bench_strategies():
    """Collective cost of procedural vs task-based vs allgather SUMMA —
    the §Perf baseline table for the paper's own technique."""
    from benchmarks.summa_scaling import run_config

    for strategy in ("procedural", "taskbased", "allgather"):
        r = run_config((4, 4), 2048, strategy=strategy, repeats=2)
        _row(
            f"summa_strategy_{strategy}_P16_N2048",
            r["wall_s"] * 1e6,
            f"coll_B/dev={r['coll_bytes_per_device']:.4g};"
            f"ag={r['coll_breakdown']['all-gather']:.3g};"
            f"ar={r['coll_breakdown']['all-reduce']:.3g}",
        )


def bench_blocksparse():
    """Block-sparse SUMMA: communication scales with live K panels, and
    useful work scales with block fill (paper's goal).  Dead panels model
    screened-out interaction shells (distance decay)."""
    import time as _t

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.hlo import analyze_hlo
    from repro.core import mask_matmul_flops, random_block_mask
    from repro.core.summa import SummaConfig, summa_blocksparse_matmul
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    n, kb = 1024, 16
    bs = n // kb
    a = jnp.ones((n, n), jnp.float32)
    b = jnp.ones((n, n), jnp.float32)
    cfg = SummaConfig(mesh=mesh, strategy="taskbased", k_blocks=kb)
    for fill, dead_frac in ((0.25, 0.5), (0.5, 0.25), (1.0, 0.0)):
        am = random_block_mask(kb, kb, fill, seed=1)
        bm = random_block_mask(kb, kb, fill, seed=2)
        dead = np.arange(int(kb * dead_frac)) * 2 + 1  # screened shells
        am[:, dead] = False
        bm[dead, :] = False
        f = jax.jit(lambda a, b: summa_blocksparse_matmul(a, b, am, bm, cfg))
        txt = f.lower(a, b).compile().as_text()
        wc = analyze_hlo(txt)
        out = f(a, b)
        out.block_until_ready()
        t0 = _t.perf_counter()
        for _ in range(3):
            out = f(a, b)
        out.block_until_ready()
        us = (_t.perf_counter() - t0) / 3 * 1e6
        useful, dense = mask_matmul_flops(am, bm, bs, bs, bs)
        alive = sum(
            1 for k in range(kb) if am[:, k].any() and bm[k, :].any()
        )
        _row(
            f"blocksparse_fill{fill}_dead{dead_frac}_N{n}",
            us,
            f"alive_panels={alive}/{kb};hlo_flops={wc.flops:.3g};"
            f"useful={useful:.3g};dense={dense:.3g}",
        )


def bench_planned_sparse(json_path: str) -> None:
    """Dense vs *planned* sparse at fills 0.1/0.3/1.0 -> BENCH_summa.json.

    One ``MatmulPlan`` per fill supplies the modeled per-device collective
    bytes, the fill-in, and the per-device pruning stats; the measured
    wall clock gives GF/s and the dense-vs-sparse speedup.  The JSON is
    the cross-PR perf trajectory record.
    """
    import json

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import DistributedMatmul
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    # Wide panels (K/4) keep per-panel GEMMs MXU/BLAS-efficient on this
    # single-core container; finer grids fragment the local dots and the
    # wall clock measures overhead instead of pruning.
    n, kb = 1024, 4
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    # Same K granularity for dense and sparse so the comparison isolates
    # the planner's pruning, not the panel count.
    mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=kb)

    def screened_mask(fill, seed):
        """Screening-style mask: dead rows/columns allowed (unlike
        ``random_block_mask``, which guarantees full coverage), so global
        panel pruning actually fires at low fill."""
        r = np.random.default_rng(seed)
        return r.random((kb, kb)) < fill

    def timed(fn):
        _, compile_s, wall = timed_split(fn, a, b)
        return compile_s, wall

    dense_compile, dense_wall = timed(jax.jit(lambda a, b: mm(a, b)))
    dense_plan = mm.plan(n, n, n)
    entries = [
        {
            "name": "dense_N1024",
            "wall_s": dense_wall,
            "compile_s": dense_compile,
            "gflops_per_s": 2.0 * n**3 / dense_wall / 1e9,
            "speedup_vs_dense": 1.0,
            "plan": dense_plan.summary(),
        }
    ]
    _row("plan_dense_N1024", dense_wall * 1e6, "speedup=1.00")
    for fill in (0.1, 0.3, 1.0):
        am = screened_mask(fill, seed=1)
        bm = screened_mask(fill, seed=2)
        plan = mm.plan(n, n, n, a_mask=am, b_mask=bm)
        compile_s, wall = timed(
            jax.jit(lambda a, b, am=am, bm=bm: mm(a, b, a_mask=am, b_mask=bm))
        )
        useful = plan.cost.flops_sparse
        entries.append(
            {
                "name": f"planned_sparse_fill{fill}_N{n}",
                "wall_s": wall,
                "compile_s": compile_s,
                "gflops_per_s": useful / wall / 1e9,
                "speedup_vs_dense": dense_wall / wall,
                "plan": plan.summary(),
            }
        )
        _row(
            f"plan_sparse_fill{fill}_N{n}",
            wall * 1e6,
            f"speedup={dense_wall / wall:.2f};fill={plan.cost.fill_in:.3f};"
            f"comm_B={plan.cost.comm_bytes['taskbased']:.3g}",
        )
    with open(json_path, "w") as f:
        json.dump(
            {
                "bench": "summa",
                "entries": entries,
                "cache_stats": mm.cache_stats(),
            },
            f, indent=2,
        )
    print(f"# wrote {json_path}", flush=True)


def bench_sched(json_path: str) -> None:
    """Schedule-simulator record -> BENCH_sched.json.

    Three sections: (1) predicted vs measured makespan for dense products
    on the local host mesh — the FLOP rate is calibrated once on the
    smallest case, every other prediction must land within 30 % of wall
    time; (2) the paper's imbalance-absorption result on a simulated
    nonuniform 16x16 grid (multi-issue I = Eq. 1 vs I = 1); (3) the
    autotuner vs the static cost-model pick on virtual grids — tuned
    simulated makespan is never worse.
    """
    import json

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import DistributedMatmul
    from repro.core.blocking import nonuniform_tiling
    from repro.core.plan import plan_matmul
    from repro.launch.mesh import make_host_mesh
    from repro.sched import (
        MachineModel,
        abstract_summa_config,
        eq1_lookahead,
        from_tilings,
        simulate,
        simulate_plan,
        tune_plan,
    )

    entries = []
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=4)
    rng = np.random.default_rng(0)

    compile_by_n: dict[int, float] = {}

    def timed(n):
        a = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
        f = jax.jit(lambda a, b: mm(a, b))
        _, compile_s, wall = timed_split(f, a, b)
        compile_by_n[n] = compile_s
        return wall

    # (1) calibrate the machine FLOP rate on one compute-bound dense case,
    # then predict the rest: the 30% acceptance band of EXPERIMENTS.md.
    # (Sub-1k sizes are launch-overhead-bound on this host and sit outside
    # the model — the protocol calibrates and predicts in the GEMM regime.)
    n0 = 1024
    wall0 = timed(n0)
    machine = MachineModel(
        flops_per_s=2.0 * n0**3 / wall0, name="local-calibrated"
    )
    for n in (n0, 1536, 2048):
        wall = wall0 if n == n0 else timed(n)
        plan = mm.plan(n, n, n)
        sim = simulate_plan(plan, machine)
        rel = abs(sim.makespan_s - wall) / wall
        entries.append(
            {
                "name": f"local_dense_N{n}",
                "grid": [1, 1],
                "predicted_makespan_s": sim.makespan_s,
                "measured_wall_s": wall,
                "compile_s": compile_by_n[n],
                "rel_err": rel,
                "within_30pct": bool(rel <= 0.30),
                "chosen_lookahead": plan.resolve_lookahead(),
                "imbalance_ratio": sim.imbalance_ratio,
                "calibration": n == n0,
            }
        )
        _row(
            f"sched_local_dense_N{n}", wall * 1e6,
            f"pred_ms={sim.makespan_s*1e3:.2f};meas_ms={wall*1e3:.2f};"
            f"rel_err={rel:.2f}",
        )

    # (2) nonuniform imbalance absorption on a virtual 16x16 grid
    # (EXPERIMENTS.md §Simulated scaling workload: N=4096, 64 nonuniform
    # blocks per dimension drawn by the paper's §4.1 procedure)
    tilings = [nonuniform_tiling(4096, 64, seed=s) for s in (1, 2, 3)]
    s1 = simulate(from_tilings(16, 16, *tilings, lookahead=1))
    se = simulate(from_tilings(16, 16, *tilings))
    speedup = s1.makespan_s / se.makespan_s
    entries.append(
        {
            "name": "sim_nonuniform_P256_N4096",
            "grid": [16, 16],
            "chosen_lookahead": eq1_lookahead(16, 16, 64),
            "makespan_I1_s": s1.makespan_s,
            "makespan_eq1_s": se.makespan_s,
            "multi_issue_speedup": speedup,
            "imbalance_ratio": se.imbalance_ratio,
        }
    )
    _row(
        "sched_sim_nonuniform_P256", se.makespan_s * 1e6,
        f"speedup_vs_I1={speedup:.2f};imbalance={se.imbalance_ratio:.2f}",
    )

    # (3) tuner vs the static cost-model choice on virtual grids
    for pr, pc, n in ((4, 4, 4096), (16, 16, 8192)):
        cfg = abstract_summa_config(pr, pc, strategy="taskbased")
        tuned = tune_plan(plan_matmul(n, n, n, cfg))
        t = tuned.tuned
        entries.append(
            {
                "name": f"tuned_P{pr*pc}_N{n}",
                "grid": [pr, pc],
                "strategy_static": t["static_strategy"],
                "strategy_tuned": t["strategy"],
                "chosen_lookahead": t["lookahead"],
                "k_blocks": t["k_blocks"],
                "makespan_static_s": t["static_makespan_s"],
                "makespan_tuned_s": t["makespan_s"],
                "tuner_not_worse": bool(
                    t["makespan_s"] <= t["static_makespan_s"] * (1 + 1e-9)
                ),
                "imbalance_ratio": t["imbalance_ratio"],
            }
        )
        _row(
            f"sched_tuned_P{pr*pc}_N{n}", t["makespan_s"] * 1e6,
            f"static={t['static_strategy']};tuned={t['strategy']};"
            f"I={t['lookahead']};speedup={t['speedup_vs_static']:.2f}",
        )
    with open(json_path, "w") as f:
        json.dump(
            {
                "bench": "sched",
                "entries": entries,
                "cache_stats": mm.cache_stats(),
            },
            f, indent=2,
        )
    print(f"# wrote {json_path}", flush=True)


def bench_ranksparse(json_path: str) -> None:
    """Rank-sparse vs mask-only vs dense -> BENCH_ranksparse.json.

    The sequel's claim on this container: on a decay-structured workload
    (near-diagonal blocks ~full rank, ranks decaying with block distance,
    far blocks screened out) the *factorized* execution beats mask-only
    block sparsity once the average block rank is small — each gemm task
    costs O(r·(bm+bk)·n) instead of O(bm·bk·n).  One entry per max-rank
    level records measured walls, both speedups, the mean rank, and the
    plan digest (modeled rank FLOPs vs mask FLOPs vs dense); the
    acceptance bar is rank-sparse beating mask-only at mean rank <= bm/4.
    """
    import json

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        DistributedMatmul,
        decay_rank_map,
        synthesize_rank_csr,
    )
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    n, blocks = 1024, 8
    bsz = n // blocks  # 128x128 blocks; dense-fallback threshold r* = 64
    mm = DistributedMatmul(mesh, strategy="taskbased")
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)

    def timed(fn):
        _, compile_s, wall = timed_split(fn, b, iters=5)
        return compile_s, wall

    a_dense = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    dense_compile, dense_wall = timed(jax.jit(lambda b: mm(a_dense, b)))
    entries = [
        {
            "name": "dense_N1024",
            "wall_s": dense_wall,
            "compile_s": dense_compile,
            "mean_rank": float(bsz),
            "speedup_vs_dense": 1.0,
            "plan": mm.plan(n, n, n).summary(),
        }
    ]
    _row("ranksparse_dense_N1024", dense_wall * 1e6, "speedup=1.00")
    # One decay structure (mask shared across rank levels) so the
    # rank-vs-mask comparison isolates the factorization, not the mask.
    mask_wall = None
    for max_rank in (96, 48, 32, 16, 8):
        rank_map = decay_rank_map(
            blocks, blocks, bsz, bsz,
            max_rank=max_rank, decay=0.9, threshold=5e-2,
        )
        rcsr = synthesize_rank_csr(rank_map, seed=1)
        if mask_wall is None:
            a_twin = jnp.asarray(rcsr.to_dense())
            mask_compile, mask_wall = timed(
                jax.jit(
                    lambda b, a=a_twin, m=rank_map.mask: mm(a, b, a_mask=m)
                )
            )
            mask_plan = mm.plan(n, n, n, a_mask=rank_map.mask)
            entries.append(
                {
                    "name": "maskonly_decay_N1024",
                    "wall_s": mask_wall,
                    "compile_s": mask_compile,
                    "speedup_vs_dense": dense_wall / mask_wall,
                    "plan": mask_plan.summary(),
                }
            )
            _row(
                "ranksparse_maskonly_N1024", mask_wall * 1e6,
                f"speedup={dense_wall / mask_wall:.2f};"
                f"fill={mask_plan.cost.fill_in:.3f}",
            )
        rank_compile, rank_wall = timed(
            jax.jit(lambda b, r=rcsr: mm(None, b, a_ranks=r))
        )
        plan = mm.plan(n, n, n, a_ranks=rcsr)
        mean_rank = rank_map.mean_rank
        entries.append(
            {
                "name": f"ranksparse_rmax{max_rank}_N1024",
                "wall_s": rank_wall,
                "compile_s": rank_compile,
                "mean_rank": mean_rank,
                "speedup_vs_dense": dense_wall / rank_wall,
                "speedup_vs_maskonly": mask_wall / rank_wall,
                "beats_maskonly": bool(rank_wall < mask_wall),
                "acceptance_regime": bool(mean_rank <= bsz / 4),
                "plan": plan.summary(),
            }
        )
        _row(
            f"ranksparse_rmax{max_rank}_N1024", rank_wall * 1e6,
            f"mean_rank={mean_rank:.1f};"
            f"speedup_vs_dense={dense_wall / rank_wall:.2f};"
            f"speedup_vs_maskonly={mask_wall / rank_wall:.2f};"
            f"flops_rank={plan.cost.flops_sparse:.3g};"
            f"flops_mask={plan.cost.flops_mask:.3g}",
        )
    with open(json_path, "w") as f:
        json.dump(
            {
                "bench": "ranksparse",
                "entries": entries,
                "cache_stats": mm.cache_stats(),
            },
            f, indent=2,
        )
    print(f"# wrote {json_path}", flush=True)


def bench_contract(json_path: str) -> None:
    """Tensor-contraction sweep + chained-contraction scheduling ->
    BENCH_contract.json.

    Two sections:

    (1) executed contractions on the host mesh — one entry per spec
    family (masked 3-D ``abc,cd->abd``, multi-contracted ``abc,bcd->ad``,
    rank-sparse ``ab,bc->ac`` on a factor payload, nonuniform mode
    extents), each recording wall time, the residual vs the float64
    ``np.einsum`` reference, and the underlying plan digest — the proof
    that the einsum front-end rides the same planned engine;

    (2) the nonuniform chain: D = (A.B).C with §4.1 nonuniform blocks on
    a virtual 8x8 grid, simulated sequentially (barrier between MMs) vs
    as the union graph (``chain_graphs``) vs jointly tuned
    (``tune_chain``).  The CI acceptance gate asserts
    ``beats_sequential`` — the union graph's makespan is strictly below
    the barrier sum (the paper's "no explicit internodal synchronization
    lets MMs overlap", measured).  The simulation is deterministic, so
    the gate is noise-free.
    """
    import json

    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        BlockSparseTensor,
        DistributedMatmul,
        contract,
        contract_chain,
        decay_block_mask,
        decay_rank_map,
        nonuniform_tiling,
        synthesize_rank_csr,
    )
    from repro.launch.mesh import make_host_mesh
    from repro.sched import chain_graphs, from_tilings, simulate, tune_chain

    entries = []
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased")
    rng = np.random.default_rng(0)

    def timed(fn, *args):
        return timed_split(fn, *args)

    def dense(shape, block_shape, mask=None):
        data = rng.normal(size=shape).astype(np.float32)
        return BlockSparseTensor.from_dense(
            jnp.asarray(data), block_shape=block_shape, mask=mask
        )

    def case_free2():
        x = dense(
            (16, 32, 512), (8, 16, 32),
            mask=rng.random((2, 2, 16)) < 0.4,
        )
        y = dense((512, 384), (32, 32), mask=decay_block_mask(16, 12, 0.5))
        return "abc,cd->abd", x, y, 64

    def case_multi():
        x = dense((512, 16, 32), (32, 8, 16), mask=rng.random((16, 2, 2)) < 0.5)
        y = dense((16, 32, 384), (8, 16, 32))
        return "abc,bcd->ad", x, y, 64

    def case_rank():
        rank_map = decay_rank_map(8, 8, 64, 64, max_rank=8, decay=0.7)
        x = BlockSparseTensor.from_rank_csr(
            synthesize_rank_csr(rank_map, seed=1)
        )
        y = dense((512, 384), (64, 32))
        return "ab,bc->ac", x, y, 64
    def case_nonuniform():
        rt = nonuniform_tiling(500, 8, seed=1)
        it = nonuniform_tiling(480, 6, seed=2)
        ct = nonuniform_tiling(420, 7, seed=3)
        x = BlockSparseTensor(
            data=jnp.asarray(rng.normal(size=(500, 480)).astype(np.float32)),
            tilings=(rt, it), mask=rng.random((8, 6)) < 0.5,
        )
        y = BlockSparseTensor(
            data=jnp.asarray(rng.normal(size=(480, 420)).astype(np.float32)),
            tilings=(it, ct),
        )
        return "ab,bc->ac", x, y, 64

    for name, case in (
        ("free2", case_free2), ("multi_contracted", case_multi),
        ("rank_sparse", case_rank), ("nonuniform", case_nonuniform),
    ):
        spec, x, y, tile = case()
        out, compile_s, wall = timed(
            lambda: contract(spec, x, y, mm=mm, tile=tile)
        )
        ref = np.einsum(
            spec, x.to_dense().astype(np.float64),
            y.to_dense().astype(np.float64),
        )
        resid = float(np.abs(np.asarray(out.data) - ref).max())
        from repro.core.contract import _geometry_cached, _plan_step

        plan = _plan_step(mm, _geometry_cached(mm, spec, x, y, tile), x)
        entries.append(
            {
                "name": f"contract_{name}",
                "spec": spec,
                "wall_s": wall,
                "compile_s": compile_s,
                "max_abs_err": resid,
                "out_fill": out.fill(),
                "plan": plan.summary(),
            }
        )
        _row(
            f"contract_{name}", wall * 1e6,
            f"spec={spec};compile_s={compile_s:.2f};err={resid:.2e};"
            f"fill={plan.cost.fill_in:.3f}",
        )

    # (2) the nonuniform chain on a virtual 8x8 grid
    nb, extent, (pr, pc) = 16, 2048, (8, 8)
    tilings = [nonuniform_tiling(extent, nb, seed=s) for s in (1, 2, 3, 4)]
    rt, it, ct, dt = tilings
    builders = [
        lambda la=None: from_tilings(pr, pc, rt, it, ct, lookahead=la),
        lambda la=None: from_tilings(pr, pc, rt, ct, dt, lookahead=la),
    ]
    seq = float(sum(simulate(b(None)).makespan_s for b in builders))
    joint = simulate(chain_graphs([b(None) for b in builders]))
    las, tuned_sim, record = tune_chain(builders)
    entries.append(
        {
            "name": f"chain_nonuniform_P{pr*pc}_N{extent}",
            "grid": [pr, pc],
            "blocks": nb,
            "sequential_makespan_s": seq,
            "joint_makespan_s": joint.makespan_s,
            "tuned_makespan_s": tuned_sim.makespan_s,
            "tuned_lookaheads": [int(la) for la in las],
            "speedup_vs_sequential": seq / tuned_sim.makespan_s,
            "beats_sequential": bool(tuned_sim.makespan_s < seq),
        }
    )
    _row(
        f"contract_chain_P{pr*pc}_N{extent}", tuned_sim.makespan_s * 1e6,
        f"seq_us={seq*1e6:.1f};joint_us={joint.makespan_s*1e6:.1f};"
        f"speedup={seq/tuned_sim.makespan_s:.3f};I={las}",
    )

    # executed chain on the host mesh (correctness + wall record); the
    # whole chain is one compiled program, so steady-state wall_s is pure
    # dispatch + compute with zero host round-trips between steps
    am = decay_block_mask(8, 8, decay=0.5, threshold=5e-2)
    x = dense((512, 512), (64, 64), mask=am)
    y1 = dense((512, 512), (64, 64), mask=am)
    y2 = dense((512, 384), (64, 48))
    report_box = {}

    def run_chain():
        res, report = contract_chain(
            [("ab,bc->ac", x, y1), ("ab,bc->ac", y2)], mm=mm, tune=True
        )
        report_box["report"] = report
        return res

    res, compile_s, wall = timed(run_chain)
    report = report_box["report"]
    ref = (
        x.to_dense().astype(np.float64) @ y1.to_dense().astype(np.float64)
    ) @ np.asarray(y2.data, np.float64)
    entries.append(
        {
            "name": "chain_executed_N512",
            "wall_s": wall,
            "compile_s": compile_s,
            "max_abs_err": float(np.abs(np.asarray(res.data) - ref).max()),
            "joint_makespan_s": report["joint_makespan_s"],
            "sequential_makespan_s": report["sequential_makespan_s"],
            "lookaheads": report["lookaheads"],
            "out_fill": res.fill(),
        }
    )
    _row(
        "contract_chain_executed_N512", wall * 1e6,
        f"err={entries[-1]['max_abs_err']:.2e};"
        f"I={report['lookaheads']};fill={res.fill():.3f}",
    )
    with open(json_path, "w") as f:
        json.dump(
            {
                "bench": "contract",
                "entries": entries,
                "cache_stats": mm.cache_stats(),
            },
            f, indent=2,
        )
    print(f"# wrote {json_path}", flush=True)


def bench_spgemm(json_path: str) -> None:
    """Sparse x sparse (SpGEMM) planning sweep -> BENCH_spgemm.json.

    A fill x fill grid of block masks on a 16x16-block product
    (m = k = n = 1024, one block per virtual device of a 16x16 grid):

    * output-structure-aware pruning — gemm tasks of the A-structure-only
      plan vs the plan that also sees B's mask and the symbolic output
      mask (``repro.spgemm.output_mask``); the aware plan must never emit
      more tasks, and strictly fewer on the banded entries;
    * pull vs broadcast — total comm bytes and simulated makespan of the
      one-sided fetch DAG vs the panel-broadcast DAG on the virtual
      16x16 grid; pull must move strictly fewer bytes on the banded
      entries and strictly *more* on the dense entry (the crossover);
    * measured correctness — both comm modes execute on the local host
      mesh and must land within 1e-3 relative residual of the float64
      numpy oracle.

    The acceptance booleans ride in the JSON (CI asserts them).
    """
    import json

    import jax.numpy as jnp
    import numpy as np

    from repro.core import DistributedMatmul
    from repro.core.plan import plan_matmul
    from repro.core.sparsity import banded_block_mask, random_block_mask
    from repro.launch.mesh import make_host_mesh
    from repro.sched import abstract_summa_config, from_plan, simulate
    from repro.spgemm import output_mask

    blk = 16  # block grid == virtual device grid (one C block per device)
    n = 1024
    cfg = abstract_summa_config(blk, blk, strategy="taskbased")
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=blk)
    rng = np.random.default_rng(0)

    cases = [
        ("banded_bw0", banded_block_mask(blk, blk, 0),
         banded_block_mask(blk, blk, 0)),
        ("banded_bw1", banded_block_mask(blk, blk, 1),
         banded_block_mask(blk, blk, 1)),
    ]
    for f in (0.05, 0.1, 0.2, 0.4):
        cases.append((
            f"random_f{int(f * 100):02d}",
            random_block_mask(blk, blk, f, seed=1),
            random_block_mask(blk, blk, f, seed=2),
        ))
    cases.append(
        ("dense", np.ones((blk, blk), bool), np.ones((blk, blk), bool))
    )

    def gemms(graph):
        return sum(
            1 for t in graph.tasks if t.kind == "gemm" and t.flops > 0
        )

    def comm_bytes(graph):
        return float(
            sum(t.bytes for t in graph.tasks if t.resource == "comm")
        )

    a64 = rng.standard_normal((n, n))
    b64 = rng.standard_normal((n, n))
    a32 = jnp.asarray(a64, jnp.float32)
    b32 = jnp.asarray(b64, jnp.float32)
    bs = n // blk

    entries = []
    for name, amask, bmask in cases:
        cmask = output_mask(amask, bmask)
        p_aonly = plan_matmul(n, n, n, cfg, a_mask=amask)
        p_aware = plan_matmul(
            n, n, n, cfg, a_mask=amask, b_mask=bmask, c_mask=cmask
        )
        p_pull = plan_matmul(
            n, n, n, cfg, a_mask=amask, b_mask=bmask, c_mask=cmask,
            comm_mode="pull",
        )
        g_aonly = from_plan(p_aonly)
        g_aware = from_plan(p_aware)
        g_pull = from_plan(p_pull)
        sim_bcast = simulate(g_aware)
        sim_pull = simulate(g_pull)

        # measured: both comm modes on the host mesh vs the f64 oracle
        fine_a = np.kron(amask, np.ones((bs, bs), bool))
        fine_b = np.kron(bmask, np.ones((bs, bs), bool))
        ref = np.where(fine_a, a64, 0.0) @ np.where(fine_b, b64, 0.0)
        scale = max(1.0, float(np.abs(ref).max()))
        res = {}
        for mode in ("broadcast", "pull"):
            out = _block(mm(
                a32, b32, a_mask=amask, b_mask=bmask, c_mask=cmask,
                comm_mode=mode,
            ))
            res[mode] = float(
                np.abs(np.asarray(out, np.float64) - ref).max()
            ) / scale

        sparse = name != "dense"
        banded = name.startswith("banded")
        entry = {
            "name": name,
            "fill_a": float(amask.mean()),
            "fill_b": float(bmask.mean()),
            "fill_c": float(cmask.mean()),
            "grid": [blk, blk],
            "shape": [n, n, n],
            "gemms_a_only": gemms(g_aonly),
            "gemms_aware": gemms(g_aware),
            "bytes_modeled_bcast": p_aware.cost.comm_bytes.get("taskbased"),
            "bytes_modeled_pull": p_pull.cost.comm_bytes.get("pull"),
            "bytes_graph_bcast": comm_bytes(g_aware),
            "bytes_graph_pull": comm_bytes(g_pull),
            "makespan_bcast_s": sim_bcast.makespan_s,
            "makespan_pull_s": sim_pull.makespan_s,
            "pull_speedup_sim": (
                sim_bcast.makespan_s / sim_pull.makespan_s
                if sim_pull.makespan_s > 0 else 1.0
            ),
            "residual_broadcast": res["broadcast"],
            "residual_pull": res["pull"],
            "aware_not_worse": bool(gemms(g_aware) <= gemms(g_aonly)),
            "aware_strictly_prunes": bool(
                gemms(g_aware) < gemms(g_aonly)
            ),
            "pull_fewer_bytes": bool(
                comm_bytes(g_pull) < comm_bytes(g_aware)
            ),
            "residual_ok": bool(max(res.values()) < 1e-3),
        }
        entries.append(entry)
        _row(
            f"spgemm_{name}", sim_bcast.makespan_s * 1e6,
            f"gemms={entry['gemms_aware']}/{entry['gemms_a_only']};"
            f"pull_bytes={entry['bytes_graph_pull']:.0f};"
            f"bcast_bytes={entry['bytes_graph_bcast']:.0f};"
            f"res={max(res.values()):.1e}",
        )

        # acceptance: output-aware planning never loses, and wins
        # strictly on the banded entries; pull's one-sided fetches beat
        # broadcast exactly where fill is low (and lose at dense — the
        # crossover the simulator prices via owner-clock contention)
        assert entry["aware_not_worse"], name
        if sparse:
            assert entry["residual_ok"], (name, res)
        if banded:
            assert entry["aware_strictly_prunes"], name
            assert entry["pull_fewer_bytes"], name
        if not sparse:
            assert not entry["pull_fewer_bytes"], name
            assert entry["residual_ok"], (name, res)

    with open(json_path, "w") as f:
        json.dump(
            {
                "bench": "spgemm",
                "entries": entries,
                "cache_stats": mm.cache_stats(),
            },
            f, indent=2,
        )
    print(f"# wrote {json_path}", flush=True)


def bench_filter(json_path: str) -> None:
    """Norm-filter threshold sweep + autotune persistence -> BENCH_filter.json.

    DBCSR-style on-the-fly filtering on a decaying-norm workload (block
    norms fall exponentially with band distance |i - k|, the iterative
    C <- A.B regime of arXiv:1910.13555):

    * threshold sweep — for each ``filter_eps`` the planned gemm-task
      count must fall **monotonically**, the simulated makespan must
      never exceed the unfiltered schedule's (filtered-never-slower; the
      simulation is deterministic so the gate is noise-free), and the
      measured Frobenius error vs the unfiltered float64 product must
      stay <= the plan's documented additive bound ``filter_bound``;
    * ``filter_eps=0`` — the plan digest must be **bitwise identical** to
      a plan that never saw norms (the no-op contract the executable
      cache relies on);
    * filtered contract latency — steady-state wall of a filtered
      ``contract()`` call, FLOP-normalized against the dense matmul wall
      measured in the same process (the CI latency gate's filtered leg);
    * kernel autotune — a save/load roundtrip of a freshly tuned bucket
      (fingerprint-stable), with the recorded winner never slower than
      the generic ``xla`` route on its own bucket.

    The acceptance booleans ride in the JSON (CI asserts them).
    """
    import json
    import os
    import tempfile

    import jax.numpy as jnp
    import numpy as np

    from repro.core import DistributedMatmul
    from repro.core.contract import BlockSparseTensor
    from repro.core.plan import plan_matmul
    from repro.core.sparsity import block_norms
    from repro.kernels.autotune import KernelAutotuner, set_autotune_cache
    from repro.launch.mesh import make_host_mesh
    from repro.sched import abstract_summa_config, from_plan, simulate

    set_autotune_cache(None)  # keep measured digests on the cold path
    blk, n = 16, 1024
    bs = n // blk
    cfg = abstract_summa_config(blk, blk, strategy="taskbased")
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=blk)
    rng = np.random.default_rng(0)
    decay = np.exp(
        -0.8 * np.abs(np.arange(blk)[:, None] - np.arange(blk)[None, :])
    )

    def mat(_seed):
        x = rng.standard_normal((n, n))
        return (
            x.reshape(blk, bs, blk, bs) * decay[:, None, :, None]
        ).reshape(n, n)

    a64, b64 = mat(0), mat(1)
    a32 = jnp.asarray(a64, jnp.float32)
    b32 = jnp.asarray(b64, jnp.float32)
    an = block_norms(a64, blk, blk)
    bn = block_norms(b64, blk, blk)
    pmax = float(np.max(an[:, :, None] * bn[None, :, :]))
    ref = a64 @ b64

    def gemms(graph):
        return sum(
            1 for t in graph.tasks if t.kind == "gemm" and t.flops > 0
        )

    base_plan = plan_matmul(n, n, n, cfg)
    base_sim = simulate(from_plan(base_plan))
    # eps=0 digest bitwise: norms without a threshold are a strict no-op
    eps0_plan = plan_matmul(
        n, n, n, cfg, a_norms=an, b_norms=bn, filter_eps=0.0
    )
    digest_preserved = eps0_plan.digest() == base_plan.digest()

    entries = []
    prev_gemms = None
    monotone = True
    for frac in (0.0, 1e-4, 1e-3, 1e-2, 5e-2):
        eps = frac * pmax
        if eps > 0.0:
            p = plan_matmul(
                n, n, n, cfg, a_norms=an, b_norms=bn, filter_eps=eps
            )
        else:
            p = base_plan
        sim = simulate(from_plan(p))
        ng = gemms(from_plan(p))
        out, compile_s, wall_s = timed_split(
            lambda e=eps: mm(
                a32, b32, a_norms=an, b_norms=bn, filter_eps=e
            )
        )
        err = float(
            np.linalg.norm(np.asarray(out, np.float64) - ref)
        )
        bound = float(getattr(p, "filter_bound", 0.0))
        # float32 execution noise rides on top of the analytic bound;
        # normalize the slack to the result's own scale
        slack = 1e-5 * float(np.linalg.norm(ref))
        entry = {
            "name": f"filter_f{frac:g}",
            "filter_eps": eps,
            "gemm_tasks": ng,
            "gemm_tasks_unfiltered": gemms(from_plan(base_plan)),
            "filter_bound": bound,
            "error_frobenius": err,
            "error_within_bound": bool(err <= bound + slack),
            "makespan_s": sim.makespan_s,
            "makespan_unfiltered_s": base_sim.makespan_s,
            "never_slower_sim": bool(
                sim.makespan_s <= base_sim.makespan_s * (1 + 1e-9)
            ),
            "wall_s": wall_s,
            "compile_s": compile_s,
        }
        if prev_gemms is not None and ng > prev_gemms:
            monotone = False
        prev_gemms = ng
        entries.append(entry)
        _row(
            entry["name"], wall_s * 1e6,
            f"gemms={ng};bound={bound:.3g};err={err:.3g};"
            f"sim={sim.makespan_s:.3e}",
        )
        assert entry["error_within_bound"], (entry["name"], err, bound)
        assert entry["never_slower_sim"], (
            entry["name"], sim.makespan_s, base_sim.makespan_s,
        )
    assert monotone, [e["gemm_tasks"] for e in entries]
    assert digest_preserved, "filter_eps=0 changed the plan digest"

    # filtered contract leg of the latency gate: steady-state wall of a
    # filtered contract() vs the dense matmul wall, FLOP-normalized
    xa = BlockSparseTensor.from_dense(a32, block_shape=(bs, bs))
    xb = BlockSparseTensor.from_dense(b32, block_shape=(bs, bs))
    eps_mid = 1e-3 * pmax
    _, dense_compile, dense_wall = timed_split(lambda: mm(a32, b32))
    cout, c_compile, c_wall = timed_split(
        lambda: mm.contract("ik,kj->ij", xa, xb, filter_eps=eps_mid)
    )
    fp = mm.plan(n, n, n, a_norms=an, b_norms=bn, filter_eps=eps_mid)
    fsummary = fp.summary()
    contract_entry = {
        "name": "contract_filtered",
        "filter_eps": eps_mid,
        "wall_s": c_wall,
        "compile_s": c_compile,
        "dense_wall_s": dense_wall,
        "flops_sparse": fsummary["flops_sparse"],
        "flops_dense": fsummary["flops_dense"],
    }
    entries.append(contract_entry)
    _row(
        "filter_contract", c_wall * 1e6,
        f"dense_wall={dense_wall * 1e6:.1f}us;"
        f"flops_ratio={fsummary['flops_sparse'] / fsummary['flops_dense']:.3f}",
    )

    # kernel autotune: tuned winner never loses to the generic route on
    # its own bucket, and the JSON persistence roundtrip is stable
    tuner = KernelAutotuner()
    entry_at = tuner.tune(bs, bs, bs, repeats=2, routes=("xla", "pallas"))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "autotune.json")
        tuner.save(path)
        restored = KernelAutotuner()
        n_loaded = restored.load(path)
    autotune = {
        "winner": entry_at["winner"],
        "times_s": entry_at["times_s"],
        "winner_not_slower_than_generic": bool(
            entry_at["times_s"][entry_at["winner"]]
            <= entry_at["times_s"]["xla"]
        ),
        "roundtrip_entries": n_loaded,
        "roundtrip_fingerprint_stable": bool(
            restored.fingerprint() == tuner.fingerprint()
        ),
    }
    assert autotune["winner_not_slower_than_generic"], autotune
    assert autotune["roundtrip_fingerprint_stable"], autotune
    _row(
        "filter_autotune", 0.0,
        f"winner={autotune['winner']};entries={n_loaded}",
    )

    with open(json_path, "w") as f:
        json.dump(
            {
                "bench": "filter",
                "entries": entries,
                "autotune": autotune,
                "digest_preserved_eps0": digest_preserved,
                "monotone_gemm_reduction": monotone,
                "cache_stats": mm.cache_stats(),
            },
            f, indent=2,
        )
    print(f"# wrote {json_path}", flush=True)


def bench_serve(json_path: str) -> None:
    """Continuous vs static vs paged serving -> BENCH_serve.json.

    One ragged-arrival trace (adjacent requests alternate short/long
    decode depths — the shape static batching is worst at), served three
    ways through the *same* scheduler loop:

    * ``static``     — admit only when every slot is free (classic batch
                       serving; the baseline).
    * ``continuous`` — admit into any free slot every step.
    * ``paged``      — continuous + paged KV backend (``serve.pages``).

    Records tokens/s and p50/p99 per-step latency, asserts all three
    produce identical greedy outputs per request, and that continuous
    needs strictly fewer steps than static.  Also round-trips the
    persistent plan service (``serve.plan_service``): cold warm-up tunes,
    a restored service re-applies winners with zero tuner runs — the CI
    gate re-checks that across *processes*.
    """
    import json

    import jax

    from repro.configs import get_config
    from repro.dist.context import ParallelCtx
    from repro.models.model import init_model
    from repro.serve import engine
    from repro.serve.plan_service import PlanService
    from repro.serve.scheduler import Scheduler, ragged_trace

    cfg = get_config("llama3.2-1b", smoke=True)
    ctx = ParallelCtx(mesh=None)
    params = init_model(jax.random.PRNGKey(0), cfg, ctx)
    n_slots, max_len = 4, 48

    def trace():
        return ragged_trace(
            16, prompt_lens=(8, 16), gen_lens=(4, 24),
            vocab=cfg.vocab_size, seed=7,
        )

    entries, outputs = {}, {}
    for name, mode, backend in (
        ("static", "static", "dense"),
        ("continuous", "continuous", "dense"),
        ("paged", "continuous", "paged"),
    ):
        sched = Scheduler(
            params, cfg, ctx, n_slots=n_slots, max_len=max_len,
            mode=mode, backend=backend, page_size=8,
        )
        res = sched.run(trace())
        outputs[name] = res.pop("outputs")
        entries[name] = res
        _row(
            f"serve_{name}", res["p50_step_ms"] * 1e3,
            f"tok/s={res['tokens_per_s']:.1f};steps={res['steps']};"
            f"p99_ms={res['p99_step_ms']:.2f}",
        )
    assert outputs["continuous"] == outputs["static"] == outputs["paged"], (
        "serving modes disagree on greedy outputs"
    )
    assert entries["continuous"]["steps"] < entries["static"]["steps"], (
        entries["continuous"]["steps"], entries["static"]["steps"],
    )
    speedup = (
        entries["continuous"]["tokens_per_s"]
        / entries["static"]["tokens_per_s"]
    )
    _row("serve_speedup", 0.0, f"continuous/static={speedup:.2f}x")

    # plan-service persistence: cold tune -> save -> restore -> zero tunes
    import os
    import tempfile

    import numpy as _np
    from jax.sharding import Mesh

    mesh = Mesh(_np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    pctx = ParallelCtx(mesh=mesh, matmul_strategy="auto")
    cold = PlanService()
    engine.warm_matmul_plans(
        cfg, pctx, n_slots, 16, warm_executables=False, service=cold
    )
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "plans.json")
        cold.save(path)
        warm = PlanService()
        n_loaded = warm.load(path)
        engine.warm_matmul_plans(
            cfg, pctx, n_slots, 16, warm_executables=False, service=warm
        )
    plan_svc = {
        "cold_tunes": cold.stats["tunes"],
        "warm_tunes": warm.stats["tunes"],
        "warm_hits": warm.stats["hits"],
        "entries": n_loaded,
        "traffic": cold.traffic,
        "fingerprint_stable": bool(
            warm.fingerprint() == cold.fingerprint()
        ),
    }
    assert plan_svc["cold_tunes"] > 0, plan_svc
    assert plan_svc["warm_tunes"] == 0, plan_svc
    assert plan_svc["fingerprint_stable"], plan_svc
    _row(
        "serve_plan_service", 0.0,
        f"cold_tunes={plan_svc['cold_tunes']};"
        f"warm_tunes={plan_svc['warm_tunes']}",
    )

    with open(json_path, "w") as f:
        json.dump(
            {
                "bench": "serve",
                "trace": {
                    "requests": 16, "prompt_lens": [8, 16],
                    "gen_lens": [4, 24], "n_slots": n_slots,
                    "max_len": max_len,
                },
                "entries": entries,
                "speedup_continuous_vs_static": speedup,
                "outputs_identical_across_modes": True,
                "plan_service": plan_svc,
            },
            f, indent=2,
        )
    print(f"# wrote {json_path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_summa.json")
    ap.add_argument("--sched-json", default="BENCH_sched.json")
    ap.add_argument("--ranksparse-json", default="BENCH_ranksparse.json")
    ap.add_argument("--contract-json", default="BENCH_contract.json")
    ap.add_argument("--spgemm-json", default="BENCH_spgemm.json")
    ap.add_argument("--filter-json", default="BENCH_filter.json")
    ap.add_argument("--serve-json", default="BENCH_serve.json")
    ap.add_argument(
        "--only",
        help="comma-separated list of JSON-writing sections to run "
        "(ranksparse, sched, summa, contract, spgemm, filter, serve), "
        "e.g. --only summa,contract (CI artifact jobs)",
    )
    args = ap.parse_args()
    runners = {
        "summa": lambda: bench_planned_sparse(args.json),
        "sched": lambda: bench_sched(args.sched_json),
        "ranksparse": lambda: bench_ranksparse(args.ranksparse_json),
        "contract": lambda: bench_contract(args.contract_json),
        "spgemm": lambda: bench_spgemm(args.spgemm_json),
        "filter": lambda: bench_filter(args.filter_json),
        "serve": lambda: bench_serve(args.serve_json),
    }
    if args.only is not None:
        names = [s.strip() for s in args.only.split(",") if s.strip()]
        valid = ", ".join(sorted(runners))
        if not names:
            ap.error(f"--only: empty bench list (valid benches: {valid})")
        unknown = [s for s in names if s not in runners]
        if unknown:
            ap.error(
                f"--only: unknown bench name(s) {', '.join(unknown)} "
                f"(valid benches: {valid})"
            )
        print("name,us_per_call,derived")
        for s in names:
            runners[s]()
        return
    print("name,us_per_call,derived")
    bench_table1()
    bench_planned_sparse(args.json)
    bench_sched(args.sched_json)
    bench_ranksparse(args.ranksparse_json)
    bench_contract(args.contract_json)
    bench_spgemm(args.spgemm_json)
    bench_filter(args.filter_json)
    bench_blocksparse()
    bench_strategies()
    bench_weak_scaling(args.quick)
    bench_strong_scaling(args.quick)


if __name__ == "__main__":
    main()
