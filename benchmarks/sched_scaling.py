"""Simulated weak/strong scaling at paper scale (repro.sched).

The paper's Figs 4-8 run up to thousands of processes; this container has
one core.  The discrete-event simulator closes the gap: the exact task
DAG the schedule implies (nonuniform block extents, cyclic embedding,
multiple-issue window) is simulated on virtual grids up to 64x64 = 4096
devices.  The headline reproduction: with I = 1 the nonuniform schedule
loses substantially to the uniform one; with the Eq.-(1) lookahead the
loss is largely absorbed (paper §4.4).

    PYTHONPATH=src python -m benchmarks.sched_scaling [--quick]

Writes ``results/sched_scaling.json`` and prints CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.blocking import nonuniform_tiling, uniform_tiling
from repro.sched import eq1_lookahead, from_tilings, simulate

AVG_BLOCK = 256  # the paper's average logical block size


def simulate_case(
    p: int, n: int, *, nonuniform: bool, lookahead: int | None, seed: int = 0
) -> dict:
    blocks = max(n // AVG_BLOCK, 1)
    if nonuniform:
        tilings = [
            nonuniform_tiling(n, blocks, seed=seed + s) for s in range(3)
        ]
    else:
        tilings = [uniform_tiling(n, AVG_BLOCK) for _ in range(3)]
    graph = from_tilings(p, p, *tilings, lookahead=lookahead)
    sim = simulate(graph)
    flops = 2.0 * float(n) ** 3
    return {
        "grid": [p, p],
        "devices": p * p,
        "n": n,
        "blocks": blocks,
        "nonuniform": nonuniform,
        "lookahead": graph.lookahead,
        "makespan_s": sim.makespan_s,
        "gflops_per_s": flops / sim.makespan_s / 1e9,
        "imbalance_ratio": sim.imbalance_ratio,
        "efficiency": sim.efficiency,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="results/sched_scaling.json")
    args = ap.parse_args()

    grids = [8, 16, 32] if args.quick else [8, 16, 32, 64]
    out = []
    print("name,makespan_us,derived")
    # weak scaling: per-device work constant (N grows with sqrt P)
    for p in grids:
        n = 2048 * (p // 8)
        for nonuni in (False, True):
            rows = {}
            for la in (1, None):
                r = simulate_case(p, n, nonuniform=nonuni, lookahead=la)
                r["curve"] = "weak"
                out.append(r)
                rows[r["lookahead"]] = r
            eq1 = eq1_lookahead(p, p, max(n // AVG_BLOCK, 1))
            speedup = rows[1]["makespan_s"] / rows[eq1]["makespan_s"]
            tag = "nonuniform" if nonuni else "uniform"
            print(
                f"sched_weak_{tag}_P{p*p}_N{n},"
                f"{rows[eq1]['makespan_s']*1e6:.1f},"
                f"I_eq1={eq1};speedup_vs_I1={speedup:.2f};"
                f"gflops={rows[eq1]['gflops_per_s']:.0f};"
                f"imbalance={rows[eq1]['imbalance_ratio']:.2f}",
                flush=True,
            )
    # strong scaling: fixed N
    n = 16_384
    for p in grids:
        for nonuni in (False, True):
            r = simulate_case(p, n, nonuniform=nonuni, lookahead=None)
            r["curve"] = "strong"
            out.append(r)
            tag = "nonuniform" if nonuni else "uniform"
            print(
                f"sched_strong_{tag}_P{p*p}_N{n},"
                f"{r['makespan_s']*1e6:.1f},"
                f"gflops={r['gflops_per_s']:.0f};"
                f"efficiency={r['efficiency']:.2f}",
                flush=True,
            )
    # the recovery claim, spelled out at the largest grid
    p = grids[-1]
    n = 2048 * (p // 8)
    uni = simulate_case(p, n, nonuniform=False, lookahead=None)
    non1 = simulate_case(p, n, nonuniform=True, lookahead=1)
    noneq = simulate_case(p, n, nonuniform=True, lookahead=None)
    recovery = {
        "curve": "recovery",
        "devices": p * p,
        "n": n,
        "uniform_eq1_s": uni["makespan_s"],
        "nonuniform_I1_s": non1["makespan_s"],
        "nonuniform_eq1_s": noneq["makespan_s"],
        "loss_at_I1": non1["makespan_s"] / uni["makespan_s"],
        "loss_at_eq1": noneq["makespan_s"] / uni["makespan_s"],
        "multi_issue_speedup": non1["makespan_s"] / noneq["makespan_s"],
    }
    out.append(recovery)
    print(
        f"sched_recovery_P{p*p}_N{n},{noneq['makespan_s']*1e6:.1f},"
        f"loss_I1={recovery['loss_at_I1']:.2f}x;"
        f"loss_eq1={recovery['loss_at_eq1']:.2f}x;"
        f"speedup={recovery['multi_issue_speedup']:.2f}",
        flush=True,
    )
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
