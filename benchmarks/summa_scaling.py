"""Shared runner for the paper's scaling experiments (Figs 4-8).

Each measurement runs in a fresh subprocess with an emulated device count
so the parent process keeps seeing one device.  Two metric classes:

* wall-time / GFLOP-rate — what the paper plots.  CAVEAT (recorded in
  EXPERIMENTS.md): this container has ONE physical core, so emulated
  multi-device wall time measures the algorithm's total work + overhead,
  not true parallel speedup.
* structural metrics from the compiled HLO — per-device FLOPs and
  collective bytes (hardware-independent; these are what must scale for
  the algorithm to scale, and what the roofline consumes).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

_CHILD = r"""
import json, time, sys
import numpy as np, jax, jax.numpy as jnp
cfg_in = json.loads(sys.argv[1])
P = cfg_in["grid"]
N = cfg_in["n"]
strategy = cfg_in["strategy"]
nonuniform = cfg_in["nonuniform"]
repeats = cfg_in["repeats"]

from repro.launch.mesh import make_mesh
mesh = make_mesh((P[0], P[1]), ("data", "model"))
from repro.core import (DistributedMatmul, NonuniformMatmul, nonuniform_tiling,
                        uniform_tiling)
from repro.analysis.hlo import analyze_hlo

rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(N, N)), jnp.float32)
b = jnp.asarray(rng.normal(size=(N, N)), jnp.float32)
mm = DistributedMatmul(mesh, strategy=strategy, k_blocks=cfg_in["k_blocks"])

if nonuniform:
    nb = max(N // cfg_in["block"], 1)  # paper: avg logical block 256
    tilings = [nonuniform_tiling(N, nb, seed=s) for s in (1, 2, 3)]
    # physical tile 64: bounds bucketization padding waste to ~12% per dim
    run = NonuniformMatmul(mm, *tilings, tile=64)
else:
    run = mm

fn = jax.jit(lambda a, b: run(a, b))
lowered = fn.lower(a, b)
compiled = lowered.compile()
wc = analyze_hlo(compiled.as_text())

out = fn(a, b)
out.block_until_ready()   # warmup (compile already done)
t0 = time.perf_counter()
for _ in range(repeats):
    out = fn(a, b)
out.block_until_ready()
wall = (time.perf_counter() - t0) / repeats

flops_total = 2.0 * N * N * N
print(json.dumps({
    "wall_s": wall,
    "gflops": flops_total / wall / 1e9,
    "flops_per_device_hlo": wc.flops,
    "coll_bytes_per_device": wc.coll_bytes,
    "coll_breakdown": wc.coll_bytes_by_op,
}))
"""


def run_config(
    grid: tuple[int, int],
    n: int,
    *,
    strategy: str = "taskbased",
    nonuniform: bool = False,
    block: int = 256,
    k_blocks: int | None = None,
    repeats: int = 3,
) -> dict:
    devices = grid[0] * grid[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    payload = json.dumps(
        {
            "grid": list(grid),
            "n": n,
            "strategy": strategy,
            "nonuniform": nonuniform,
            "block": block,
            "k_blocks": k_blocks or max(grid),
            "repeats": repeats,
        }
    )
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, payload],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return json.loads(proc.stdout.strip().splitlines()[-1])
