import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""The paper's headline experiment at production scale (dry-run).

Lowers + compiles the task-based SUMMA for the paper's matrix sizes
(N = 32768 / 65536, block 256) on the 16x16 production mesh and the
2x16x16 multi-pod mesh, for every strategy, and reports roofline terms.

Every cell routes through the ``plan_matmul`` / ``execute_plan``
front-ends (the 2.5D variant passes its precomputed plan to
``summa_25d_matmul``), so the compiled numbers reflect plan pruning and —
for the ``tuned`` cell — the schedule the autotuner picked.  Each cell
also records the discrete-event simulator's predicted makespan next to
the roofline bound, so predicted and structural costs land side by side.

    PYTHONPATH=src python -m benchmarks.paper_scale_dryrun
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import analyze_hlo, roofline
from repro.core.plan import plan_matmul
from repro.core.summa import SummaConfig, execute_plan, summa_25d_matmul
from repro.launch.mesh import make_production_mesh
from repro.sched.simulator import simulate_plan
from repro.sched.tuner import tune_plan


def run(n: int, strategy: str, k_blocks: int, multi_pod: bool = False,
        two_five_d: bool = False, tune: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    row_axis = (
        "data" if two_five_d
        else (("pod", "data") if multi_pod else "data")
    )
    cfg = SummaConfig(
        mesh=mesh, row_axis=row_axis, col_axis="model",
        strategy=strategy, k_blocks=k_blocks,
    )
    a = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    plan = plan_matmul(n, n, n, cfg, itemsize=2)
    if tune:
        plan = tune_plan(plan)
    assert plan.padded_shapes == (a.shape, b.shape), "paper sizes divide grid"
    if two_five_d:
        mm = lambda a, b: summa_25d_matmul(a, b, cfg, plan=plan)
    else:
        mm = lambda a, b: execute_plan(a, b, plan)
    sim = simulate_plan(plan)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(mm).lower(a, b)
        compiled = lowered.compile()
        hlo = compiled.as_text()
        mem = compiled.memory_analysis()
    wc = analyze_hlo(hlo)
    chips = int(np.prod(list(mesh.shape.values())))
    rep = roofline(
        flops=wc.flops, hbm_bytes=wc.hbm_bytes, coll_bytes=wc.wire_bytes,
        chips=chips, model_flops=2.0 * n**3,
    )
    return {
        "n": n,
        "strategy": plan.cfg.strategy if tune else strategy,
        "k_blocks": plan.k_steps,
        "lookahead": plan.resolve_lookahead(),
        "tuned": plan.tuned,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "compile_s": round(time.time() - t0, 1),
        "compute_s": rep.compute_s,
        "memory_s": rep.memory_s,
        "collective_s": rep.collective_s,
        "sim_makespan_s": sim.makespan_s,
        "sim_efficiency": sim.efficiency,
        "dominant": rep.dominant,
        "bound_s": rep.bound_s,
        "frac": rep.compute_s / rep.bound_s if rep.bound_s else 0.0,
        "useful": rep.useful_ratio,
        "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9 if mem else None,
    }


def main():
    out = []
    for tag, strategy, kb, kwargs in [
        ("procedural ", "procedural", 16, {}),
        ("taskbased  ", "taskbased", 16, {}),
        ("taskbased  ", "taskbased", 128, {}),  # over-decomposition
        ("allgather  ", "allgather", 16, {}),
        ("tuned      ", "taskbased", 16, dict(tune=True)),
    ]:
        r = run(32_768, strategy, kb, **kwargs)
        if kwargs.get("tune"):
            r["variant"] = "tuned"
        out.append(r)
        print(
            f"N=32768 {tag} k={r['k_blocks']:4d} I={r['lookahead']:3d} "
            f"[{r['mesh']}]: "
            f"compute={r['compute_s']*1e3:7.2f}ms mem={r['memory_s']*1e3:7.2f}ms "
            f"coll={r['collective_s']*1e3:7.2f}ms "
            f"sim={r['sim_makespan_s']*1e3:7.2f}ms dom={r['dominant']:10s} "
            f"frac={r['frac']:.3f} temp={r['temp_gb']:.2f}GB",
            flush=True,
        )
    for tag, kwargs in [
        ("taskbased-2D ", dict(multi_pod=True)),
        ("taskbased-25D", dict(multi_pod=True, two_five_d=True)),
    ]:
        r = run(32_768, "taskbased", 32, **kwargs)
        r["variant"] = tag.strip()
        out.append(r)
        print(
            f"N=32768 {tag} k=  32 I={r['lookahead']:3d} [{r['mesh']}]: "
            f"compute={r['compute_s']*1e3:7.2f}ms mem={r['memory_s']*1e3:7.2f}ms "
            f"coll={r['collective_s']*1e3:7.2f}ms "
            f"sim={r['sim_makespan_s']*1e3:7.2f}ms dom={r['dominant']:10s} "
            f"frac={r['frac']:.3f}",
            flush=True,
        )
    os.makedirs("results", exist_ok=True)
    with open("results/paper_scale_dryrun.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
