import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""The paper's headline experiment at production scale (dry-run).

Lowers + compiles the task-based SUMMA for the paper's matrix sizes
(N = 32768 / 65536, block 256) on the 16x16 production mesh and the
2x16x16 multi-pod mesh, for every strategy, and reports roofline terms.

    PYTHONPATH=src python -m benchmarks.paper_scale_dryrun
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import analyze_hlo, roofline
from repro.core.summa import SummaConfig, summa_25d_matmul, summa_matmul
from repro.launch.mesh import make_production_mesh


def run(n: int, strategy: str, k_blocks: int, multi_pod: bool = False,
        two_five_d: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    row_axis = (
        "data" if two_five_d
        else (("pod", "data") if multi_pod else "data")
    )
    cfg = SummaConfig(
        mesh=mesh, row_axis=row_axis, col_axis="model",
        strategy=strategy, k_blocks=k_blocks,
    )
    a = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    b = jax.ShapeDtypeStruct((n, n), jnp.bfloat16)
    mm = summa_25d_matmul if two_five_d else summa_matmul
    t0 = time.time()
    with mesh:
        lowered = jax.jit(lambda a, b: mm(a, b, cfg)).lower(a, b)
        compiled = lowered.compile()
        hlo = compiled.as_text()
        mem = compiled.memory_analysis()
    wc = analyze_hlo(hlo)
    chips = int(np.prod(list(mesh.shape.values())))
    rep = roofline(
        flops=wc.flops, hbm_bytes=wc.hbm_bytes, coll_bytes=wc.wire_bytes,
        chips=chips, model_flops=2.0 * n**3,
    )
    return {
        "n": n,
        "strategy": strategy,
        "k_blocks": k_blocks,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "compile_s": round(time.time() - t0, 1),
        "compute_s": rep.compute_s,
        "memory_s": rep.memory_s,
        "collective_s": rep.collective_s,
        "dominant": rep.dominant,
        "bound_s": rep.bound_s,
        "frac": rep.compute_s / rep.bound_s if rep.bound_s else 0.0,
        "useful": rep.useful_ratio,
        "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9 if mem else None,
    }


def main():
    out = []
    for strategy, kb in [
        ("procedural", 16),
        ("taskbased", 16),
        ("taskbased", 128),  # over-decomposition: 8 panels per grid col
        ("allgather", 16),
    ]:
        r = run(32_768, strategy, kb)
        out.append(r)
        print(
            f"N=32768 {strategy:11s} k={kb:4d} [{r['mesh']}]: "
            f"compute={r['compute_s']*1e3:7.2f}ms mem={r['memory_s']*1e3:7.2f}ms "
            f"coll={r['collective_s']*1e3:7.2f}ms dom={r['dominant']:10s} "
            f"frac={r['frac']:.3f} temp={r['temp_gb']:.2f}GB",
            flush=True,
        )
    for tag, kwargs in [
        ("taskbased-2D ", dict(multi_pod=True)),
        ("taskbased-25D", dict(multi_pod=True, two_five_d=True)),
    ]:
        r = run(32_768, "taskbased", 32, **kwargs)
        r["variant"] = tag.strip()
        out.append(r)
        print(
            f"N=32768 {tag} k=  32 [{r['mesh']}]: "
            f"compute={r['compute_s']*1e3:7.2f}ms mem={r['memory_s']*1e3:7.2f}ms "
            f"coll={r['collective_s']*1e3:7.2f}ms dom={r['dominant']:10s} "
            f"frac={r['frac']:.3f}",
            flush=True,
        )
    os.makedirs("results", exist_ok=True)
    with open("results/paper_scale_dryrun.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
