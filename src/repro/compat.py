"""Version tolerance for the jax APIs this repo leans on.

The codebase is written against the modern spellings (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``); this
module maps them onto whatever the installed jax provides so the same
source runs from jax 0.4.x (``jax.experimental.shard_map``, no axis
types) up to current releases.  Import from here instead of feature-
probing at call sites:

    from repro.compat import shard_map

No third-party dependencies are introduced — everything degrades to the
older public API or to a no-op (axis types only affect GSPMD's
auto/explicit mode split, which this repo does not rely on).
"""
from __future__ import annotations

from typing import Any

import jax

__all__ = [
    "shard_map",
    "AxisType",
    "HAS_AXIS_TYPE",
    "mesh_axis_types_kwargs",
    "pallas_tpu_compiler_params",
]


try:  # jax >= 0.5.3
    from jax.sharding import AxisType

    HAS_AXIS_TYPE = True
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None
    HAS_AXIS_TYPE = False


def mesh_axis_types_kwargs(n_axes: int) -> dict[str, Any]:
    """kwargs for ``jax.make_mesh`` / ``Mesh``: all-Auto axis types when the
    installed jax supports them, nothing otherwise."""
    if HAS_AXIS_TYPE:
        return {"axis_types": (AxisType.Auto,) * n_axes}
    return {}


if hasattr(jax, "shard_map"):  # jax >= 0.6

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )

else:  # jax 0.4.x/0.5.x: experimental module, ``check_rep`` spelling
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )


shard_map.__doc__ = """``jax.shard_map`` with a stable signature across jax versions.

``check_vma`` maps to the old ``check_rep`` flag on jax < 0.6."""


def pallas_tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` under its per-version name.

    jax 0.4.x/0.5.x call it ``TPUCompilerParams``; newer releases renamed
    it to ``CompilerParams``."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:  # pragma: no cover - depends on installed jax
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
