"""Symbolic structure algebra for sparse x sparse (SpGEMM) products.

The planner historically let only A's structure drive pruning; genuinely
sparse workloads need the full A.B.C structure *triple* (ROADMAP item 1).
This module is the single source of truth for the symbolic pieces:

* :func:`output_mask` — the boolean block product ``c = a (.) b`` that
  every layer (``plan_matmul``'s dead-output pruning, ``contract()``'s
  inferred result masks) derives the C structure from, so the planner and
  the contraction front-end can never disagree;
* :func:`output_rank_bound` — the rank-aware refinement: a sum of
  per-addend rank bounds ``min(r_a[i,k], r_b[k,j])``, since the rank of a
  sum of products is at most the sum of the factor ranks;
* :func:`live_elems` — the modeled element volume a structure moves when
  its operand travels, the common currency of the stationarity chooser
  (factored blocks charge their factor footprint, mirroring
  ``sparsity.rank_panel_factored_comm``).

Structure operands are duck-typed: ``None`` (dense), a boolean/integer
block mask, a ``BlockRankMap``, or a ``RankCSR`` — rank structures
contribute their ``rank > 0`` support (rank 0 = screened out).
"""
from __future__ import annotations

import numpy as np

from repro.core.sparsity import BlockRankMap, RankCSR

__all__ = [
    "as_block_mask",
    "as_rank_grid",
    "output_mask",
    "output_rank_bound",
    "live_elems",
    "filter_keep",
    "output_norms",
]


def as_block_mask(
    structure, blocks: tuple[int, int] | None = None
) -> np.ndarray | None:
    """Normalize a structure operand to a boolean block mask.

    ``None`` stays ``None`` unless ``blocks`` gives the grid to synthesize
    all-ones on; rank structures (``BlockRankMap`` / ``RankCSR``) map to
    their ``rank > 0`` support; anything array-like is cast to bool.
    """
    if structure is None:
        if blocks is None:
            return None
        return np.ones(blocks, dtype=bool)
    if isinstance(structure, RankCSR):
        return np.asarray(structure.rank_map().mask, dtype=bool)
    if isinstance(structure, BlockRankMap):
        return np.asarray(structure.mask, dtype=bool)
    return np.asarray(structure, dtype=bool)


def as_rank_grid(structure) -> np.ndarray | None:
    """The per-block rank grid of a structure operand, or ``None`` when it
    carries no rank information (plain masks are rank-blind)."""
    if isinstance(structure, RankCSR):
        structure = structure.rank_map()
    if isinstance(structure, BlockRankMap):
        return np.asarray(structure.ranks, dtype=np.int64)
    return None


def output_mask(
    a_structure,
    b_structure,
    *,
    m_blocks: int | None = None,
    n_blocks: int | None = None,
) -> np.ndarray | None:
    """Symbolic output structure ``c = a (.) b`` (boolean block product).

    ``c[i, j]`` is live iff some panel ``kk`` has both ``a[i, kk]`` and
    ``b[kk, j]`` live — exactly the blocks a sparse x sparse product can
    populate.  One-sided inputs broadcast the surviving row/column support
    over the dense side's grid (``m_blocks`` / ``n_blocks``, default 1);
    two dense sides return ``None`` (a dense product has no structure to
    feed back).  Rank structures contribute their ``rank > 0`` support.
    """
    am = as_block_mask(a_structure)
    bm = as_block_mask(b_structure)
    if am is None and bm is None:
        return None
    if am is None:
        live_col = bm.any(axis=0)  # (N_blk,) columns reachable at all
        mb = 1 if m_blocks is None else int(m_blocks)
        return np.broadcast_to(live_col[None, :], (mb, bm.shape[1])).copy()
    if bm is None:
        live_row = am.any(axis=1)  # (M_blk,) rows with any contribution
        nb = 1 if n_blocks is None else int(n_blocks)
        return np.broadcast_to(live_row[:, None], (am.shape[0], nb)).copy()
    if am.shape[1] != bm.shape[0]:
        raise ValueError(
            f"A col-blocks ({am.shape[1]}) must equal B row-blocks "
            f"({bm.shape[0]})"
        )
    return (am.astype(np.int64) @ bm.astype(np.int64)) > 0


def output_rank_bound(a_structure, b_structure) -> np.ndarray | None:
    """Rank-aware output structure: an upper bound on each C block's rank.

    ``rank(C[i,j]) <= sum_k min(rank(A[i,k]), rank(B[k,j]))`` — each
    addend ``A[i,k] @ B[k,j]`` has rank at most the smaller factor rank,
    and ranks are subadditive over the sum.  Plain masks enter as rank-1*
    support in the sense of "unbounded": a masked (non-rank) operand
    contributes ``min`` with infinity, i.e. the other side's rank, or 1
    per addend when neither side carries ranks.  Returns ``None`` when
    neither side has block structure at all.
    """
    am = as_block_mask(a_structure)
    bm = as_block_mask(b_structure)
    if am is None or bm is None:
        return None
    ra = as_rank_grid(a_structure)
    rb = as_rank_grid(b_structure)
    big = np.int64(np.iinfo(np.int32).max)
    ra = np.where(am, big, 0) if ra is None else np.asarray(ra, np.int64)
    rb = np.where(bm, big, 0) if rb is None else np.asarray(rb, np.int64)
    if ra.shape[1] != rb.shape[0]:
        raise ValueError(
            f"A col-blocks ({ra.shape[1]}) must equal B row-blocks "
            f"({rb.shape[0]})"
        )
    per = np.minimum(ra[:, :, None], rb[None, :, :])  # (M, K, N) addends
    per = np.minimum(per, big)  # mask x mask addends stay bounded
    per = np.where(per == big, 1, per)
    return per.sum(axis=1)


def filter_keep(
    a_norms: np.ndarray, b_norms: np.ndarray, filter_eps: float
) -> tuple[np.ndarray, float]:
    """DBCSR-style product screening on per-block Frobenius norms.

    ``keep[i, k, j]`` is True iff the (i, k, j) gemm's contribution bound
    ``||A_ik||_F * ||B_kj||_F`` reaches ``filter_eps`` (dead blocks — norm
    0 — never survive).  Returns ``(keep, bound)`` where ``bound`` is the
    sum of the dropped nonzero products: by submultiplicativity and the
    triangle inequality, executing only the kept triples perturbs C by at
    most ``bound`` in Frobenius norm — the additive error bound
    ``plan_matmul`` records as ``filter_bound``.  ``keep`` shrinks
    monotonically in ``filter_eps``, so task counts are monotone too.
    """
    a = np.asarray(a_norms, np.float64)
    b = np.asarray(b_norms, np.float64)
    if a.shape[1] != b.shape[0]:
        raise ValueError(
            f"A col-blocks ({a.shape[1]}) must equal B row-blocks "
            f"({b.shape[0]})"
        )
    prod = a[:, :, None] * b[None, :, :]  # (M, K, N) contribution bounds
    keep = prod >= float(filter_eps)
    keep &= prod > 0.0
    bound = float(prod[(~keep) & (prod > 0.0)].sum())
    return keep, bound


def output_norms(
    a_norms: np.ndarray,
    b_norms: np.ndarray,
    keep: np.ndarray | None = None,
) -> np.ndarray:
    """Propagated per-block norm *bounds* for ``C = A . B``.

    ``||C_ij||_F <= sum_k ||A_ik||_F * ||B_kj||_F`` — the norm grids
    multiply like the matrices themselves.  With ``keep`` (a ``(M, K, N)``
    screening tensor from :func:`filter_keep`) only surviving triples
    contribute, so iterative chains see the *filtered* predecessor
    structure, not the symbolic product: a C block all of whose addends
    were screened carries bound 0 and drops out of the next product
    entirely (progressive sparsification, the chain regression pins this).
    """
    a = np.asarray(a_norms, np.float64)
    b = np.asarray(b_norms, np.float64)
    if keep is None:
        return a @ b
    prod = a[:, :, None] * b[None, :, :]
    return np.where(keep, prod, 0.0).sum(axis=1)


def live_elems(structure, shape: tuple[int, int]) -> float:
    """Modeled element count this operand moves when it travels.

    Dense (``None``) charges the full extent; masks charge live blocks at
    their dense block area; rank structures charge each live block
    ``min(r * (bm + bk), bm * bk)`` — factors travel while they are the
    smaller representation, the same per-block crossover the rank
    executors take (``sparsity.rank_panel_factored_comm``).
    """
    rows, cols = int(shape[0]), int(shape[1])
    if structure is None:
        return float(rows * cols)
    ranks = as_rank_grid(structure)
    mask = as_block_mask(structure)
    rb, cb = mask.shape
    if rows % rb or cols % cb:
        raise ValueError(
            f"structure grid {mask.shape} must evenly block ({rows},{cols})"
        )
    br, bc = rows // rb, cols // cb
    if ranks is None:
        return float(mask.sum()) * br * bc
    r = ranks[mask]
    return float(np.minimum(r * (br + bc), br * bc).sum())
