"""repro.spgemm — output-structure-aware sparse x sparse planning.

The planning layer for SpGEMM (sparse x sparse) products, built from
three pieces the rest of the stack consumes:

* ``structure`` — the symbolic output-structure pass: ``c = a (.) b``
  boolean block products (rank-aware), the single inference both
  ``core.plan.plan_matmul`` (dead-output gemm pruning) and
  ``core.contract`` (inferred result masks) use;
* ``stationarity`` — the DBCSR-style A-/B-/C-stationary chooser from
  modeled comm volume of the structure triple (arXiv:1910.13555);
* the one-sided **pull** comm mode (RDMA-SpGEMM, arXiv:2311.18141) lives
  where its artifacts do: ``fetch`` tasks in ``sched.taskgraph``, the
  owner-clock contention in ``sched.simulator``, and the gather-by-index
  executor route in ``core.summa`` — all keyed off
  ``MatmulPlan.comm_mode``.

Import direction: ``repro.spgemm`` may import ``repro.core.sparsity``
and ``repro.sched.taskgraph`` at module level; ``core.plan`` /
``core.contract`` import this package lazily inside functions (they sit
upstream in the import graph).
"""
from repro.spgemm.stationarity import (
    STATIONARITIES,
    choose_stationarity,
    stationarity_comm_volumes,
)
from repro.spgemm.structure import (
    as_block_mask,
    as_rank_grid,
    filter_keep,
    live_elems,
    output_mask,
    output_norms,
    output_rank_bound,
)

__all__ = [
    "STATIONARITIES",
    "choose_stationarity",
    "stationarity_comm_volumes",
    "as_block_mask",
    "as_rank_grid",
    "filter_keep",
    "live_elems",
    "output_mask",
    "output_norms",
    "output_rank_bound",
]
