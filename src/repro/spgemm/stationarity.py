"""Stationarity choice for sparse x sparse products (DBCSR-style).

DBCSR (arXiv:1910.13555) makes a block-sparse multiplication library
production-grade by *choosing which operand stays put* from modeled
communication volume.  On our 2-D grid the three schedules are:

* **C-stationary** (today's SUMMA layout): A column-panels broadcast
  along grid rows, B row-panels broadcast along grid columns, C never
  moves.
* **A-stationary**: A keeps its (row, col) layout over (M, K); B is
  re-laid-out with K over the grid *columns* and consumed in place; the
  per-device partials ``A_loc @ B_loc`` reduce-scatter along the column
  axis into C's canonical layout.
* **B-stationary**: the mirror — A re-laid-out with K over the grid
  *rows*, partials reduce-scatter along the row axis.

Modeled per-schedule volume: operands that broadcast pay the
broadcast-as-allreduce factor (``taskgraph.BCAST_FACTOR``); the final C
reduction of the A-/B-stationary schedules is a reduce-scatter —
bandwidth-optimal, factor 1.  Volumes are element counts from
``structure.live_elems`` (rank-aware), scaled by itemsize.  Each term is
gated on its axis actually having peers, so 1 x 1 grids tie at zero and
the chooser keeps "C" — bitwise identical to today's plans.
"""
from __future__ import annotations

from repro.sched.taskgraph import BCAST_FACTOR
from repro.spgemm.structure import live_elems, output_mask

__all__ = [
    "STATIONARITIES",
    "stationarity_comm_volumes",
    "choose_stationarity",
]

#: the three schedules, in tie-break priority order ("C" = today's layout)
STATIONARITIES = ("C", "A", "B")


def stationarity_comm_volumes(
    a_structure,
    b_structure,
    *,
    m: int,
    k: int,
    n: int,
    p_row: int,
    p_col: int,
    itemsize: int = 4,
    c_structure=None,
) -> dict[str, float]:
    """Modeled total comm bytes for each stationarity on the structure
    triple.  ``c_structure`` defaults to the symbolic output mask."""
    if c_structure is None:
        c_structure = output_mask(a_structure, b_structure)
    vol_a = live_elems(a_structure, (m, k)) * itemsize
    vol_b = live_elems(b_structure, (k, n)) * itemsize
    vol_c = live_elems(c_structure, (m, n)) * itemsize
    col = 1.0 if p_col > 1 else 0.0  # peers along the column axis
    row = 1.0 if p_row > 1 else 0.0  # peers along the row axis
    return {
        "C": BCAST_FACTOR * (vol_a * col + vol_b * row),
        "A": BCAST_FACTOR * vol_b * row + vol_c * col,
        "B": BCAST_FACTOR * vol_a * col + vol_c * row,
    }


def choose_stationarity(
    a_structure,
    b_structure,
    *,
    m: int,
    k: int,
    n: int,
    p_row: int,
    p_col: int,
    itemsize: int = 4,
    c_structure=None,
) -> tuple[str, dict[str, float]]:
    """The comm-volume argmin over :data:`STATIONARITIES`.

    Ties keep the earlier entry — "C" first — so a chooser that cannot
    distinguish the schedules reproduces today's plans exactly (the
    property the chooser tests pin bitwise).  Returns ``(choice,
    volumes)``; the volumes ride into ``PlanCost.comm_bytes``.
    """
    vols = stationarity_comm_volumes(
        a_structure, b_structure, m=m, k=k, n=n,
        p_row=p_row, p_col=p_col, itemsize=itemsize,
        c_structure=c_structure,
    )
    best = STATIONARITIES[0]
    for s in STATIONARITIES[1:]:
        if vols[s] < vols[best]:
            best = s
    return best, vols
