"""Train-step construction: grad accumulation, optimizer apply, sharding.

``build_train_step`` returns a jit-compiled step with donated state:
state = {"params", "opt", "step"}.  Gradients are accumulated in fp32
over ``microbatches`` slices of the global batch (a rolled ``lax.scan``
so activation memory is bounded by one microbatch), then the optimizer
applies once — exact arithmetic match to the unaccumulated step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.context import ParallelCtx
from repro.dist.partitioning import param_shardings
from repro.models.config import ModelConfig
from repro.models.model import init_model, loss_fn
from repro.train.optimizer import Optimizer

__all__ = ["make_train_state", "build_train_step", "state_shardings", "batch_shardings"]


def make_train_state(rng, cfg: ModelConfig, ctx: ParallelCtx, opt: Optimizer):
    params = init_model(rng, cfg, ctx)
    return {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(rng, cfg: ModelConfig, ctx: ParallelCtx, opt: Optimizer):
    """ShapeDtypeStruct state (dry-run: no allocation)."""
    return jax.eval_shape(lambda r: make_train_state(r, cfg, ctx, opt), rng)


def state_shardings(state, ctx: ParallelCtx):
    """NamedShardings for the full train state (params + opt mirrors).

    With ``ctx.zero1`` params are replicated over the FSDP axis while the
    optimizer mirrors stay FSDP-sharded: GSPMD then emits one
    reduce-scatter(grads) + all-gather(params) per optimizer step instead
    of per-microbatch parameter re-gathers (the ZeRO-3 <-> ZeRO-1
    trade-off, EXPERIMENTS.md §Perf)."""
    mesh = ctx.mesh
    tp = not ctx.pure_dp
    p_sh = param_shardings(state["params"], mesh, fsdp=not ctx.zero1, tp=tp)
    opt_ref_sh = (
        param_shardings(state["params"], mesh, fsdp=True, tp=tp)
        if ctx.zero1
        else p_sh
    )

    def mirror(opt_tree, params_tree, params_sh):
        """Optimizer slots mirror their param's sharding when shapes match;
        factored slots (adafactor vr/vc) drop the reduced dim's spec."""
        flat_p, pdef = jax.tree_util.tree_flatten(params_tree)
        flat_sh = pdef.flatten_up_to(params_sh)
        by_shape = {}

        def assign(leaf):
            for p, sh in zip(flat_p, flat_sh):
                if leaf.shape == p.shape:
                    return sh
                # adafactor factored: shape is p.shape minus last or
                # second-to-last dim
                if leaf.shape == p.shape[:-1]:
                    spec = sh.spec
                    return NamedSharding(mesh, P(*spec[:-1]))
                if leaf.shape == p.shape[:-2] + p.shape[-1:]:
                    spec = sh.spec
                    return NamedSharding(
                        mesh, P(*(spec[:-2] + spec[-1:]))
                    )
            return NamedSharding(mesh, P())

        return jax.tree.map(assign, opt_tree)

    return {
        "params": p_sh,
        "opt": mirror(state["opt"], state["params"], opt_ref_sh),
        "step": NamedSharding(mesh, P()),
    }


def batch_shardings(batch_struct, ctx: ParallelCtx):
    mesh = ctx.mesh
    return jax.tree.map(
        lambda x: NamedSharding(mesh, P(ctx.dp, *([None] * (len(x.shape) - 1)))),
        batch_struct,
    )


def build_train_step(
    cfg: ModelConfig,
    ctx: ParallelCtx,
    opt: Optimizer,
    *,
    microbatches: int = 1,
    remat: bool = True,
):
    def _constrain_grads(grads):
        """ZeRO-1: keep gradients (and the fp32 accumulator) FSDP-sharded
        even though params are replicated — each microbatch contributes a
        reduce-scatter instead of a full-size replicated accumulator."""
        if not ctx.zero1 or ctx.mesh is None or ctx.mesh.empty:
            return grads
        from repro.dist.partitioning import param_shardings

        sh = param_shardings(grads, ctx.mesh, fsdp=True)
        return jax.tree.map(jax.lax.with_sharding_constraint, grads, sh)

    def grad_fn(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            functools.partial(loss_fn, cfg=cfg, ctx=ctx, remat=remat),
            has_aux=True,
        )(params, mb)
        return _constrain_grads(grads), metrics

    def train_step(state, batch):
        params = state["params"]
        if microbatches == 1:
            grads, metrics = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def slice_mb(i, x):
                mb_size = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb_size, mb_size, 0)

            def body(carry, i):
                acc, _ = carry
                mb = jax.tree.map(functools.partial(slice_mb, i), batch)
                g, m = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g
                )
                return (acc, m), None

            zeros = _constrain_grads(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            m0 = {
                "ce": jnp.zeros((), jnp.float32),
                "z_loss": jnp.zeros((), jnp.float32),
                "aux": jnp.zeros((), jnp.float32),
                "loss": jnp.zeros((), jnp.float32),
            }
            (grads, metrics), _ = jax.lax.scan(
                body, (zeros, m0), jnp.arange(microbatches)
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params, new_opt = opt.update(grads, state["opt"], params, state["step"])
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        return new_state, metrics

    return train_step


def jit_train_step(train_step, state, batch_struct, ctx: ParallelCtx):
    """jit with explicit state/batch shardings and donated state."""
    st_sh = state_shardings(state, ctx)
    b_sh = batch_shardings(batch_struct, ctx)
    return jax.jit(
        train_step,
        in_shardings=(st_sh, b_sh),
        out_shardings=(st_sh, None),
        donate_argnums=(0,),
    )
