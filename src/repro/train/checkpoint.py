"""Sharded, atomic, reshardable checkpoints.

Layout::

    <dir>/step_<N>/
        manifest.json      # tree structure, shapes, dtypes, checksums
        <leaf-id>.npy      # one file per pytree leaf

Writes go to ``step_<N>.tmp`` and are renamed into place only after the
manifest (written last) lands — a crash mid-write never corrupts the
latest checkpoint.  Restore accepts a *different* mesh/sharding than the
one that saved (elastic scaling): leaves are loaded on host and
``device_put`` against the new shardings.

On a real multi-host cluster each host writes only the shards it owns
(process_index subdirs); this single-process container exercises the same
code path with one writer.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "CheckpointManager"]


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for keypath, leaf in flat:
        parts = []
        for k in keypath:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out


def _fname(path: str) -> str:
    return path.replace("/", "__") + ".npy"


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomic save; returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: dict[str, Any] = {"step": step, "leaves": {}}
    for path, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fn = _fname(path)
        # numpy can't serialise ml_dtypes (bfloat16 etc.) natively: store
        # the raw bits as uint; the manifest dtype restores the view.
        to_store = arr
        if arr.dtype.name == "bfloat16":
            to_store = arr.view(np.uint16)
        np.save(os.path.join(tmp, fn), to_store)
        with open(os.path.join(tmp, fn), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()[:16]
        manifest["leaves"][path] = {
            "file": fn,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha256_16": digest,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    step: int,
    target: Any,
    shardings: Any | None = None,
    *,
    verify: bool = True,
) -> Any:
    """Restore into the structure of ``target``.

    ``shardings``: optional pytree of NamedShardings (may describe a
    different mesh than the saver's — elastic restart).
    """
    base = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (keypath, tgt), shard in zip(flat, shard_flat):
        parts = []
        for k in keypath:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
        path = "/".join(parts)
        meta = manifest["leaves"][path]
        fn = os.path.join(base, meta["file"])
        if verify:
            with open(fn, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()[:16]
            if digest != meta["sha256_16"]:
                raise IOError(f"checksum mismatch for {path} in {base}")
        arr = np.load(fn)
        if meta["dtype"] == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        if list(arr.shape) != list(tgt.shape):
            raise ValueError(
                f"shape mismatch for {path}: ckpt {arr.shape} vs target {tgt.shape}"
            )
        arr = arr.astype(tgt.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints, saves every ``every`` steps."""

    def __init__(self, ckpt_dir: str, every: int = 50, keep: int = 3):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, tree: Any) -> bool:
        if self.every <= 0 or step % self.every:
            return False
        save_checkpoint(self.dir, step, tree)
        self._gc()
        return True

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)
