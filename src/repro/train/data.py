"""Deterministic synthetic data pipeline with async host prefetch.

Stateless-by-step generation: batch ``i`` is a pure function of
``(seed, i)`` (Philox counter RNG), so checkpoint/restart resumes the
stream losslessly with no dataloader state to save — a key piece of the
fault-tolerance story.  A background thread keeps a small prefetch queue
ahead of the training loop (the static-SPMD analogue of the paper's
communication/computation overlap, applied to the host->device edge).

Token stream: Zipf-distributed ids with a deterministic shift structure
so the LM has learnable signal (next-token = f(current), loss should
drop), which the e2e example asserts.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from repro.models.config import ModelConfig

__all__ = ["SyntheticData", "Prefetcher"]


class SyntheticData:
    def __init__(
        self,
        cfg: ModelConfig,
        batch: int,
        seq: int,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(key=[self.seed, step]))

    def batch_at(self, step: int) -> dict:
        rng = self._rng(step)
        cfg = self.cfg
        v = cfg.vocab_size
        if cfg.family == "audio":
            # frame embeddings + per-frame class labels, correlated so the
            # classifier head has signal
            labels = rng.integers(0, v, size=(self.batch, self.seq)).astype(np.int32)
            base = rng.normal(size=(v, cfg.d_model)).astype(np.float32)
            embeds = base[labels] + 0.1 * rng.normal(
                size=(self.batch, self.seq, cfg.d_model)
            ).astype(np.float32)
            return {"embeds": embeds, "labels": labels}
        # zipf-ish marginals + learnable next = (3*cur + 7) % V structure
        z = rng.zipf(1.5, size=(self.batch, self.seq))
        tokens = np.minimum(z, v - 1).astype(np.int32)
        half = self.seq // 2
        for t in range(half, self.seq):  # second half is deterministic
            tokens[:, t] = (3 * tokens[:, t - 1] + 7) % v
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1  # masked
        if cfg.family == "vlm":
            s_vis = self.seq // 4
            s_text = self.seq - s_vis
            embeds = rng.normal(size=(self.batch, s_vis, cfg.d_model)).astype(
                np.float32
            )
            pos = mrope_positions(self.batch, s_vis, s_text)
            return {
                "tokens": tokens[:, :s_text],
                "embeds": embeds,
                "positions": pos,
                "labels": labels[:, :s_text],
            }
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def mrope_positions(batch: int, s_vis: int, s_text: int) -> np.ndarray:
    """(B, S, 3) t/h/w positions: vision patches on a ~square grid, text
    sequential after the vision span (Qwen2-VL scheme, simplified)."""
    side = max(int(np.sqrt(s_vis)), 1)
    t = np.zeros(s_vis, np.int32)
    h = (np.arange(s_vis) // side).astype(np.int32)
    w = (np.arange(s_vis) % side).astype(np.int32)
    vis = np.stack([t, h, w], -1)  # (s_vis, 3)
    start = int(vis.max()) + 1
    txt = (start + np.arange(s_text)).astype(np.int32)[:, None].repeat(3, 1)
    pos = np.concatenate([vis, txt], 0)  # (S, 3)
    return np.broadcast_to(pos[None], (batch, s_vis + s_text, 3)).copy()


class Prefetcher:
    """Background-thread prefetch of ``SyntheticData`` batches."""

    def __init__(self, data: SyntheticData, start_step: int = 0, depth: int = 2):
        self.data = data
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.data.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
