"""Optimizers: AdamW (fp32 master + moments) and Adafactor (factored).

Pure-pytree implementations (no optax dependency).  AdamW keeps an fp32
master copy so bf16 params don't lose small updates.  Adafactor stores
row/column-factored second moments and no master/first moment — the
memory-frugal choice that lets the 1T-param kimi-k2 optimizer state fit
512 x 16 GB (EXPERIMENTS.md §Memory budget).

Both include global-norm clipping and a linear-warmup + cosine schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "Optimizer", "make_optimizer"]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # "adamw" | "adafactor"
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    af_eps: float = 1e-30


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def _schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = (step + 1.0) / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.minimum(warm, cos)


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def _clip_by_norm(tree, norm, max_norm):
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g.astype(jnp.float32) * scale, tree)


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "adamw":
        return _make_adamw(cfg)
    if cfg.name == "adafactor":
        return _make_adafactor(cfg)
    raise ValueError(cfg.name)


# ---------------------------------------------------------------- AdamW


def _make_adamw(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
        }

    def update(grads, state, params, step):
        gnorm = _global_norm(grads)
        grads = _clip_by_norm(grads, gnorm, cfg.clip_norm)
        lr = _schedule(cfg, step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - cfg.b1**t
        bc2 = 1.0 - cfg.b2**t

        def upd(g, m, v, master):
            m_new = cfg.b1 * m + (1 - cfg.b1) * g
            v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
            mh = m_new / bc1
            vh = v_new / bc2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if master.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + cfg.weight_decay * master
            return m_new, v_new, master - lr * delta

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_ma = treedef.flatten_up_to(state["master"])
        new_m, new_v, new_ma = [], [], []
        for g, mm, vv, ma in zip(flat_g, flat_m, flat_v, flat_ma):
            a, b, c = upd(g, mm, vv, ma)
            new_m.append(a)
            new_v.append(b)
            new_ma.append(c)
        new_state = {
            "master": jax.tree_util.tree_unflatten(treedef, new_ma),
            "m": jax.tree_util.tree_unflatten(treedef, new_m),
            "v": jax.tree_util.tree_unflatten(treedef, new_v),
        }
        new_params = jax.tree.map(
            lambda ma, p: ma.astype(p.dtype), new_state["master"], params
        )
        return new_params, new_state

    return Optimizer(init=init, update=update)


# ------------------------------------------------------------- Adafactor


def _make_adafactor(cfg: OptimizerConfig) -> Optimizer:
    def init(params):
        def leaf_state(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"v": jax.tree.map(leaf_state, params)}

    def update(grads, state, params, step):
        gnorm = _global_norm(grads)
        grads = _clip_by_norm(grads, gnorm, cfg.clip_norm)
        lr = _schedule(cfg, step)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-cfg.decay_rate)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        new_v, new_p = [], []
        for g, v, p in zip(flat_g, flat_v, flat_p):
            g2 = g * g + cfg.af_eps
            if p.ndim >= 2:
                vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
                # rank-1 reconstruction of the second moment
                denom = vr[..., :, None] * vc[..., None, :]
                denom = denom / jnp.maximum(
                    vr.mean(axis=-1)[..., None, None], cfg.af_eps
                )
                upd = g / jnp.sqrt(denom + cfg.af_eps)
                nv = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                upd = g / jnp.sqrt(vv + cfg.af_eps)
                nv = {"v": vv}
            # update clipping by RMS (Adafactor's d=1.0 rule)
            rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-12)
            upd = upd / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
            new_v.append(nv)
        return (
            jax.tree_util.tree_unflatten(treedef, new_p),
            {"v": jax.tree_util.tree_unflatten(treedef, new_v)},
        )

    return Optimizer(init=init, update=update)
