"""Training substrate: optimizer, data, checkpointing, train step."""
