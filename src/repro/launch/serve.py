"""Serving driver: batched prefill + autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --batch 4 --prompt-len 64 --gen 32

Runs greedy decoding over synthetic prompts and reports prefill/decode
throughput.  With ``--tp > 1`` the KV cache is sequence-sharded and decode
attention uses the LSE-combined partial-softmax path.

``--continuous`` switches from the fixed-shape batch loop to the
continuous-batching scheduler (``serve.scheduler``) over a ragged
arrival trace; ``--paged`` additionally backs the KV cache with page
pools (``serve.pages``).  ``--plan-cache plans.json`` persists tuned
schedule winners + the traffic distribution across processes
(``serve.plan_service``) — a warm restart re-applies stored winners with
zero tuner runs.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist.context import ParallelCtx
from repro.dist.partitioning import param_shardings
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_model
from repro.serve import engine
from repro.serve.plan_service import plan_service


def _run_continuous(params, cfg, ctx, args):
    from repro.serve.scheduler import Scheduler, ragged_trace

    max_len = args.prompt_len + args.gen
    sched = Scheduler(
        params, cfg, ctx, n_slots=args.batch, max_len=max_len,
        mode="continuous", backend="paged" if args.paged else "dense",
    )
    reqs = ragged_trace(
        4 * args.batch,
        prompt_lens=(max(args.prompt_len // 2, 1), args.prompt_len),
        gen_lens=(max(args.gen // 4, 1), args.gen),
        vocab=cfg.vocab_size, seed=args.seed,
    )
    res = sched.run(reqs)
    print(
        f"continuous[{res['backend']}]: {res['requests']} requests in "
        f"{res['steps']} steps   {res['tokens_per_s']:,.0f} tok/s   "
        f"p50 {res['p50_step_ms']:.1f} ms   p99 {res['p99_step_ms']:.1f} ms"
    )
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--matmul-strategy", default="xla",
        choices=["xla", "summa", "allgather", "auto"],
    )
    ap.add_argument("--max-len", type=int, default=None,
                    help="KV cache capacity (default: prompt-len + gen)")
    ap.add_argument("--continuous", action="store_true",
                    help="serve a ragged trace via the scheduler")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV backend (implies --continuous)")
    ap.add_argument("--plan-cache", default=None,
                    help="JSON path to load/save tuned plan winners")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no autoregressive serving")
    mesh = make_host_mesh(args.dp, args.tp)
    ctx = ParallelCtx(mesh=mesh, matmul_strategy=args.matmul_strategy)
    svc = plan_service()
    if args.plan_cache and os.path.exists(args.plan_cache):
        n = svc.load(args.plan_cache)
        print(f"plan cache: loaded {n} winners from {args.plan_cache}")
    # Derive all projection schedules once, outside the jitted traces.
    engine.warm_matmul_plans(cfg, ctx, args.batch, args.prompt_len)
    if args.plan_cache:
        svc.save(args.plan_cache)
        print(
            f"plan cache: saved {len(svc.table)} winners "
            f"(tunes={svc.stats['tunes']} hits={svc.stats['hits']})"
        )
    rng = jax.random.PRNGKey(args.seed)
    max_len = args.max_len or (args.prompt_len + args.gen)
    # The engine never corrupts state past capacity (writes are dropped),
    # but the logits would be wrong — the driver refuses up front.
    s_c = engine.cache_len(cfg, max_len)
    if cfg.window is None and args.prompt_len + args.gen > s_c:
        raise engine.CacheCapacityError(
            f"prompt {args.prompt_len} + gen {args.gen} = "
            f"{args.prompt_len + args.gen} tokens > cache capacity {s_c}; "
            "raise --max-len"
        )
    if args.continuous or args.paged:
        params = init_model(rng, cfg, ctx)
        with mesh:
            return _run_continuous(params, cfg, ctx, args)

    with mesh:
        params = init_model(rng, cfg, ctx)
        params = jax.tree.map(
            jax.device_put, params, param_shardings(params, mesh)
        )
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size,
        )
        inputs = {"tokens": prompts}
        if cfg.family == "vlm":
            s_vis = args.prompt_len // 4
            inputs = {
                "tokens": prompts[:, s_vis:],
                "embeds": jnp.zeros(
                    (args.batch, s_vis, cfg.d_model), jnp.bfloat16
                ),
                "positions": jnp.broadcast_to(
                    jnp.arange(args.prompt_len)[None, :, None],
                    (args.batch, args.prompt_len, 3),
                ).astype(jnp.int32),
            }

        prefill = jax.jit(
            lambda p, b: engine.prefill(p, b, cfg, ctx, max_len=max_len)
        )
        decode = jax.jit(lambda p, c, t: engine.decode_step(p, c, t, cfg, ctx))

        t0 = time.time()
        logits, cache = prefill(params, inputs)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        tokens = jnp.argmax(logits, axis=-1)
        out_tokens = [tokens]
        t0 = time.time()
        for _ in range(args.gen - 1):
            logits, cache = decode(params, cache, tokens)
            tokens = jnp.argmax(logits, axis=-1)
            out_tokens.append(tokens)
        tokens.block_until_ready()
        t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"generated shape: {gen.shape}")
    print(f"sample: {gen[0][:16].tolist()}")
    print(
        f"prefill: {args.batch * args.prompt_len / t_prefill:,.0f} tok/s   "
        f"decode: {args.batch * (args.gen - 1) / max(t_decode, 1e-9):,.0f} tok/s"
    )
    return gen


if __name__ == "__main__":
    main()
