import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the two lines above must execute before
jax initialises devices):

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k [--multi-pod] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Per cell this produces lowered.compile() (proving the sharding config is
coherent at 256/512 chips), prints memory_analysis / cost_analysis, and
derives the three roofline terms (analysis.hlo) recorded as JSON for
EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo as hloa
from repro.configs import cell_skip_reason, get_config
from repro.dist.context import ParallelCtx
from repro.dist.partitioning import param_shardings
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.model import init_model
from repro.serve import engine
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train import train_step as ts

DEFAULT_MICROBATCHES = 16


def make_ctx(
    mesh,
    multi_pod: bool,
    matmul_strategy: str = "xla",
    attention_impl: str = "ref",
    mlstm_chunk: int | None = None,
    zero1: bool = False,
    kv_quant: bool = False,
    slstm_replicated: bool = False,
    pure_dp: bool = False,
) -> ParallelCtx:
    if pure_dp:
        dp = ("pod", "data", "model") if multi_pod else ("data", "model")
    else:
        dp = ("pod", "data") if multi_pod else ("data",)
    return ParallelCtx(
        mesh=mesh,
        dp_axes=dp,
        tp_axis="model",
        matmul_strategy=matmul_strategy,
        attention_impl=attention_impl,
        mlstm_chunk=mlstm_chunk,
        zero1=zero1,
        kv_quant=kv_quant,
        slstm_replicated=slstm_replicated,
        pure_dp=pure_dp,
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs only — never allocated)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract train/prefill batch for this arch family."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "audio":
        return {
            "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    if cfg.family == "vlm":
        s_vis = s // 4
        s_text = s - s_vis
        return {
            "tokens": jax.ShapeDtypeStruct((b, s_text), i32),
            "embeds": jax.ShapeDtypeStruct((b, s_vis, cfg.d_model), jnp.bfloat16),
            "positions": jax.ShapeDtypeStruct((b, s, 3), i32),
            "labels": jax.ShapeDtypeStruct((b, s_text), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "labels": jax.ShapeDtypeStruct((b, s), i32),
    }


def model_flops_per_step(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (forward-only), N = active."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


# ---------------------------------------------------------------------------
# cell builders: return (jitted_fn, example_args_abstract)
# ---------------------------------------------------------------------------


def build_train_cell(cfg, shape, ctx, microbatches):
    opt = make_optimizer(
        OptimizerConfig(
            name="adafactor" if cfg.name.startswith("kimi") else "adamw"
        )
    )
    rng = jax.random.PRNGKey(0)
    state = ts.abstract_train_state(rng, cfg, ctx, opt)
    st_sh = ts.state_shardings(state, ctx)
    batch = input_specs(cfg, shape)
    b_sh = ts.batch_shardings(batch, ctx)
    step = ts.build_train_step(cfg, ctx, opt, microbatches=microbatches)
    jitted = jax.jit(
        step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
        donate_argnums=(0,),
    )
    state = _with_shardings(state, st_sh)
    batch = _with_shardings(batch, b_sh)
    return jitted, (state, batch)


def build_prefill_cell(cfg, shape, ctx):
    rng = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda r: init_model(r, cfg, ctx), rng)
    p_sh = param_shardings(params, ctx.mesh)
    batch = input_specs(cfg, shape)
    batch.pop("labels", None)
    b_sh = ts.batch_shardings(batch, ctx)

    def fn(p, b):
        return engine.prefill(p, b, cfg, ctx, max_len=shape.seq_len)

    jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
    return jitted, (_with_shardings(params, p_sh), _with_shardings(batch, b_sh))


def build_decode_cell(cfg, shape, ctx):
    rng = jax.random.PRNGKey(0)
    b = shape.global_batch
    params = jax.eval_shape(lambda r: init_model(r, cfg, ctx), rng)
    p_sh = param_shardings(params, ctx.mesh)
    cache = jax.eval_shape(
        lambda: engine.init_cache(cfg, b, shape.seq_len, kv_quant=ctx.kv_quant)
    )
    c_sh = _cache_shardings(cache, ctx, b)
    tokens = jax.ShapeDtypeStruct((b,), jnp.int32)
    t_sh = NamedSharding(
        ctx.mesh, P(ctx.dp if b % ctx.dp_size == 0 else None)
    )

    def fn(p, c, t):
        return engine.decode_step(p, c, t, cfg, ctx)

    jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh), donate_argnums=(1,))
    return jitted, (
        _with_shardings(params, p_sh),
        _with_shardings(cache, c_sh),
        _with_shardings(tokens, t_sh),
    )


def _cache_shardings(cache, ctx: ParallelCtx, batch: int):
    # One cache-sharding function for the whole codebase: the engine owns
    # the leaf classification (KV + quant scales vs recurrent state).
    return engine.cache_shardings(cache, ctx, batch)


def _with_shardings(abstract_tree, shardings):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_tree,
        shardings,
    )


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    microbatches: int = DEFAULT_MICROBATCHES,
    matmul_strategy: str = "xla",
    attention_impl: str = "ref",
    mlstm_chunk: int | None = None,
    zero1: bool = False,
    kv_quant: bool = False,
    slstm_replicated: bool = False,
    pure_dp: bool = False,
    save_hlo: str | None = None,
) -> dict:
    shape = SHAPES[shape_name]
    skip = cell_skip_reason(arch, shape_name)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "matmul_strategy": matmul_strategy,
        "attention_impl": attention_impl,
        "mlstm_chunk": mlstm_chunk,
        "zero1": zero1,
        "kv_quant": kv_quant,
        "microbatches": microbatches if shape.kind == "train" else None,
    }
    if skip:
        result["status"] = skip
        return result
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh, multi_pod, matmul_strategy, attention_impl,
                   mlstm_chunk, zero1, kv_quant, slstm_replicated, pure_dp)
    # per-microbatch batch must divide the DP degree, or sharding degrades
    # to replicated compute (found via the 2-pod roofline; EXPERIMENTS.md)
    if shape.kind == "train":
        microbatches = max(1, min(microbatches,
                                  shape.global_batch // ctx.dp_size))
        result["microbatches"] = microbatches
    # Derive + simulate (and for "auto": tune) the projection schedules
    # first, so the traces below hit the warmed plan cache.
    try:
        sched = sched_section(cfg, shape, ctx, microbatches)
    except Exception as e:  # simulation must never sink a dry-run cell
        sched = [{"status": f"sched-error: {type(e).__name__}: {e}"}]
    if sched is not None:
        result["sched"] = sched
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            jitted, args = build_train_cell(cfg, shape, ctx, microbatches)
        elif shape.kind == "prefill":
            jitted, args = build_prefill_cell(cfg, shape, ctx)
        else:
            jitted, args = build_decode_cell(cfg, shape, ctx)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # jax < 0.5 returns a one-element list of dicts, newer jax a dict
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else None
        hlo_text = compiled.as_text()
    wc = hloa.analyze_hlo(hlo_text)
    chips = int(np.prod(list(mesh.shape.values())))
    mf = model_flops_per_step(cfg, shape)
    rep = hloa.roofline(
        flops=wc.flops,
        hbm_bytes=wc.hbm_bytes,
        coll_bytes=wc.wire_bytes,  # ring wire-cost model (analysis.hlo)
        chips=chips,
        model_flops=mf,
    )
    result.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        chips=chips,
        flops_per_device=wc.flops,
        hbm_bytes_per_device=wc.hbm_bytes,
        collective_bytes_per_device=wc.coll_bytes,
        collective_wire_bytes_per_device=wc.wire_bytes,
        collective_breakdown=wc.coll_bytes_by_op,
        collective_counts=wc.coll_counts_by_op,
        xla_cost_analysis={
            "flops_unweighted": float(cost.get("flops", 0.0)) if cost else 0.0,
            "bytes_unweighted": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        },
        roofline=rep.row(),
        memory_analysis=_mem_dict(mem),
    )
    if save_hlo:
        os.makedirs(os.path.dirname(save_hlo), exist_ok=True)
        with open(save_hlo, "w") as f:
            f.write(hlo_text)
    return result


def sched_section(cfg, shape, ctx, microbatches: int) -> list | None:
    """Simulated projection schedules for this cell (repro.sched).

    For every FFN projection shape the cell will trace, derive (and with
    ``matmul_strategy="auto"`` tune) the ``MatmulPlan``, then run its task
    DAG through the discrete-event simulator: predicted makespan,
    imbalance, and the executed lookahead land next to the roofline terms
    in the cell JSON.  Plans are cached, so the subsequent trace reuses
    them.
    """
    if not ctx.has_mesh or ctx.matmul_strategy == "xla" or ctx.pure_dp:
        return None
    if not cfg.d_ff:
        return None
    from repro.sched.simulator import simulate_plan

    if shape.kind == "train":
        m = (shape.global_batch // max(microbatches, 1)) * shape.seq_len
    elif shape.kind == "prefill":
        m = shape.global_batch * shape.seq_len
    else:
        m = shape.global_batch
    tune = ctx.matmul_strategy == "auto"
    # plan under the activation dtype's itemsize, or the traces below plan
    # under a different cache key and re-derive (serve.warm_matmul_plans
    # makes the same move)
    itemsize = jnp.dtype(cfg.dtype).itemsize
    out = []
    d = cfg.d_model
    for k_in, n_out in ((d, cfg.d_ff), (cfg.d_ff, d)):
        plan = ctx.plan_projection(
            m, k_in, n_out, itemsize=itemsize, tune=tune
        )
        if plan is None:
            continue
        sim = simulate_plan(plan)
        out.append(
            {
                "proj": [m, k_in, n_out],
                "strategy": plan.cfg.strategy,
                "lookahead": plan.resolve_lookahead(),
                "k_steps": plan.k_steps,
                "sim_makespan_s": sim.makespan_s,
                "sim_imbalance": sim.imbalance_ratio,
                "sim_efficiency": sim.efficiency,
                "tuned": plan.tuned,
            }
        )
    return out


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=DEFAULT_MICROBATCHES)
    ap.add_argument("--matmul-strategy", default="xla",
                    choices=["xla", "summa", "allgather", "auto"])
    ap.add_argument("--attention", default="ref", choices=["ref", "chunked"])
    ap.add_argument("--mlstm-chunk", type=int, default=None)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--slstm-replicated", action="store_true")
    ap.add_argument("--pure-dp", action="store_true")
    ap.add_argument("--tag", default=None,
                    help="suffix for the result filename (perf variants)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    from repro.configs import ARCH_IDS

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    pods = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in pods:
                cells.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    for a, s, mp in cells:
        tag = f"{a}__{s}__{'2pod' if mp else '1pod'}"
        if args.matmul_strategy != "xla":
            tag += f"__{args.matmul_strategy}"
        if args.tag:
            tag += f"__{args.tag}"
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path):
            print(f"[skip-existing] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            res = run_cell(
                a, s, mp,
                microbatches=args.microbatches,
                matmul_strategy=args.matmul_strategy,
                attention_impl=args.attention,
                mlstm_chunk=args.mlstm_chunk,
                zero1=args.zero1,
                kv_quant=args.kv_quant,
                slstm_replicated=args.slstm_replicated,
                pure_dp=args.pure_dp,
                save_hlo=args.save_hlo,
            )
        except Exception as e:  # record failures — they are findings
            res = {
                "arch": a, "shape": s,
                "mesh": "2x16x16" if mp else "16x16",
                "status": f"error: {type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
        with open(out_path, "w") as f:
            json.dump(res, f, indent=1, default=str)
        print(f"[done] {tag}: {res.get('status')}", flush=True)


if __name__ == "__main__":
    main()
