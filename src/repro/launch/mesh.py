"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.  The dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before importing
jax; everything else sees the real device count.

Axis types (``jax.sharding.AxisType``) only exist on newer jax; on
older versions the meshes are built without them (repro.compat), which
is behaviour-identical for this repo since every axis would be ``Auto``.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import mesh_axis_types_kwargs


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_types_kwargs(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests, benchmarks, elastic restarts)."""
    return jax.make_mesh(shape, axes, **mesh_axis_types_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices this host exposes."""
    import numpy as np

    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"), **mesh_axis_types_kwargs(2))
