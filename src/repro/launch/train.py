"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 60 --global-batch 8 --seq 128 --ckpt-dir /tmp/ckpt \
        [--resume] [--fail-at-step 30] [--microbatches 2] \
        [--matmul-strategy summa] [--dp 1 --tp 1]

Features exercised here (the fault-tolerance story):
* periodic atomic checkpoints + ``--resume`` (restores params/opt/step and
  the data stream resumes deterministically at the right batch),
* ``--fail-at-step N`` kills the process mid-run to simulate a node
  failure; a following ``--resume`` run must continue losslessly,
* async host data prefetch (train.data.Prefetcher),
* optional task-based-SUMMA matmul strategy (the paper's algorithm in the
  training loop).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

from repro.configs import get_config
from repro.dist.context import ParallelCtx
from repro.launch.mesh import make_host_mesh
from repro.train import checkpoint as ckpt
from repro.train import train_step as ts
from repro.train.data import Prefetcher, SyntheticData
from repro.train.optimizer import OptimizerConfig, make_optimizer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--matmul-strategy", default="xla",
                    choices=["xla", "summa", "allgather", "auto"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(args.dp, args.tp)
    ctx = ParallelCtx(mesh=mesh, matmul_strategy=args.matmul_strategy)

    opt = make_optimizer(
        OptimizerConfig(
            name=args.optimizer, peak_lr=args.lr,
            warmup_steps=max(args.steps // 10, 1), total_steps=args.steps,
        )
    )
    rng = jax.random.PRNGKey(args.seed)
    with mesh:
        abstract = ts.abstract_train_state(rng, cfg, ctx, opt)
        st_sh = ts.state_shardings(abstract, ctx)
        # init under jit so every state leaf gets its own (sharded) buffer
        state = jax.jit(
            lambda r: ts.make_train_state(r, cfg, ctx, opt),
            out_shardings=st_sh,
        )(rng)

        start_step = 0
        if args.resume and args.ckpt_dir:
            last = ckpt.latest_step(args.ckpt_dir)
            if last is not None:
                state = ckpt.restore_checkpoint(
                    args.ckpt_dir, last, state, st_sh
                )
                start_step = last
                print(f"[resume] restored step {last} from {args.ckpt_dir}")

        data = SyntheticData(cfg, args.global_batch, args.seq, seed=args.seed)
        step_fn = ts.build_train_step(
            cfg, ctx, opt, microbatches=args.microbatches
        )
        batch0 = data.batch_at(0)
        b_sh = ts.batch_shardings(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch0),
            ctx,
        )
        jitted = jax.jit(
            step_fn, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )

        manager = (
            ckpt.CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
            if args.ckpt_dir
            else None
        )
        pre = Prefetcher(data, start_step=start_step)
        losses = []
        t0 = time.time()
        try:
            for step in range(start_step, args.steps):
                got_step, batch = pre.next()
                assert got_step == step, (got_step, step)
                batch = jax.tree.map(jax.device_put, batch, b_sh)
                state, metrics = jitted(state, batch)
                if args.fail_at_step is not None and step + 1 == args.fail_at_step:
                    # simulate a node failure AFTER the optimizer step but
                    # potentially before the checkpoint - worst case
                    if manager:
                        manager.maybe_save(step + 1, state)
                    print(f"[failure-sim] dying at step {step + 1}", flush=True)
                    sys.exit(42)
                if manager:
                    manager.maybe_save(step + 1, state)
                loss = float(metrics["loss"])
                losses.append(loss)
                if (step + 1) % args.log_every == 0 or step == start_step:
                    dt = time.time() - t0
                    print(
                        f"step {step + 1:5d}  loss {loss:8.4f}  "
                        f"ce {float(metrics['ce']):8.4f}  "
                        f"({dt / max(len(losses), 1):.2f}s/step)",
                        flush=True,
                    )
        finally:
            pre.stop()
        if manager:
            ckpt.save_checkpoint(args.ckpt_dir, args.steps, state)
    print(
        f"[done] steps {start_step}->{args.steps}  "
        f"first loss {losses[0]:.4f}  last loss {losses[-1]:.4f}"
    )
    return losses


if __name__ == "__main__":
    main()
