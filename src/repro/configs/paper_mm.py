"""The paper's own experiment configurations (matrix multiplication).

Matrix sizes and blockings from §4: BG/Q weak/strong scaling used square
matrices N in {32768, 65536, 98304, 256000}; the commodity-cluster strong
scaling used N=32768 with block size 256 (uniform) and average 256
(nonuniform).  These drive benchmarks/ and the SUMMA-engine dry-run.
"""
import dataclasses

PAPER_MATRIX_SIZES = (32_768, 65_536, 98_304, 256_000)
COMMODITY_N = 32_768
COMMODITY_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class MMConfig:
    n: int  # square matrix dimension
    block: int  # uniform block size (nonuniform: average)
    nonuniform: bool = False
    seed: int = 0

    @property
    def num_blocks(self) -> int:
        return self.n // self.block


# scaled-down versions runnable on this container (same structure)
BENCH_CONFIGS = {
    "uniform_small": MMConfig(n=2048, block=256),
    "nonuniform_small": MMConfig(n=2048, block=256, nonuniform=True),
    "uniform_medium": MMConfig(n=4096, block=256),
    "nonuniform_medium": MMConfig(n=4096, block=256, nonuniform=True),
}
