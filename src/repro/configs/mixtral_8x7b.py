"""mixtral-8x7b — MoE (8 experts, top-2) with sliding-window attention.

[arXiv:2401.04088] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000;
SWA window 4096.  Sub-quadratic via rolling-window KV cache -> long_500k
runs (decode touches only the last `window` keys).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32_000,
    activation="swiglu",
    window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=14336),
    subquadratic=True,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=0,
    vocab_size=512,
    activation="swiglu",
    window=16,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=64),
    subquadratic=True,
    tie_embeddings=False,
)
