"""hubert-xlarge — encoder-only audio backbone (w2v2 architecture).

[arXiv:2106.07447] 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.
Bidirectional attention, GELU FFN.  The conv feature extractor is a STUB:
``input_specs`` feeds precomputed frame embeddings (B, S, 1280).
Encoder-only: no decode shapes (see README.md §Cell skips).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    activation="gelu",
    rope="none",
    causal=False,
    embed_inputs=False,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke",
    family="audio",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=32,
    activation="gelu",
    rope="none",
    causal=False,
    embed_inputs=False,
    tie_embeddings=False,
)
