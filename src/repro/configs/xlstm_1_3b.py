"""xlstm-1.3b — sLSTM + mLSTM block stack.

[arXiv:2405.04517] 48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.
Pattern unit: 7 mLSTM + 1 sLSTM (the paper's [7:1] ratio); 48 = 6 units.
d_ff=0: blocks carry their own projections (mLSTM 2x expansion, sLSTM
4/3 post-FFN).  Fully recurrent -> long_500k runs with O(1) state.
"""
from repro.models.config import ModelConfig

_PATTERN = ("mlstm",) * 7 + ("slstm",)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    rope="none",
    block_pattern=_PATTERN,
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="xlstm-1.3b-smoke",
    family="ssm",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    rope="none",
    block_pattern=_PATTERN,
    subquadratic=True,
)
