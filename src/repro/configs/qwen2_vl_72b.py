"""qwen2-vl-72b — VLM backbone with M-RoPE and dynamic resolution.

[arXiv:2409.12191] 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064.  Backbone only: the vision tower is a STUB —
``input_specs`` provides precomputed patch embeddings for 1/4 of the
sequence plus (t, h, w) M-RoPE position streams.  Full attention ->
long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152_064,
    activation="swiglu",
    qkv_bias=True,
    rope="mrope",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2-vl-72b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=512,
    activation="swiglu",
    qkv_bias=True,
    rope="mrope",
    tie_embeddings=False,
)
