"""Architecture configs (one module per assigned arch) + registry."""
from repro.configs.registry import ARCH_IDS, SKIPS, cell_skip_reason, get_config
