"""command-r-35b — dense GQA, no biases.

[hf:CohereForAI/c4ai-command-r-v01] 40L d_model=8192 64H (GQA kv=8)
d_ff=22528 vocab=256000; head_dim=128; SwiGLU.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256_000,
    activation="swiglu",
    rope_theta=8_000_000.0,
)

SMOKE = ModelConfig(
    name="command-r-35b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=512,
    activation="swiglu",
)
