"""qwen2.5-32b — dense GQA with QKV bias.

[hf:Qwen/Qwen2.5-32B] 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064; head_dim=128; SwiGLU; RoPE theta 1e6.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152_064,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2.5-32b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=192,
    vocab_size=512,
    activation="swiglu",
    qkv_bias=True,
    tie_embeddings=False,
)
