"""recurrentgemma-9b — RG-LRU + local attention hybrid, 1 attn : 2 recurrent.

[arXiv:2402.19427] 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000.
Pattern unit (rglru, rglru, attn); 38 = 12 units + (rglru, rglru) tail.
Local attention window 2048 (Griffin).  Sub-quadratic: RG-LRU state is
O(1), local attention cache is O(window) -> long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    activation="geglu",
    rope="rope",
    window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    activation="geglu",
    rope="rope",
    window=16,
    block_pattern=("rglru", "rglru", "attn"),
    subquadratic=True,
)
