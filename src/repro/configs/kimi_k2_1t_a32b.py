"""kimi-k2-1t-a32b — trillion-parameter MoE (384 experts, top-8, 1 shared).

[arXiv:2501.kimi2, paper-table] 61L d_model=7168 64H (GQA kv=8)
expert d_ff=2048 vocab=163840; MoE 384e top-8 + 1 shared expert.
Full attention -> long_500k skipped.  Optimizer: Adafactor (factored
second moment) so 1T-param optimizer state fits 512 x 16 GB
(EXPERIMENTS.md §Memory budget).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=0,
    vocab_size=163_840,
    activation="swiglu",
    moe=MoEConfig(num_experts=384, top_k=8, d_ff=2048, num_shared_experts=1),
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="kimi-k2-1t-a32b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=0,
    vocab_size=512,
    activation="swiglu",
    moe=MoEConfig(num_experts=8, top_k=4, d_ff=32, num_shared_experts=1),
    tie_embeddings=False,
)
