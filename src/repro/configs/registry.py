"""Architecture registry: ``--arch <id>`` resolution.

Each id maps to a module exporting ``CONFIG`` (the full, paper-faithful
configuration) and ``SMOKE`` (a reduced variant that runs a CPU forward
+ train step in seconds).  ``get_config(arch, smoke=...)`` picks one.

The ten architectures were chosen so that every scheduling/sharding
scenario the system claims to handle is exercised by at least one
config — dense vs MoE (expert parallelism), full vs sliding-window vs
recurrent sequence mixing (KV-ring vs O(1) caches), tied vs untied
embeddings, text vs audio vs vision-language frontends, and AdamW vs
factored-Adafactor optimizer states.  README.md §Architectures has the
full id -> scenario table; README.md §Cell skips documents which
(arch, shape) dry-run cells are intentionally skipped and why.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "xlstm-1.3b": "xlstm_1_3b",
    "hubert-xlarge": "hubert_xlarge",
    "llama3.2-1b": "llama3_2_1b",
    "gemma-2b": "gemma_2b",
    "qwen2.5-32b": "qwen2_5_32b",
    "command-r-35b": "command_r_35b",
    "mixtral-8x7b": "mixtral_8x7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


# (arch, shape) cells that are skipped, with reasons (README.md §Cell skips)
SKIPS: dict[tuple[str, str], str] = {
    ("llama3.2-1b", "long_500k"): "skip(full-attn)",
    ("gemma-2b", "long_500k"): "skip(full-attn)",
    ("qwen2.5-32b", "long_500k"): "skip(full-attn)",
    ("command-r-35b", "long_500k"): "skip(full-attn)",
    ("kimi-k2-1t-a32b", "long_500k"): "skip(full-attn)",
    ("qwen2-vl-72b", "long_500k"): "skip(full-attn)",
    ("hubert-xlarge", "long_500k"): "skip(encoder-only)",
    ("hubert-xlarge", "decode_32k"): "skip(encoder-only)",
}


def cell_skip_reason(arch: str, shape: str) -> str | None:
    return SKIPS.get((arch, shape))
