"""llama3.2-1b — small dense Llama-3 with GQA.

[hf:meta-llama/Llama-3.2-1B] 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256; head_dim=64; SwiGLU; RoPE theta 500k; tied embeddings.
Pure full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128_256,
    activation="swiglu",
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=512,
    activation="swiglu",
    rope_theta=500_000.0,
)
