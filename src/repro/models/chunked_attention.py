"""Flash-style chunked attention with a custom VJP — differentiable, and
no S x S materialization in either pass.

The reference attention writes (B, H, S, S) logits/probs to HBM (forward
AND backward), which dominates the memory roofline term of every
full-attention train cell.  This version tiles the computation into
(Cq x Ck) blocks: the forward is an online-softmax sweep, the backward
recomputes probability tiles (flash-attention recomputation).  All tiles
are VMEM-sized; only q/k/v/o/do and the (B, H, S) row statistics touch
HBM.  Causal block skipping drops ~half the tile work.

This is the "beyond-paper" optimization applied to the assigned LM cells
(EXPERIMENTS.md §Perf); the Pallas kernel (kernels/flash_attention.py)
covers the serving path, this covers training (XLA fuses the jnp tile
bodies).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["chunked_attention"]

_NEG = -1e30


def _tile_logits(q_i, k_j, scale, causal, window, q0, k0, cq, ck):
    """(B, Hkv, G, Cq, Ck) masked logit tile."""
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk",
        q_i.astype(jnp.float32) * scale,
        k_j.astype(jnp.float32),
    )
    pos_q = q0 + jnp.arange(cq)[:, None]
    pos_k = k0 + jnp.arange(ck)[None, :]
    mask = jnp.ones((cq, ck), bool)
    if causal:
        mask &= pos_q >= pos_k
    if window is not None:
        mask &= pos_q - pos_k < window
    return jnp.where(mask, s, _NEG)


def _fwd(q, k, v, scale, causal, window, cq, ck):
    """Returns (o fp32, m, l) with shapes (B,Hkv,G,S,D), (B,Hkv,G,S)."""
    b, hkv, g, s, d = q.shape
    nq, nk = s // cq, s // ck
    o = jnp.zeros((b, hkv, g, s, d), jnp.float32)
    m_all = jnp.full((b, hkv, g, s), _NEG, jnp.float32)
    l_all = jnp.zeros((b, hkv, g, s), jnp.float32)
    for qi in range(nq):
        q0 = qi * cq
        q_i = jax.lax.dynamic_slice_in_dim(q, q0, cq, axis=3)
        # causal: only kv chunks overlapping [*, q0+cq)
        kj_hi = nk if not causal else (q0 + cq + ck - 1) // ck
        kj_lo = 0
        if window is not None:
            kj_lo = max(0, (q0 - window) // ck)

        def body(carry, kj):
            m, l, acc = carry
            k0 = kj * ck
            k_j = jax.lax.dynamic_slice_in_dim(k, k0, ck, axis=2)
            v_j = jax.lax.dynamic_slice_in_dim(v, k0, ck, axis=2)
            st = _tile_logits(q_i, k_j, scale, causal, window, q0, k0, cq, ck)
            m_new = jnp.maximum(m, st.max(-1))
            p = jnp.exp(st - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_j.astype(jnp.float32)
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, d), jnp.float32)
        (m_i, l_i, acc_i), _ = jax.lax.scan(
            body, (m0, l0, a0), jnp.arange(kj_lo, kj_hi)
        )
        l_safe = jnp.where(l_i == 0, 1.0, l_i)
        o = jax.lax.dynamic_update_slice_in_dim(
            o, acc_i / l_safe[..., None], q0, axis=3
        )
        m_all = jax.lax.dynamic_update_slice_in_dim(m_all, m_i, q0, axis=3)
        l_all = jax.lax.dynamic_update_slice_in_dim(l_all, l_safe, q0, axis=3)
    return o, m_all, l_all


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _chunked_core(q, k, v, scale, causal, window, cq, ck):
    o, _, _ = _fwd(q, k, v, scale, causal, window, cq, ck)
    return o


def _chunked_core_fwd(q, k, v, scale, causal, window, cq, ck):
    o, m, l = _fwd(q, k, v, scale, causal, window, cq, ck)
    return o, (q, k, v, o, m, l)


def _chunked_core_bwd(scale, causal, window, cq, ck, res, do):
    q, k, v, o, m, l = res
    b, hkv, g, s, d = q.shape
    nq, nk = s // cq, s // ck
    do = do.astype(jnp.float32)
    delta = (do * o).sum(-1)  # (B,Hkv,G,S)
    dq = jnp.zeros_like(q, jnp.float32)
    dk = jnp.zeros((b, hkv, s, d), jnp.float32)
    dv = jnp.zeros((b, hkv, s, d), jnp.float32)
    for qi in range(nq):
        q0 = qi * cq
        q_i = jax.lax.dynamic_slice_in_dim(q, q0, cq, axis=3)
        do_i = jax.lax.dynamic_slice_in_dim(do, q0, cq, axis=3)
        m_i = jax.lax.dynamic_slice_in_dim(m, q0, cq, axis=3)
        l_i = jax.lax.dynamic_slice_in_dim(l, q0, cq, axis=3)
        dl_i = jax.lax.dynamic_slice_in_dim(delta, q0, cq, axis=3)
        kj_hi = nk if not causal else (q0 + cq + ck - 1) // ck
        kj_lo = 0 if window is None else max(0, (q0 - window) // ck)

        def body(carry, kj):
            dq_i, dk_acc, dv_acc = carry
            k0 = kj * ck
            k_j = jax.lax.dynamic_slice_in_dim(k, k0, ck, axis=2)
            v_j = jax.lax.dynamic_slice_in_dim(v, k0, ck, axis=2)
            st = _tile_logits(q_i, k_j, scale, causal, window, q0, k0, cq, ck)
            p = jnp.exp(st - m_i[..., None]) / l_i[..., None]
            dv_t = jnp.einsum("bhgqk,bhgqd->bhkd", p, do_i)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", do_i, v_j.astype(jnp.float32))
            ds = p * (dp - dl_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhgqk,bhkd->bhgqd", ds, k_j.astype(jnp.float32))
            dk_t = jnp.einsum("bhgqk,bhgqd->bhkd", ds, q_i.astype(jnp.float32))
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(dk_acc, k0, ck, 2) + dk_t,
                k0, axis=2,
            )
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(dv_acc, k0, ck, 2) + dv_t,
                k0, axis=2,
            )
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, hkv, g, cq, d), jnp.float32)
        (dq_i, dk, dv), _ = jax.lax.scan(
            body, (dq0, dk, dv), jnp.arange(kj_lo, kj_hi)
        )
        dq = jax.lax.dynamic_update_slice_in_dim(dq, dq_i, q0, axis=3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_chunked_core.defvjp(_chunked_core_fwd, _chunked_core_bwd)


def chunked_attention(
    q: jax.Array,  # (B, H, S, Dh)
    k: jax.Array,  # (B, Hkv, S, Dh)
    v: jax.Array,  # (B, Hkv, S, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    chunk_q: int = 512,
    chunk_k: int = 512,
    out_dtype: Any | None = None,
) -> jax.Array:
    """Drop-in replacement for ref.flash_attention_ref, differentiable,
    O(S) HBM in the sequence dimension."""
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    g = h // hkv
    cq = min(chunk_q, s)
    ck = min(chunk_k, s)
    while s % cq:
        cq //= 2
    while s % ck:
        ck //= 2
    scale_val = float(scale) if scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, hkv, g, s, dh)
    o = _chunked_core(qg, k, v, scale_val, causal, window, cq, ck)
    out_dtype = out_dtype or q.dtype
    return o.reshape(b, h, s, dh).astype(out_dtype)
