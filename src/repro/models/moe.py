"""Mixture-of-Experts layer with expert parallelism over the TP axis.

MoE *is* block-sparse tensor computing: each token-group × expert pair is
a nonuniformly-sized block of a block-diagonal matmul — the irregular
structure the paper targets.  The layer distributes experts over
``ctx.tp_axis`` (EP) inside a ``shard_map``:

  1. Router (fp32) + top-k on the replicated activation stream.
  2. Each EP shard gathers only the token copies routed to ITS experts
     into a static per-expert capacity buffer (sorted dispatch, no
     all-to-all, no one-hot blow-up; overflow copies are dropped —
     standard capacity discipline).
  3. Batched per-expert GEMMs over the buffer (exactly the active FLOPs,
     modulo capacity padding).
  4. Each shard scatters its partial outputs back to token order;
     a single ``psum`` over the EP axis combines shards (same collective
     cost as a Megatron TP FFN: one all-reduce of the activations).

Experts are zero-padded to a multiple of the EP degree so a single mesh
axis serves any expert count (e.g. Mixtral's 8 experts on a 16-way axis).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.dist.context import ParallelCtx
from repro.models import layers as L
from repro.models.config import ModelConfig, MoEConfig

from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def padded_experts(moe: MoEConfig, ep: int) -> int:
    return -(-moe.num_experts // ep) * ep


def capacity(moe: MoEConfig, seq: int, e_pad: int) -> int:
    c = math.ceil(seq * moe.top_k / e_pad * moe.capacity_factor)
    return max(8, -(-c // 8) * 8)


def init_moe(rng, cfg: ModelConfig, ctx: ParallelCtx, dtype=jnp.bfloat16) -> dict:
    moe = cfg.moe
    assert moe is not None
    d, f = cfg.d_model, moe.d_ff
    e_pad = padded_experts(moe, ctx.tp_size)
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    std = 1.0 / math.sqrt(d)
    p = {
        "norm": L.init_rmsnorm(d),
        "router": {
            "w": jax.random.normal(k1, (d, moe.num_experts), jnp.float32) * std
        },
        "w_gate": (jax.random.normal(k2, (e_pad, d, f), jnp.float32) * std).astype(dtype),
        "w_up": (jax.random.normal(k3, (e_pad, d, f), jnp.float32) * std).astype(dtype),
        "w_down": (jax.random.normal(k4, (e_pad, f, d), jnp.float32) / math.sqrt(f)).astype(dtype),
    }
    if moe.num_shared_experts:
        fs = moe.d_ff * moe.num_shared_experts
        shared_cfg = ModelConfig(
            name="shared",
            family="dense",
            num_layers=1,
            d_model=d,
            num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads,
            d_ff=fs,
            vocab_size=1,
            activation="swiglu",
        )
        from repro.models.ffn import init_ffn

        p["shared"] = init_ffn(k5, shared_cfg, dtype=dtype)
    return p


def _dispatch_compute_combine(
    h_loc, topi, gates, w_gate, w_up, w_down, *, e_pad, top_k, cap, tp_axis
):
    """shard_map body: EP-local dispatch -> expert GEMMs -> combine."""
    ep_idx = jax.lax.axis_index(tp_axis)
    e_loc = w_gate.shape[0]
    b, s, d = h_loc.shape
    tk = s * top_k

    eid = topi.reshape(b, tk)
    order = jnp.argsort(eid, axis=-1, stable=True)  # (B, Tk)
    inv = jnp.argsort(order, axis=-1)  # sorted position of each copy
    counts = jax.vmap(functools.partial(jnp.bincount, length=e_pad))(eid)
    offsets = jnp.cumsum(counts, axis=-1) - counts  # (B, E_pad)

    # ---- gather my experts' token copies into (B, E_loc, C, D) buffers
    my_experts = ep_idx * e_loc + jnp.arange(e_loc)  # (E_loc,)
    my_counts = jnp.take_along_axis(
        counts, jnp.broadcast_to(my_experts[None], (b, e_loc)), axis=-1
    )  # (B, E_loc)
    my_offsets = jnp.take_along_axis(
        offsets, jnp.broadcast_to(my_experts[None], (b, e_loc)), axis=-1
    )
    slot = my_offsets[:, :, None] + jnp.arange(cap)[None, None, :]  # (B,E_loc,C)
    slot_valid = jnp.arange(cap)[None, None, :] < my_counts[:, :, None]
    slot_c = jnp.clip(slot, 0, tk - 1).reshape(b, -1)
    copy_idx = jnp.take_along_axis(order, slot_c, axis=-1)  # (B, E_loc*C)
    tok_idx = copy_idx // top_k
    x_buf = jnp.take_along_axis(
        h_loc, tok_idx[:, :, None], axis=1
    )  # (B, E_loc*C, D)
    x_buf = jnp.where(slot_valid.reshape(b, -1, 1), x_buf, 0)
    x_buf = x_buf.reshape(b, e_loc, cap, d)

    # ---- expert GEMMs (SwiGLU)
    g = jnp.einsum("becd,edf->becf", x_buf, w_gate)
    u = jnp.einsum("becd,edf->becf", x_buf, w_up)
    mid = jax.nn.silu(g) * u
    y_buf = jnp.einsum("becf,efd->becd", mid, w_down)  # (B, E_loc, C, D)

    # ---- combine back to token order (partial: only my experts)
    rank = inv - jnp.take_along_axis(offsets, eid, axis=-1)  # (B, Tk)
    mine = (eid // e_loc) == ep_idx
    keep = mine & (rank < cap)
    local_e = jnp.clip(eid - ep_idx * e_loc, 0, e_loc - 1)
    flat = jnp.clip(local_e * cap + rank, 0, e_loc * cap - 1)
    z = jnp.take_along_axis(
        y_buf.reshape(b, e_loc * cap, d), flat[:, :, None], axis=1
    )  # (B, Tk, D)
    z = jnp.where(keep[:, :, None], z, 0)
    z = z.reshape(b, s, top_k, d) * gates[..., None].astype(z.dtype)
    y = z.sum(axis=2)
    return jax.lax.psum(y, tp_axis)


def moe_ffn(
    p: dict, x: jax.Array, cfg: ModelConfig, ctx: ParallelCtx
) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss)."""
    moe = cfg.moe
    assert moe is not None
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    b, s, d = h.shape

    logits = jnp.einsum(
        "bsd,de->bse", h.astype(jnp.float32), p["router"]["w"]
    )  # fp32 router
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(logits, moe.top_k)
    gates = jax.nn.softmax(topv, axis=-1)  # renormalize over selected

    # Switch-style load-balance aux loss.
    density = jnp.mean(
        jax.nn.one_hot(topi[..., 0], moe.num_experts, dtype=jnp.float32),
        axis=(0, 1),
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = moe.num_experts * jnp.sum(density * mean_prob)

    e_pad = padded_experts(moe, ctx.tp_size)
    cap = capacity(moe, s, e_pad)

    # Registered block masks over the (d, f) expert weight shapes: zero the
    # masked blocks so every expert computes the same block-sparse product
    # the planned FFN path would (MoE *is* block-sparse tensor computing —
    # this keeps the arithmetic contract aligned across the stack).
    w_gate_e, w_up_e, w_down_e = p["w_gate"], p["w_up"], p["w_down"]
    m_in = ctx.weight_mask(w_gate_e.shape[1:])
    m_out = ctx.weight_mask(w_down_e.shape[1:])
    if m_in is not None:
        w_gate_e = _mask_expert_weight(w_gate_e, m_in)
        w_up_e = _mask_expert_weight(w_up_e, m_in)
    if m_out is not None:
        w_down_e = _mask_expert_weight(w_down_e, m_out)

    if ctx.mesh is None or ctx.mesh.empty:
        # single-device fallback: one "shard" holding all experts
        y = _dispatch_compute_combine_local(
            h, topi, gates, w_gate_e, w_up_e, w_down_e,
            e_pad=e_pad, top_k=moe.top_k, cap=cap,
        )
    else:
        body = functools.partial(
            _dispatch_compute_combine,
            e_pad=e_pad,
            top_k=moe.top_k,
            cap=cap,
            tp_axis=ctx.tp_axis,
        )
        bspec = ctx.dp if b % max(ctx.dp_size, 1) == 0 else None
        act = P(bspec, None, None)
        y = shard_map(
            body,
            mesh=ctx.mesh,
            in_specs=(
                act,
                act,
                act,
                P(ctx.tp_axis, None, None),
                P(ctx.tp_axis, None, None),
                P(ctx.tp_axis, None, None),
            ),
            out_specs=act,
            check_vma=False,
        )(h, topi, gates, w_gate_e, w_up_e, w_down_e)

    if "shared" in p:
        from repro.models.ffn import ffn as dense_ffn

        shared_cfg = cfg
        # shared expert consumes the same normed input; reuse ffn on raw x
        # with its own norm inside -> pass x (it has its own norm params? no)
        # ffn() norms internally with p["shared"]["norm"].
        y = y + dense_ffn(p["shared"], x, _shared_view(cfg), ctx)
    return y.astype(x.dtype), aux


def _mask_expert_weight(w: jax.Array, mask) -> jax.Array:
    """Zero masked (d, f) blocks of a stacked (E, d, f) expert weight."""
    import numpy as np

    mask = np.asarray(mask, dtype=bool)
    _, d, f = w.shape
    rb, cb = mask.shape
    if d % rb or f % cb:
        raise ValueError(f"weight {w.shape} not divisible by mask {mask.shape}")
    fine = jnp.asarray(np.repeat(np.repeat(mask, d // rb, 0), f // cb, 1))
    return jnp.where(fine[None], w, jnp.zeros((), w.dtype))


def _shared_view(cfg: ModelConfig) -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        cfg, activation="swiglu",
        d_ff=cfg.moe.d_ff * cfg.moe.num_shared_experts,
    )


def _dispatch_compute_combine_local(
    h, topi, gates, w_gate, w_up, w_down, *, e_pad, top_k, cap
):
    """Mesh-free single-shard version (smoke tests): EP degree 1."""

    class _Ax:
        pass

    b, s, d = h.shape
    tk = s * top_k
    e_loc = w_gate.shape[0]
    eid = topi.reshape(b, tk)
    order = jnp.argsort(eid, axis=-1, stable=True)
    inv = jnp.argsort(order, axis=-1)
    counts = jax.vmap(functools.partial(jnp.bincount, length=e_pad))(eid)
    offsets = jnp.cumsum(counts, axis=-1) - counts
    slot = offsets[:, :, None] + jnp.arange(cap)[None, None, :]
    slot_valid = jnp.arange(cap)[None, None, :] < counts[:, :, None]
    slot_c = jnp.clip(slot, 0, tk - 1).reshape(b, -1)
    copy_idx = jnp.take_along_axis(order, slot_c, axis=-1)
    tok_idx = copy_idx // top_k
    x_buf = jnp.take_along_axis(h, tok_idx[:, :, None], axis=1)
    x_buf = jnp.where(slot_valid.reshape(b, -1, 1), x_buf, 0)
    x_buf = x_buf.reshape(b, e_loc, cap, d)
    g = jnp.einsum("becd,edf->becf", x_buf, w_gate)
    u = jnp.einsum("becd,edf->becf", x_buf, w_up)
    mid = jax.nn.silu(g) * u
    y_buf = jnp.einsum("becf,efd->becd", mid, w_down)
    rank = inv - jnp.take_along_axis(offsets, eid, axis=-1)
    keep = rank < cap
    flat = jnp.clip(eid * cap + rank, 0, e_loc * cap - 1)
    z = jnp.take_along_axis(y_buf.reshape(b, e_loc * cap, d), flat[:, :, None], axis=1)
    z = jnp.where(keep[:, :, None], z, 0)
    z = z.reshape(b, s, top_k, d) * gates[..., None].astype(z.dtype)
    return z.sum(axis=2)
