"""GQA multi-head attention with RoPE / M-RoPE, causal + sliding window.

Training uses the differentiable jnp path (XLA fuses it; remat bounds the
S² logits).  Serving prefill uses the Pallas flash-attention kernel
(forward-only).  TP: heads are sharded over ``ctx.tp_axis`` via sharding
constraints; GSPMD inserts the corresponding collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.context import ParallelCtx
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import layers as L
from repro.models.config import ModelConfig


def init_attention(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "wq": L.init_dense(k1, d, cfg.num_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": L.init_dense(k2, d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": L.init_dense(k3, d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": L.init_dense(k4, cfg.num_heads * hd, d, bias=False, dtype=dtype),
        "norm": L.init_rmsnorm(d),
    }


def _project_qkv(p, x, positions, cfg: ModelConfig, ctx: ParallelCtx):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.dense(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    k = L.dense(p["wk"], x).reshape(b, s, cfg.num_kv_heads, hd)
    v = L.dense(p["wv"], x).reshape(b, s, cfg.num_kv_heads, hd)
    q = ctx.wsc(q, ctx.dp, None, ctx.tp_axis, None)
    k = ctx.wsc(k, ctx.dp, None, ctx.tp_axis if cfg.num_kv_heads >= ctx.tp_size else None, None)
    v = ctx.wsc(v, ctx.dp, None, ctx.tp_axis if cfg.num_kv_heads >= ctx.tp_size else None, None)
    if cfg.rope == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = L.apply_mrope(q, positions, cfg.rope_theta)
        k = L.apply_mrope(k, positions, cfg.rope_theta)
    return q, k, v


def attention(
    p: dict,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S) or (B, S, 3) for mrope
    cfg: ModelConfig,
    ctx: ParallelCtx,
    *,
    window: int | None = None,
    use_kernel: bool = False,
    return_kv: bool = False,
):
    """Self-attention sublayer (pre-norm, residual added by caller)."""
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    q, k, v = _project_qkv(p, h, positions, cfg, ctx)
    # (B, S, H, Dh) -> (B, H, S, Dh)
    qt, kt, vt = (z.transpose(0, 2, 1, 3) for z in (q, k, v))
    if use_kernel:
        o = kops.flash_attention(qt, kt, vt, causal=cfg.causal, window=window)
    elif ctx.attention_impl == "chunked":
        from repro.models.chunked_attention import chunked_attention

        o = chunked_attention(qt, kt, vt, causal=cfg.causal, window=window)
    else:
        o = kref.flash_attention_ref(qt, kt, vt, causal=cfg.causal, window=window)
    b, s = x.shape[0], x.shape[1]
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    o = L.dense(p["wo"], o)
    o = ctx.wsc(o, *([ctx.dp, None, None]))
    if return_kv:
        return o, (kt, vt)  # post-RoPE (B, Hkv, S, Dh)
    return o
