"""Dense FFN sublayers: SwiGLU / GeGLU / GELU-MLP.

TP: hidden dim sharded over ``ctx.tp_axis``; the down projection's output
is constrained back to the activation sharding (GSPMD emits the
reduce-scatter/all-reduce).  The big matmuls can optionally run through
the task-based SUMMA engine (``matmul_strategy="summa"``, see
dist/collective_matmul.py) — the paper's algorithm embedded in the LM.
Block masks registered in ``ctx.weight_block_masks`` flow through each
projection: the shared ``MatmulPlan`` then prunes dead K panels (and,
with the Pallas local kernel, dead per-device blocks) instead of
multiplying masked weights densely.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.context import ParallelCtx
from repro.models import layers as L
from repro.models.config import ModelConfig


def init_ffn(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {
        "norm": L.init_rmsnorm(d),
        "w_up": L.init_dense(k1, d, f, dtype=dtype),
        "w_down": L.init_dense(k2, f, d, dtype=dtype),
    }
    if cfg.activation in ("swiglu", "geglu"):
        p["w_gate"] = L.init_dense(k3, d, f, dtype=dtype)
    return p


def ffn(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ParallelCtx) -> jax.Array:
    from repro.dist.collective_matmul import project

    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    act = L.ACTIVATIONS[cfg.activation]
    # project() resolves ctx.weight_block_masks per weight shape itself.
    up = project(h, p["w_up"]["w"], ctx)
    up = ctx.wsc(up, ctx.dp, None, ctx.tp_axis)
    if "w_gate" in p:
        gate = project(h, p["w_gate"]["w"], ctx)
        gate = ctx.wsc(gate, ctx.dp, None, ctx.tp_axis)
        hidden = act(gate) * up
    else:
        hidden = act(up)
    out = project(hidden, p["w_down"]["w"], ctx)
    return ctx.wsc(out, ctx.dp, None, None)
