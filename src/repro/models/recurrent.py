"""Recurrent blocks: RG-LRU (RecurrentGemma), mLSTM and sLSTM (xLSTM).

Training uses parallel forms: the RG-LRU diagonal linear recurrence is a
``lax.associative_scan``; the mLSTM matrix memory uses the stabilized
quadratic (attention-like) parallel form from the xLSTM paper; the sLSTM
is an inherently sequential ``lax.scan`` (it has recurrent nonlinearity).

Each block also exposes a single-token ``*_step`` used by the serving
path — recurrent state is O(1) in sequence length, which is what makes
the ``long_500k`` decode cell feasible for these architectures.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.context import ParallelCtx
from repro.models import layers as L
from repro.models.config import ModelConfig

_RGLRU_C = 8.0


# =========================== RG-LRU block ===================================


def init_rglru_block(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    dr = d  # lru width = d_model
    ks = jax.random.split(rng, 6)
    # Λ init so that a = exp(-c softplus(Λ)) spreads over (0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, dr)) / _RGLRU_C))
    return {
        "norm": L.init_rmsnorm(d),
        "w_x": L.init_dense(ks[0], d, dr, dtype=dtype),
        "w_gate": L.init_dense(ks[1], d, dr, dtype=dtype),
        "conv_w": (jax.random.normal(ks[2], (4, dr), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_input_gate": L.init_dense(ks[3], dr, dr, dtype=dtype),
        "w_rec_gate": L.init_dense(ks[4], dr, dr, dtype=dtype),
        "lambda": lam.astype(jnp.float32),
        "w_out": L.init_dense(ks[5], dr, d, dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along S; x (B, S, C), w (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b


def _rglru_gates(p, u):
    """Gate computations shared by scan and step paths; u (..., Dr)."""
    r = jax.nn.sigmoid(L.dense(p["w_rec_gate"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(L.dense(p["w_input_gate"], u).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lambda"]) * r  # (..., Dr) fp32
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * u.astype(jnp.float32)
    )
    return a, gated_in


def rglru_block(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    *,
    return_state: bool = False,
):
    """(B, S, D) -> (B, S, D) recurrent sublayer (residual by caller)."""
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    u = L.dense(p["w_x"], h)
    u_pre = u
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, b = _rglru_gates(p, u)

    # y_t = a_t * y_{t-1} + b_t  via associative scan over S
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, y = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(L.dense(p["w_gate"], h).astype(jnp.float32))
    out = L.dense(p["w_out"], (y * gate).astype(x.dtype))
    out = ctx.wsc(out, ctx.dp, None, None)
    if return_state:
        s = x.shape[1]
        pad = jnp.zeros((x.shape[0], max(0, 3 - s), u_pre.shape[-1]), jnp.float32)
        hist = jnp.concatenate(
            [pad, u_pre[:, max(0, s - 3) :, :].astype(jnp.float32)], axis=1
        )
        state = {"h": y[:, -1, :], "conv": hist}
        return out, state
    return out


def rglru_init_state(p: dict, batch: int) -> dict:
    dr = p["lambda"].shape[0]
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, 3, dr), jnp.float32),  # last K-1 inputs
    }


def rglru_step(p: dict, x_t: jax.Array, state: dict, cfg: ModelConfig):
    """x_t (B, D) one token; returns (y_t, new_state)."""
    h = L.rmsnorm(p["norm"], x_t, cfg.norm_eps)
    u = L.dense(p["w_x"], h)
    hist = jnp.concatenate([state["conv"], u[:, None, :].astype(jnp.float32)], 1)
    w = p["conv_w"].astype(jnp.float32)
    u_c = (hist * w[None]).sum(1) + p["conv_b"].astype(jnp.float32)
    u_c = u_c.astype(u.dtype)
    a, b = _rglru_gates(p, u_c)
    y = a * state["h"] + b
    gate = jax.nn.gelu(L.dense(p["w_gate"], h).astype(jnp.float32))
    out = L.dense(p["w_out"], (y * gate).astype(x_t.dtype))
    return out, {"h": y, "conv": hist[:, 1:, :]}


# ============================== mLSTM block =================================


def init_mlstm_block(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    di = 2 * d  # inner expansion 2x (xLSTM-1.3b default)
    ks = jax.random.split(rng, 7)
    return {
        "norm": L.init_rmsnorm(d),
        "w_in": L.init_dense(ks[0], d, 2 * di, dtype=dtype),  # x_m and gate z
        "conv_w": (jax.random.normal(ks[1], (4, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_q": L.init_dense(ks[2], di, di, dtype=dtype),
        "w_k": L.init_dense(ks[3], di, di, dtype=dtype),
        "w_v": L.init_dense(ks[4], di, di, dtype=dtype),
        "w_if": L.init_dense(ks[5], di, 2 * cfg.num_heads, dtype=dtype),
        "head_norm": L.init_rmsnorm(di // cfg.num_heads),
        "w_out": L.init_dense(ks[6], di, d, dtype=dtype),
    }


def _mlstm_core_chunked(q, k, v, i_pre, f_pre, chunk: int):
    """Chunkwise-parallel mLSTM: O(S·C) D-matrices instead of O(S²).

    Within each chunk the stabilized quadratic form runs as usual; across
    chunks the matrix memory (C, n, m) is carried recurrently (the same
    closed-form state the serving path uses).  Exact match to the parallel
    form up to fp rounding; the memory roofline term drops by ~S/C.
    """
    b, h, s, dh = q.shape
    if s % chunk:
        return _mlstm_core(q, k, v, i_pre, f_pre)
    n_chunks = s // chunk
    qf = q.astype(jnp.float32).reshape(b, h, n_chunks, chunk, dh)
    kf = k.astype(jnp.float32).reshape(b, h, n_chunks, chunk, dh)
    vf = v.astype(jnp.float32).reshape(b, h, n_chunks, chunk, dh)
    i_c = i_pre.astype(jnp.float32).reshape(b, h, n_chunks, chunk)
    lf_c = jax.nn.log_sigmoid(f_pre.astype(jnp.float32)).reshape(
        b, h, n_chunks, chunk
    )
    scale = 1.0 / math.sqrt(dh)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, xs):
        c_st, n_st, m_in = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qc, kc, vc, ic, lfc = xs  # (B,H,C,dh) etc.
        cum_f = jnp.cumsum(lfc, axis=-1)  # inclusive F_t
        # intra-chunk pairwise weights
        dmat = cum_f[..., :, None] - cum_f[..., None, :] + ic[..., None, :]
        dmat = jnp.where(causal, dmat, -jnp.inf)
        inter = cum_f + m_in[..., None]  # (B,H,C): weight of carried state
        m_t = jnp.maximum(jnp.max(dmat, axis=-1), inter)
        w_intra = jnp.exp(dmat - m_t[..., None])  # (B,H,C,C)
        w_inter = jnp.exp(inter - m_t)  # (B,H,C)
        scores = jnp.einsum("bhtd,bhsd->bhts", qc * scale, kc)
        sw = scores * w_intra
        num = jnp.einsum("bhts,bhsd->bhtd", sw, vc)
        num = num + w_inter[..., None] * jnp.einsum(
            "bhtd,bhde->bhte", qc * scale, c_st
        )
        den = sw.sum(-1) + w_inter * jnp.einsum("bhtd,bhd->bht", qc * scale, n_st)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h_out = num / den[..., None]
        # end-of-chunk state update
        f_total = cum_f[..., -1]  # (B,H)
        rel = f_total[..., None] - cum_f + ic  # (B,H,C)
        m_out = jnp.maximum(f_total + m_in, jnp.max(rel, axis=-1))
        w_st = jnp.exp(rel - m_out[..., None])
        decay = jnp.exp(f_total + m_in - m_out)
        c_new = decay[..., None, None] * c_st + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w_st, kc, vc
        )
        n_new = decay[..., None] * n_st + jnp.einsum("bhs,bhsd->bhd", w_st, kc)
        return (c_new, n_new, m_out), h_out

    init = (
        jnp.zeros((b, h, dh, dh), jnp.float32),
        jnp.zeros((b, h, dh), jnp.float32),
        jnp.full((b, h), -1e30, jnp.float32),
    )
    xs = (
        qf.transpose(2, 0, 1, 3, 4),
        kf.transpose(2, 0, 1, 3, 4),
        vf.transpose(2, 0, 1, 3, 4),
        i_c.transpose(2, 0, 1, 3),
        lf_c.transpose(2, 0, 1, 3),
    )
    _, hs = jax.lax.scan(body, init, xs)  # (n_chunks, B, H, C, dh)
    return hs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, dh)


def _mlstm_core(q, k, v, i_pre, f_pre):
    """Stabilized parallel mLSTM; q/k/v (B, H, S, dh); gates (B, H, S)."""
    b, h, s, dh = q.shape
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))  # (B,H,S)
    cum_f = jnp.cumsum(log_f, axis=-1)
    # D[t, s] = cumF_t - cumF_s + i_s  for s <= t
    dmat = cum_f[..., :, None] - cum_f[..., None, :] + i_pre.astype(jnp.float32)[..., None, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(causal, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=-1, keepdims=True)  # (B,H,S,1)
    w = jnp.exp(dmat - m)
    scores = jnp.einsum(
        "bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(dh)
    sw = scores * w
    norm = jnp.maximum(jnp.abs(sw.sum(-1, keepdims=True)), jnp.exp(-m))
    out = jnp.einsum("bhts,bhsd->bhtd", sw / norm, v.astype(jnp.float32))
    return out


def mlstm_block(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    *,
    return_state: bool = False,
):
    b, s, d = x.shape
    nh = cfg.num_heads
    h_in = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    xz = L.dense(p["w_in"], h_in)
    x_m, z = jnp.split(xz, 2, axis=-1)  # (B, S, di) each
    di = x_m.shape[-1]
    dh = di // nh
    x_c = jax.nn.silu(_causal_conv(x_m, p["conv_w"], p["conv_b"]))
    q = L.dense(p["w_q"], x_c).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    k = L.dense(p["w_k"], x_c).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    v = L.dense(p["w_v"], x_m).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    i_f = L.dense(p["w_if"], x_c)  # (B, S, 2H)
    i_pre, f_pre = jnp.split(i_f.transpose(0, 2, 1), 2, axis=1)  # (B,H,S)
    if ctx.mlstm_chunk is not None and s > ctx.mlstm_chunk:
        core = _mlstm_core_chunked(q, k, v, i_pre, f_pre, ctx.mlstm_chunk)
    else:
        core = _mlstm_core(q, k, v, i_pre, f_pre)  # (B,H,S,dh) fp32
    core = L.rmsnorm(p["head_norm"], core.astype(x.dtype), cfg.norm_eps)
    core = core.transpose(0, 2, 1, 3).reshape(b, s, di)
    out = L.dense(p["w_out"], core * jax.nn.silu(z))
    out = ctx.wsc(out, ctx.dp, None, None)
    if return_state:
        # closed-form final state of the recurrence (no sequential scan):
        # m_S = max_s(i_s + F_S - F_s); C = sum_s e^{i_s+F_S-F_s-m_S} k v^T
        log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
        cum_f = jnp.cumsum(log_f, axis=-1)
        rel = cum_f[..., -1:] - cum_f + i_pre.astype(jnp.float32)  # (B,H,S)
        m_state = jnp.max(rel, axis=-1)  # (B,H)
        w = jnp.exp(rel - m_state[..., None])  # (B,H,S)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        c_state = jnp.einsum("bhs,bhsd,bhse->bhde", w, kf, vf)
        n_state = jnp.einsum("bhs,bhsd->bhd", w, kf)
        pad = jnp.zeros((b, max(0, 3 - s), di), jnp.float32)
        hist = jnp.concatenate(
            [pad, x_m[:, max(0, s - 3) :, :].astype(jnp.float32)], axis=1
        )
        state = {"c": c_state, "n": n_state, "m": m_state, "conv": hist}
        return out, state
    return out


def mlstm_init_state(p: dict, cfg: ModelConfig, batch: int) -> dict:
    di = p["w_q"]["w"].shape[1]
    nh = cfg.num_heads
    dh = di // nh
    return {
        "c": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, 3, di), jnp.float32),
    }


def mlstm_step(p: dict, x_t: jax.Array, state: dict, cfg: ModelConfig):
    b, d = x_t.shape
    nh = cfg.num_heads
    h_in = L.rmsnorm(p["norm"], x_t, cfg.norm_eps)
    xz = L.dense(p["w_in"], h_in)
    x_m, z = jnp.split(xz, 2, axis=-1)
    di = x_m.shape[-1]
    dh = di // nh
    hist = jnp.concatenate([state["conv"], x_m[:, None, :].astype(jnp.float32)], 1)
    w = p["conv_w"].astype(jnp.float32)
    x_c = jax.nn.silu((hist * w[None]).sum(1) + p["conv_b"].astype(jnp.float32))
    x_c = x_c.astype(x_m.dtype)
    q = L.dense(p["w_q"], x_c).reshape(b, nh, dh).astype(jnp.float32)
    k = L.dense(p["w_k"], x_c).reshape(b, nh, dh).astype(jnp.float32)
    v = L.dense(p["w_v"], x_m).reshape(b, nh, dh).astype(jnp.float32)
    i_f = L.dense(p["w_if"], x_c).astype(jnp.float32)
    i_pre, f_pre = jnp.split(i_f, 2, axis=-1)  # (B, H)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    i_s = jnp.exp(i_pre - m_new)
    c = f_s[..., None, None] * state["c"] + i_s[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = f_s[..., None] * state["n"] + i_s[..., None] * k
    qn = q / math.sqrt(dh)
    num = jnp.einsum("bhd,bhde->bhe", qn, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qn, n)), jnp.exp(-m_new))
    core = num / den[..., None]
    core = L.rmsnorm(p["head_norm"], core.astype(x_t.dtype), cfg.norm_eps)
    out = L.dense(p["w_out"], core.reshape(b, di) * jax.nn.silu(z))
    new_state = {"c": c, "n": n, "m": m_new, "conv": hist[:, 1:, :]}
    return out, new_state


# ============================== sLSTM block =================================


def init_slstm_block(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    ks = jax.random.split(rng, 4)
    std = 1.0 / math.sqrt(d)
    return {
        "norm": L.init_rmsnorm(d),
        # 4 gates (i, f, z, o) from input
        "w_gates": L.init_dense(ks[0], d, 4 * d, dtype=dtype),
        # block-diagonal recurrent weights per head, per gate
        "r_gates": (
            jax.random.normal(ks[1], (4, nh, dh, dh), jnp.float32) * std
        ).astype(dtype),
        "head_norm": L.init_rmsnorm(dh),
        # 4/3 expansion rounded up to 128 so TP/FSDP sharding divides evenly
        "w_up": L.init_dense(ks[2], d, _slstm_ff(d), dtype=dtype),
        "w_down": L.init_dense(ks[3], _slstm_ff(d), d, dtype=dtype),
    }


def _slstm_ff(d: int) -> int:
    return max(128, -(-(4 * d // 3) // 128) * 128)


def slstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(p, gates_x_t, state, nh):
    """One sLSTM time step; gates_x_t (B, 4D) precomputed input part."""
    b = gates_x_t.shape[0]
    d = gates_x_t.shape[-1] // 4
    dh = d // nh
    h_prev = state["h"].reshape(b, nh, dh)
    rec = jnp.einsum(
        "bhd,ghde->gbhe", h_prev.astype(jnp.float32), p["r_gates"].astype(jnp.float32)
    ).reshape(4, b, d)
    gx = gates_x_t.astype(jnp.float32).reshape(b, 4, d).transpose(1, 0, 2)
    i_pre, f_pre, z_pre, o_pre = (gx[g] + rec[g] for g in range(4))
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c = f_s * state["c"] + i_s * z
    n = f_s * state["n"] + i_s
    h = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h}


def slstm_block(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    *,
    return_state: bool = False,
):
    b, s, d = x.shape
    nh = cfg.num_heads
    h_in = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    gates_x = L.dense(p["w_gates"], h_in)  # (B, S, 4D)
    if ctx.slstm_replicated:
        # keep the whole recurrence TP-replicated: one all-gather here
        # instead of per-timestep collectives inside the scan
        gates_x = ctx.wsc(gates_x, ctx.dp, None, None)

    def step(state, g_t):
        new = _slstm_cell(p, g_t, state, nh)
        return new, new["h"]

    state0 = slstm_init_state(cfg, b)
    final_state, hs = jax.lax.scan(step, state0, gates_x.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2)  # (B, S, D)
    hs = L.rmsnorm(
        p["head_norm"], hs.reshape(b, s, nh, d // nh).astype(x.dtype), cfg.norm_eps
    ).reshape(b, s, d)
    # small post-FFN (4/3 expansion, xLSTM style)
    up = jax.nn.gelu(L.dense(p["w_up"], hs))
    out = L.dense(p["w_down"], up)
    out = ctx.wsc(out, ctx.dp, None, None)
    if return_state:
        return out, final_state
    return out


def slstm_step(p: dict, x_t: jax.Array, state: dict, cfg: ModelConfig):
    b, d = x_t.shape
    nh = cfg.num_heads
    h_in = L.rmsnorm(p["norm"], x_t, cfg.norm_eps)
    g_t = L.dense(p["w_gates"], h_in)
    new = _slstm_cell(p, g_t, state, nh)
    hs = L.rmsnorm(
        p["head_norm"], new["h"].reshape(b, nh, d // nh).astype(x_t.dtype), cfg.norm_eps
    ).reshape(b, d)
    up = jax.nn.gelu(L.dense(p["w_up"], hs))
    out = L.dense(p["w_down"], up)
    return out, new
