"""Model stack: configs, layers, blocks, and the assembled LM."""
from repro.models.config import ModelConfig, MoEConfig, ShapeConfig, SHAPES
from repro.models.model import forward, init_model, loss_fn
