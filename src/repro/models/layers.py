"""Primitive layers: norms, dense projections, embeddings, RoPE/M-RoPE.

Parameters are plain nested dicts of jax arrays (pytree-native, no
framework dependency); ``init_*`` functions build them, ``*_apply``
functions consume them.  Sharding is attached externally by path-based
rules (dist/partitioning.py), keeping model code mesh-agnostic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Params = dict


def _dtype(cfg_dtype: str):
    return jnp.dtype(cfg_dtype)


# -- norms -------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"] + p["bias"]).astype(x.dtype)


# -- dense -------------------------------------------------------------------


def init_dense(
    rng, in_dim: int, out_dim: int, *, bias: bool = False, dtype=jnp.bfloat16
) -> Params:
    std = 1.0 / np.sqrt(in_dim)
    p = {"w": (jax.random.normal(rng, (in_dim, out_dim), jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


# -- embeddings --------------------------------------------------------------


def init_embedding(rng, vocab: int, d: int, dtype=jnp.bfloat16) -> Params:
    emb = jax.random.normal(rng, (vocab, d), jnp.float32).astype(dtype)
    return {"embedding": emb}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Tied LM head: logits in fp32 for a stable softmax/CE."""
    return jnp.einsum(
        "...d,vd->...v", x, p["embedding"], preferred_element_type=jnp.float32
    )


# -- rotary embeddings -------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jax.Array,  # (B, S, H, Dh)
    positions: jax.Array,  # (B, S)
    theta: float = 10_000.0,
) -> jax.Array:
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# M-RoPE (Qwen2-VL): the rotary frequency bands are partitioned into three
# sections (temporal, height, width); each section rotates by its own
# position stream.  Text tokens carry identical positions in all three
# streams, so M-RoPE degenerates to RoPE for text.
MROPE_SECTIONS = (0.25, 0.375, 0.375)  # fractions of Dh/2 per (t, h, w)


def apply_mrope(
    x: jax.Array,  # (B, S, H, Dh)
    positions: jax.Array,  # (B, S, 3) -> (t, h, w) position per token
    theta: float = 1_000_000.0,
) -> jax.Array:
    dh = x.shape[-1]
    half = dh // 2
    freqs = rope_frequencies(dh, theta)  # (half,)
    n_t = int(half * MROPE_SECTIONS[0])
    n_h = int(half * MROPE_SECTIONS[1])
    section = np.zeros(half, dtype=np.int32)
    section[n_t : n_t + n_h] = 1
    section[n_t + n_h :] = 2
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.asarray(section)[None, None, :].repeat(positions.shape[0], 0)
        .repeat(positions.shape[1], 1),
        axis=-1,
    )  # (B, S, half): per-band position choice
    angles = pos * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- misc --------------------------------------------------------------------


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {
    "swiglu": jax.nn.silu,
    "geglu": gelu,
    "gelu": gelu,
}
