"""Model configuration covering every assigned architecture family.

One ``ModelConfig`` describes dense GQA transformers, MoE transformers,
RG-LRU hybrids (recurrentgemma), xLSTM stacks, encoder-only audio
backbones, and VLM backbones.  Layer stacks are expressed as a repeating
``block_pattern`` unit (scanned) plus an optional unrolled tail, which is
how heterogeneous stacks (e.g. recurrentgemma's recurrent/recurrent/attn
pattern) stay scan-compatible.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "rglru", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden dim
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # None -> d_model // num_heads
    activation: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    qkv_bias: bool = False
    rope: Literal["rope", "mrope", "none"] = "rope"
    rope_theta: float = 10_000.0
    causal: bool = True  # False for encoder-only
    window: int | None = None  # sliding-window size for attn blocks
    # Repeating layer-stack unit; e.g. ("rglru","rglru","attn").  The stack
    # is ceil-divided: full units are scanned, the remainder is a tail of
    # the unit's prefix, unrolled.
    block_pattern: tuple[BlockKind, ...] = ("attn",)
    moe: MoEConfig | None = None
    # Embedding-free input (audio/vlm stubs feed precomputed embeddings).
    embed_inputs: bool = True
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # Serving / long-context
    subquadratic: bool = False  # True if decode state is O(1) or windowed
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def units(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def tail(self) -> tuple[BlockKind, ...]:
        rem = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND math."""
        d, hd = self.d_model, self.resolved_head_dim
        h, hkv = self.num_heads, self.num_kv_heads
        attn = d * (h * hd) + 2 * d * (hkv * hd) + (h * hd) * d
        if self.activation in ("swiglu", "geglu"):
            ffn = 3 * self.d_model * self.d_ff
        else:
            ffn = 2 * self.d_model * self.d_ff
        per_kind = {}
        per_kind["attn"] = attn + (ffn if self.d_ff else 0)
        # recurrent blocks: in/out proj + conv + gates (approx; see models)
        per_kind["rglru"] = 2 * d * d + 4 * d + 3 * d * d // 1
        per_kind["mlstm"] = int(4.5 * d * d)
        per_kind["slstm"] = int(4.5 * d * d)
        if self.moe is not None:
            experts = (
                self.moe.num_experts + self.moe.num_shared_experts
            ) * 3 * d * self.moe.d_ff
            router = d * self.moe.num_experts
            per_kind["attn"] = attn + experts + router
        total = 0
        for i in range(self.num_layers):
            kind = self.block_pattern[i % len(self.block_pattern)]
            total += per_kind[kind]
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only routed experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        all_experts = (
            self.moe.num_experts + self.moe.num_shared_experts
        ) * 3 * d * self.moe.d_ff
        active_experts = (
            self.moe.top_k + self.moe.num_shared_experts
        ) * 3 * d * self.moe.d_ff
        n_moe_layers = sum(
            1
            for i in range(self.num_layers)
            if self.block_pattern[i % len(self.block_pattern)] == "attn"
        )
        return self.param_count() - n_moe_layers * (all_experts - active_experts)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
