"""The assembled LM: heterogeneous block stacks, scan-over-units, losses.

A model is ``embed -> [scan over repeating block-pattern units] -> tail
-> norm -> head``.  Heterogeneous stacks (RecurrentGemma's
(rglru, rglru, attn), xLSTM's (mlstm×7, slstm)) scan over *macro-units*
so the whole depth stays a single rolled loop: compile time and HLO size
are O(unit), not O(layers), which is what makes 80-layer × 512-device
dry-runs tractable.  Remat (`jax.checkpoint`) wraps each unit.

Inputs are a dict: ``tokens`` (B, S) int32 and/or ``embeds`` (B, S, D)
(modality-frontend stubs for audio/VLM), ``positions`` (B, S) or
(B, S, 3) for M-RoPE.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.context import ParallelCtx
from repro.models import layers as L
from repro.models.attention import attention, init_attention
from repro.models.config import ModelConfig
from repro.models.ffn import ffn, init_ffn
from repro.models.moe import init_moe, moe_ffn
from repro.models.recurrent import (
    init_mlstm_block,
    init_rglru_block,
    init_slstm_block,
    mlstm_block,
    rglru_block,
    slstm_block,
)

Params = Any


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_block(rng, kind: str, cfg: ModelConfig, ctx: ParallelCtx, dtype) -> Params:
    if kind == "attn":
        k1, k2 = jax.random.split(rng)
        p = {"attn": init_attention(k1, cfg, dtype)}
        if cfg.moe is not None:
            p["moe"] = init_moe(k2, cfg, ctx, dtype)
        elif cfg.d_ff:
            p["ffn"] = init_ffn(k2, cfg, dtype)
        return p
    if kind == "rglru":
        k1, k2 = jax.random.split(rng)
        return {"rec": init_rglru_block(k1, cfg, dtype), "ffn": init_ffn(k2, cfg, dtype)}
    if kind == "mlstm":
        return {"rec": init_mlstm_block(rng, cfg, dtype)}
    if kind == "slstm":
        return {"rec": init_slstm_block(rng, cfg, dtype)}
    raise ValueError(kind)


def init_model(rng, cfg: ModelConfig, ctx: ParallelCtx) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    n_units = cfg.units
    pattern = cfg.block_pattern
    keys = jax.random.split(rng, 4)

    def init_unit(unit_rng):
        ks = jax.random.split(unit_rng, len(pattern))
        return {
            f"b{j}": _init_block(ks[j], kind, cfg, ctx, dtype)
            for j, kind in enumerate(pattern)
        }

    unit_rngs = jax.random.split(keys[0], n_units)
    units = jax.vmap(init_unit)(unit_rngs)  # leaves stacked on axis 0

    tail_rngs = jax.random.split(keys[1], max(len(cfg.tail), 1))
    tail = [
        _init_block(tail_rngs[j], kind, cfg, ctx, dtype)
        for j, kind in enumerate(cfg.tail)
    ]

    params: Params = {"units": units, "tail": tail, "final_norm": L.init_rmsnorm(cfg.d_model)}
    if cfg.embed_inputs:
        params["embed"] = L.init_embedding(keys[2], cfg.vocab_size, cfg.d_model, dtype)
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        params["head"] = L.init_dense(keys[3], cfg.d_model, cfg.vocab_size, dtype=dtype)
    return params


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def apply_block(
    kind: str,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    *,
    use_kernel: bool = False,
):
    """Residual application of one block; returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        x = x + attention(
            p["attn"], x, positions, cfg, ctx, window=cfg.window, use_kernel=use_kernel
        )
        if "moe" in p:
            y, aux = moe_ffn(p["moe"], x, cfg, ctx)
            x = x + y
        elif "ffn" in p:
            x = x + ffn(p["ffn"], x, cfg, ctx)
    elif kind == "rglru":
        x = x + rglru_block(p["rec"], x, cfg, ctx)
        x = x + ffn(p["ffn"], x, cfg, ctx)
    elif kind == "mlstm":
        x = x + mlstm_block(p["rec"], x, cfg, ctx)
    elif kind == "slstm":
        x = x + slstm_block(p["rec"], x, cfg, ctx)
    else:
        raise ValueError(kind)
    return x, aux


def embed_inputs(params: Params, inputs: dict, cfg: ModelConfig) -> jax.Array:
    parts = []
    if "embeds" in inputs and inputs["embeds"] is not None:
        parts.append(inputs["embeds"])
    if cfg.embed_inputs and "tokens" in inputs and inputs["tokens"] is not None:
        parts.append(L.embed(params["embed"], inputs["tokens"]))
    if not parts:
        raise ValueError("inputs must contain 'tokens' and/or 'embeds'")
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return x


def forward(
    params: Params,
    inputs: dict,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    *,
    use_kernel: bool = False,
    remat: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V) fp32, aux_loss scalar)."""
    x = embed_inputs(params, inputs, cfg)
    x = ctx.wsc(x, ctx.dp, None, None)
    positions = inputs.get("positions")
    if positions is None:
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def unit_fn(carry, unit_params):
        x, aux = carry
        for j, kind in enumerate(cfg.block_pattern):
            x, a = apply_block(
                kind, unit_params[f"b{j}"], x, positions, cfg, ctx,
                use_kernel=use_kernel,
            )
            aux = aux + a
        return (x, aux), None

    if remat:
        unit_fn = jax.checkpoint(unit_fn)

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.units > 0:
        (x, aux), _ = jax.lax.scan(unit_fn, (x, aux0), params["units"])
    else:
        aux = aux0
    for j, kind in enumerate(cfg.tail):
        x, a = apply_block(
            kind, params["tail"][j], x, positions, cfg, ctx, use_kernel=use_kernel
        )
        aux = aux + a

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if "head" in params:
        logits = L.dense(params["head"], x).astype(jnp.float32)
    else:
        logits = L.unembed(params["embed"], x)
    logits = ctx.wsc(logits, ctx.dp, None, ctx.tp_axis)
    return logits, aux


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

AUX_LOSS_COEF = 0.01
Z_LOSS_COEF = 1e-4


def loss_fn(
    params: Params,
    batch: dict,
    cfg: ModelConfig,
    ctx: ParallelCtx,
    *,
    remat: bool = True,
) -> tuple[jax.Array, dict]:
    """Cross-entropy (+ MoE aux + z-loss).  batch must contain 'labels'."""
    logits, aux = forward(params, batch, cfg, ctx, remat=remat)
    labels = batch["labels"]
    # Align: logits over the full stream; labels may cover the token tail
    # only (VLM: vision prefix has no labels).
    s_lab = labels.shape[1]
    logits = logits[:, -s_lab:, :]
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = ((logz - ll) * mask).sum() / denom
    z_loss = Z_LOSS_COEF * ((logz * mask) ** 2).sum() / denom
    total = ce + z_loss + AUX_LOSS_COEF * aux
    metrics = {"ce": ce, "z_loss": z_loss, "aux": aux, "loss": total}
    return total, metrics
