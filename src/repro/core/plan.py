"""MatmulPlan: one sparsity-aware execution plan for every matmul path.

The paper's central claim is that a single task formulation absorbs
dense, block-sparse, and nonuniformly blocked matrices without separate
algorithms.  ``MatmulPlan`` is that formulation made static: given
operand shapes, optional block masks, and a ``SummaConfig`` it
precomputes — once, in numpy, outside any trace —

* padded, grid- and block-aligned physical shapes;
* the K-panel schedule (panel width, owners, over-decomposition);
* **global panel liveness** (panels dead for every device: neither their
  broadcast nor their rank-k update is emitted — today's trace-time
  pruning) and **per-device panel liveness** (panels dead *for that grid
  row/column*, strictly finer on structured masks);
* per-device ``BlockCSR`` column maps feeding the Pallas scalar-prefetch
  BSMM kernel, so surviving panels still skip dead blocks locally;
* a cost model (modeled per-device collective bytes for every strategy,
  dense/sparse FLOPs, fill-in) that upper layers use to pick a strategy.

``core.summa.execute_plan`` interprets a plan inside ``shard_map``;
``core.api.DistributedMatmul`` / ``NonuniformMatmul`` are thin
front-ends that build (and cache) plans; ``dist.collective_matmul``
consults the cost model for strategy auto-selection.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math

import numpy as np

from repro.core.sparsity import BlockRankMap, mask_matmul_flops
from repro.core.summa import SummaConfig, resolve_multi_issue

__all__ = ["MatmulPlan", "PlanCost", "plan_matmul", "mask_key", "rank_key"]


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def mask_key(mask: np.ndarray | None) -> tuple | None:
    """Stable, cheap cache key for a block mask (shape + content digest)."""
    if mask is None:
        return None
    mask = np.asarray(mask, dtype=bool)
    return (mask.shape, hashlib.sha1(mask.tobytes()).hexdigest())


def rank_key(ranks) -> tuple | None:
    """Stable cache key for a rank structure (``BlockRankMap`` or
    ``RankCSR``): block grid + extents + per-block-rank content digest.
    Factor *values* are intentionally not keyed — the plan depends only on
    the static structure (``DistributedMatmul`` documents this)."""
    if ranks is None:
        return None
    rank_map = ranks.rank_map() if hasattr(ranks, "rank_map") else ranks
    arr = np.ascontiguousarray(rank_map.ranks, dtype=np.int32)
    return (
        arr.shape,
        rank_map.bm,
        rank_map.bk,
        hashlib.sha1(arr.tobytes()).hexdigest(),
    )


@dataclasses.dataclass(frozen=True, eq=False)
class PlanCost:
    """Static cost estimates attached to a plan (modeled, per device)."""

    flops_dense: float  # global useful FLOPs of the dense product
    flops_sparse: float  # global FLOPs given masks AND ranks (== dense if none)
    comm_bytes: dict  # strategy -> modeled per-device collective bytes
    fill_in: float  # flops_sparse / flops_dense
    # Mask-only accounting of the same structure (every live block charged
    # its dense area).  Equals ``flops_sparse`` unless the plan carries
    # per-block ranks, where the gap is exactly what rank-sparsity buys.
    flops_mask: float | None = None

    def best_strategy(self, candidates: tuple[str, ...]) -> str:
        known = [c for c in candidates if c in self.comm_bytes]
        if not known:
            raise ValueError(f"no known strategy among {candidates}")
        return min(known, key=lambda c: self.comm_bytes[c])


@dataclasses.dataclass(frozen=True, eq=False)
class MatmulPlan:
    """The full static schedule of one distributed (block-sparse) matmul.

    All index math is resolved here; the executors in ``core.summa`` only
    interpret it.  ``local_impl`` selects the local rank-k realisation:

    * ``"dense"``  — no masks; strategy pipelines run dense panel dots.
    * ``"masked"`` — masks present; globally-live panels unroll into a
      static task DAG with masked operands (the pre-plan behaviour, and
      the fallback when the BSMM alignment conditions fail).
    * ``"bsmm"``   — masks present and ``local_matmul="pallas"``: live
      panels are gathered once, then the Pallas scalar-prefetch kernel
      consumes this device's CSR column map — local FLOPs scale with the
      *per-device* fill-in, not the global one.
    """

    cfg: SummaConfig
    m: int
    k: int
    n: int
    m_pad: int
    k_pad: int
    n_pad: int
    k_steps: int
    kb_width: int
    live_panels: tuple[int, ...]
    a_mask: np.ndarray | None  # padded (M_blk, K_blk) block mask
    b_mask: np.ndarray | None  # padded (K_blk, N_blk) block mask
    device_live: np.ndarray | None  # (p_row, p_col, k_steps) bool
    local_cols: np.ndarray | None  # (p_row, p_col, mb_loc, S) int32, -1 pad
    local_block: tuple[int, int, int] | None  # (bm, bk, bn) for the kernel
    local_impl: str  # "dense" | "masked" | "bsmm" | "ranksparse"
    cost: PlanCost
    itemsize: int
    # Padded (M_blk, K_blk) int32 per-block ranks of A (block-rank
    # sparsity); None unless planned with ``a_ranks=``.  ``a_mask`` is then
    # ``a_ranks > 0`` and ``local_impl == "ranksparse"`` when the factor
    # layout fits the grid (``execute_rank_plan`` consumes the factors;
    # dense-stored execution of the same plan runs the masked DAG).
    a_ranks: np.ndarray | None = None
    # Per-plan multiple-issue window (paper Eq. 1).  ``None`` defers to
    # ``cfg.resolve_lookahead``; the schedule autotuner (repro.sched.tuner)
    # sets it, and ``core.summa._exec_taskbased`` honors it.
    lookahead: int | None = None
    # Search record attached by ``repro.sched.tuner.tune_plan`` (winning
    # strategy/k_blocks/lookahead, simulated makespan, static baseline).
    tuned: dict | None = None
    # -- SpGEMM extensions (repro.spgemm) ------------------------------------
    # Padded (K_blk, N_blk) int32 per-block ranks of B.  Structure-only
    # planning input: B stays dense-stored (``b_mask`` is ``b_ranks > 0``),
    # the ranks refine modeled broadcast volume and the stationarity choice.
    b_ranks: np.ndarray | None = None
    # Padded (M_blk, N_blk) output block mask.  When set, gemm tasks whose
    # C block is dead are pruned from ``device_live`` and execution zeroes
    # the dead output blocks (the mask is an output *filter*).
    c_mask: np.ndarray | None = None
    # Panel transport: "broadcast" (panel broadcast along grid rows/cols,
    # today's pipeline) or "pull" (one-sided fetch of exactly the panels
    # this device's surviving gemms read — RDMA-SpGEMM style; fetch tasks
    # contend on the owner's clock in the simulator).
    comm_mode: str = "broadcast"
    # Which operand stays put: "C" (today's SUMMA layout), or "A"/"B"
    # (transposed layouts with a final C reduce-scatter — DBCSR-style;
    # ``repro.spgemm.stationarity`` chooses under ``stationarity="auto"``).
    stationarity: str = "C"
    # -- Norm-filter extensions (DBCSR-style on-the-fly filtering) -----------
    # Product-screening threshold this plan was built with: gemm tasks whose
    # ``||A_ik||_F * ||B_kj||_F`` bound fell below it were removed from the
    # masks / device liveness above, so the filtered structure bytes are what
    # the digest (and therefore the executable cache) sees.  0.0 = off, and
    # an eps-0 plan is bitwise identical to one planned without norms.
    filter_eps: float = 0.0
    # Additive Frobenius-norm error bound on C: the sum of every screened
    # product ``||A_ik||_F * ||B_kj||_F``.  Execution granularity is
    # panel-wise, so the measured error is <= this bound (a triple screened
    # at plan level may still ride along in a panel that survives for
    # other outputs — the bound never understates).
    filter_bound: float = 0.0
    # Propagated per-block output norm *bounds* (M_blk, N_blk float64) when
    # the plan was given operand norms: ``sum_k ||A_ik|| ||B_kj||`` over the
    # surviving triples.  Derived metadata (not digested) — chains feed it
    # forward as the next product's operand norms so iterative C <- A.B
    # gets progressively sparser.
    c_norms: np.ndarray | None = None

    # -- geometry -----------------------------------------------------------

    @property
    def p_row(self) -> int:
        return self.cfg.p_row

    @property
    def p_col(self) -> int:
        return self.cfg.p_col

    @property
    def padded_shapes(self) -> tuple[tuple[int, int], tuple[int, int]]:
        return (self.m_pad, self.k_pad), (self.k_pad, self.n_pad)

    def resolve_lookahead(self, k_steps: int | None = None) -> int:
        """The multiple-issue window executed for this plan: the tuned
        per-plan value when set, else the config's Eq.-(1) resolution."""
        if k_steps is None:
            k_steps = self.k_steps
        if self.lookahead is not None:
            return resolve_multi_issue(
                self.p_row, self.p_col, k_steps, self.lookahead
            )
        return self.cfg.resolve_lookahead(k_steps)

    # -- pruning accounting --------------------------------------------------

    @property
    def skipped_panels_global(self) -> int:
        """Panels pruned for the whole mesh (no broadcast emitted)."""
        return self.k_steps - len(self.live_panels)

    def skipped_panels_per_device(self) -> np.ndarray:
        """(p_row, p_col) int — panels dead for each device's C tile.

        Always >= ``skipped_panels_global`` elementwise; strictly greater
        wherever the mask structure is non-global (e.g. banded masks on a
        multi-row grid) — the finer pruning the planner feeds the local
        BSMM kernel.
        """
        if self.device_live is None:
            return np.zeros((self.p_row, self.p_col), dtype=np.int64)
        return self.k_steps - self.device_live.sum(axis=2)

    def digest(self) -> str:
        """Stable content hash of every execution-relevant static field.

        This is the executable-cache key (``core.summa``): two plans with
        the same digest trace to the *same* jitted program — mesh devices,
        grid axes, strategy, padded geometry, panel schedule, masks, rank
        structure, local implementation and the resolved multiple-issue
        window are all folded in.  Factor *values* of rank payloads are
        deliberately absent (they are runtime operands, mirroring
        ``rank_key``).  Memoized on the instance.
        """
        cached = self.__dict__.get("_digest")
        if cached is not None:
            return cached
        cfg = self.cfg
        devices = getattr(cfg.mesh, "devices", None)
        if devices is None:  # plan-only fake meshes (never executed)
            mesh_fp = ("abstract", repr(getattr(cfg.mesh, "shape", None)))
        else:
            darr = np.asarray(devices)
            mesh_fp = (
                darr.shape,
                tuple(int(getattr(d, "id", -1)) for d in darr.ravel()),
                tuple(getattr(cfg.mesh, "axis_names", ())),
            )
        h = hashlib.sha1()
        h.update(
            repr((
                mesh_fp, cfg.row_axis, cfg.col_axis, cfg.strategy,
                cfg.k_blocks, cfg.lookahead,
                np.dtype(cfg.accum_dtype).name, cfg.local_matmul,
                self.m, self.k, self.n, self.m_pad, self.k_pad,
                self.n_pad, self.k_steps, self.kb_width,
                self.live_panels, self.local_impl, self.local_block,
                self.itemsize, self.lookahead, self.resolve_lookahead(),
                self.comm_mode, self.stationarity,
            )).encode()
        )
        for arr in (
            self.a_mask, self.b_mask, self.device_live, self.local_cols,
            self.a_ranks, self.b_ranks, self.c_mask,
        ):
            if arr is None:
                h.update(b"|none")
            else:
                h.update(b"|")
                h.update(np.ascontiguousarray(arr).tobytes())
        digest = h.hexdigest()
        self.__dict__["_digest"] = digest  # frozen: write storage directly
        return digest

    def summary(self) -> dict:
        """JSON-able digest for benchmarks / logging."""
        skipped = self.skipped_panels_per_device()
        return {
            "shape": [self.m, self.k, self.n],
            "padded_shape": [self.m_pad, self.k_pad, self.n_pad],
            "grid": [self.p_row, self.p_col],
            "strategy": self.cfg.strategy,
            "local_impl": self.local_impl,
            "comm_mode": self.comm_mode,
            "stationarity": self.stationarity,
            "k_steps": self.k_steps,
            "kb_width": self.kb_width,
            "live_panels": len(self.live_panels),
            "skipped_global": int(self.skipped_panels_global),
            "skipped_per_device_mean": float(skipped.mean()),
            "skipped_per_device_max": int(skipped.max()),
            "lookahead": self.resolve_lookahead(),
            "tuned": self.tuned,
            "filter_eps": self.filter_eps,
            "filter_bound": self.filter_bound,
            "fill_in": self.cost.fill_in,
            "flops_dense": self.cost.flops_dense,
            "flops_sparse": self.cost.flops_sparse,
            "flops_mask": self.cost.flops_mask,
            "mean_rank": (
                float(self.a_ranks[self.a_ranks > 0].mean())
                if self.a_ranks is not None and (self.a_ranks > 0).any()
                else None
            ),
            "comm_bytes": {
                s: float(v) for s, v in self.cost.comm_bytes.items()
            },
        }


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------


def _panel_liveness(
    a_mask: np.ndarray,
    b_mask: np.ndarray,
    k_steps: int,
    p_row: int,
    p_col: int,
) -> tuple[list[int], np.ndarray, np.ndarray]:
    """Global live panels, per-device liveness, per-grid-column liveness.

    Returns ``(live, device_live, b_col)`` where ``device_live`` is
    (p_row, p_col, k_steps) bool and ``b_col`` is the (p_col, k_steps)
    per-grid-column panel liveness that ``_local_csr_cols`` reuses.
    Per-device refinement is applied on each side only when that side's
    block grid aligns with the device grid (blocks per shard is integral);
    otherwise that side falls back to its global column/row test.
    """
    m_blk, k_blk = a_mask.shape
    _, n_blk = b_mask.shape
    assert k_blk == k_steps
    a_any = a_mask.any(axis=0)  # (K_blk,)
    b_any = b_mask.any(axis=1)
    live = [kk for kk in range(k_steps) if a_any[kk] and b_any[kk]]

    if m_blk % p_row == 0:
        mb_loc = m_blk // p_row
        a_row = np.array(
            [
                a_mask[i * mb_loc : (i + 1) * mb_loc, :].any(axis=0)
                for i in range(p_row)
            ]
        )  # (p_row, K_blk)
    else:
        a_row = np.broadcast_to(a_any, (p_row, k_blk))
    if n_blk % p_col == 0:
        nb_loc = n_blk // p_col
        b_col = np.array(
            [
                b_mask[:, j * nb_loc : (j + 1) * nb_loc].any(axis=1)
                for j in range(p_col)
            ]
        )  # (p_col, K_blk)
    else:
        b_col = np.broadcast_to(b_any, (p_col, k_blk)).copy()
    device_live = a_row[:, None, :] & b_col[None, :, :]
    return live, device_live, b_col


def _local_csr_cols(
    a_mask: np.ndarray,
    b_col: np.ndarray,
    live: list[int],
    p_row: int,
    p_col: int,
) -> np.ndarray:
    """Per-device padded CSR column maps over the *gathered live panels*.

    ``cols[i, j, ib, s]`` is the position (0..L-1) within the gathered
    K-panel axis of the s-th live block for local block row ``ib`` on
    device (i, j), or -1.  A block is live for (i, j, ib) when A's block
    (global row ib, panel) is nonzero and the panel intersects B columns
    owned by grid column j (``b_col`` from ``_panel_liveness``).
    """
    m_blk, _ = a_mask.shape
    mb_loc = m_blk // p_row
    rows: dict[tuple[int, int, int], list[int]] = {}
    s_max = 1
    for i in range(p_row):
        for j in range(p_col):
            for ib in range(mb_loc):
                gb = i * mb_loc + ib
                cols = [
                    pos
                    for pos, kk in enumerate(live)
                    if a_mask[gb, kk] and b_col[j, kk]
                ]
                rows[(i, j, ib)] = cols
                s_max = max(s_max, len(cols))
    out = np.full((p_row, p_col, mb_loc, s_max), -1, dtype=np.int32)
    for (i, j, ib), cols in rows.items():
        out[i, j, ib, : len(cols)] = cols
    return out


def _pick_bn(n_loc: int, pref: int = 256) -> int:
    """Largest divisor of ``n_loc`` not exceeding ``pref``."""
    if n_loc <= pref:
        return n_loc
    for bn in range(pref, 0, -1):
        if n_loc % bn == 0:
            return bn
    return n_loc


def _pad_block_mask(
    mask: np.ndarray, blocks_pad: tuple[int, int]
) -> np.ndarray:
    """Extend a block mask with all-zero pad blocks to the padded grid."""
    rb, cb = mask.shape
    out = np.zeros(blocks_pad, dtype=bool)
    out[:rb, :cb] = mask
    return out


def _comm_model(
    *,
    m_loc: int,
    n_loc: int,
    k_pad: int,
    kb_width: int,
    live: int,
    k_steps: int,
    p_row: int,
    p_col: int,
    itemsize: int,
    a_live_elems: float | None = None,
    b_live_elems: float | None = None,
) -> dict:
    """Modeled per-device collective bytes for each execution strategy.

    Broadcast-as-allreduce (the static-SPMD idiom ``_bcast_panel`` uses)
    costs ~2x the panel bytes of a tree broadcast, and only globally-live
    panels are broadcast — these numbers match what ``_exec_procedural``
    / ``_exec_taskbased`` and both sparse executors actually move.  The
    bulk all-gather (``_exec_allgather``) and the ring collective matmul
    (``dist.collective_matmul.allgather_matmul``) are *sparsity-blind*:
    they move the full remote shards regardless of masks, so their bytes
    are not scaled by liveness (masked plans never execute them — the
    numbers say what switching would cost).

    ``a_live_elems`` overrides the A-side broadcast volume (summed over
    live panels): rank-sparse plans broadcast *factor* panels whose bytes
    follow the per-panel ranks, not the dense panel area.
    ``b_live_elems`` is the B-side mirror: block-sparse B panels move only
    their surviving blocks (mean over grid columns, summed over live
    panels) — same sizing the task graph's ``bcast_b`` tasks use.
    """
    del k_steps  # liveness already folded into `live`
    # psum/all_gather over a size-1 axis moves nothing — gate each
    # operand's term on its broadcast axis actually having peers.
    if a_live_elems is None:
        a_live_elems = float(m_loc * kb_width * live)
    if b_live_elems is None:
        b_live_elems = float(kb_width * n_loc * live)
    bcast = 2.0 * itemsize * (
        a_live_elems * (p_col > 1) + b_live_elems * (p_row > 1)
    )
    allgather = itemsize * (
        m_loc * k_pad * (p_col - 1) / max(p_col, 1)
        + k_pad * n_loc * (p_row - 1) / max(p_row, 1)
    )
    ring = itemsize * (m_loc / max(p_col, 1)) * k_pad * (p_col - 1)
    return {
        "procedural": bcast,
        "taskbased": bcast,
        "allgather": allgather,
        "ring": ring,
    }


def b_panel_live_elems(
    b_mask: np.ndarray | None,
    b_ranks: np.ndarray | None,
    *,
    bk_sz: int,
    bn_sz: int,
    p_col: int,
) -> np.ndarray | None:
    """(k_steps, p_col) surviving B-panel elements per grid column.

    The single sizing both ``PlanCost`` and the task graph's ``bcast_b``
    / ``fetch_b`` tasks use: panel ``kk``'s slab for grid column ``j``
    carries only its live blocks (rank-structured blocks charge their
    factor footprint past nothing — ``min(r (bk + bn), bk bn)``, the
    travel bound ``spgemm.structure.live_elems`` documents).  ``None``
    when the block grid does not align with the device columns (the full
    panel is the only honest answer then).
    """
    if b_mask is None:
        return None
    k_steps, n_blk = b_mask.shape
    if n_blk % p_col:
        return None
    nb_loc = n_blk // p_col
    out = np.zeros((k_steps, p_col))
    for j in range(p_col):
        sl = slice(j * nb_loc, (j + 1) * nb_loc)
        if b_ranks is None:
            out[:, j] = b_mask[:, sl].sum(axis=1) * float(bk_sz * bn_sz)
        else:
            elems = np.minimum(
                b_ranks[:, sl].astype(np.int64) * (bk_sz + bn_sz),
                bk_sz * bn_sz,
            ) * b_mask[:, sl]
            out[:, j] = elems.sum(axis=1).astype(np.float64)
    return out


def _refine_device_live_c(
    device_live: np.ndarray,
    a_mask: np.ndarray,
    b_mask: np.ndarray,
    c_mask: np.ndarray,
    p_row: int,
    p_col: int,
) -> np.ndarray:
    """Output-structure refinement of per-device panel liveness.

    Device (i, j) needs panel ``kk`` only if some addend ``A[mb, kk] @
    B[kk, nb]`` lands in a *live* C block of its tile — the symbolic
    contribution test ``a & b & c``.  Falls back to the input liveness
    when either block grid does not align with the device grid.
    """
    m_blk = a_mask.shape[0]
    n_blk = b_mask.shape[1]
    if m_blk % p_row or n_blk % p_col:
        return device_live
    mb_loc = m_blk // p_row
    nb_loc = n_blk // p_col
    out = device_live.copy()
    a64 = a_mask.astype(np.int64)
    b64 = b_mask.astype(np.int64)
    c64 = c_mask.astype(np.int64)
    for i in range(p_row):
        am_i = a64[i * mb_loc : (i + 1) * mb_loc, :]
        for j in range(p_col):
            bm_j = b64[:, j * nb_loc : (j + 1) * nb_loc]
            cm_ij = c64[
                i * mb_loc : (i + 1) * mb_loc,
                j * nb_loc : (j + 1) * nb_loc,
            ]
            contrib = np.einsum("mk,kn,mn->k", am_i, bm_j, cm_ij)
            out[i, j, :] &= contrib > 0
    return out


def _pull_comm_bytes(
    device_live: np.ndarray,
    live: list[int],
    *,
    k_steps: int,
    m_loc: int,
    kb_width: int,
    n_loc: int,
    p_row: int,
    p_col: int,
    itemsize: int,
    b_live_cols: np.ndarray | None,
    a_fetch_elems: dict[int, float] | None = None,
) -> float:
    """Modeled per-device comm bytes of the one-sided pull schedule.

    Every surviving (device, panel) pair fetches its A panel from the
    owning grid column and its B slab from the owning grid row, at factor
    1.0 (a one-sided get moves the payload once — no allreduce doubling).
    A fetch occupies *both* endpoints' comm clocks (receiver and owner,
    which is where owner contention appears in the simulator), so the
    per-device mean occupancy is twice the total fetched bytes over the
    device count.  Pull undercuts broadcast once the live-receiver count
    per owner drops below the broadcast factor — the RDMA-SpGEMM
    crossover the 16x16-grid sweep validates.
    """
    t_a = max(k_steps // p_col, 1)
    t_b = max(k_steps // p_row, 1)
    total = 0.0
    for kk in live:
        owner_col = kk // t_a
        owner_row = kk // t_b
        for i in range(p_row):
            for j in range(p_col):
                if not device_live[i, j, kk]:
                    continue
                if p_col > 1 and j != owner_col:
                    # rank-factorized A panels fetch their U/V factors
                    # instead of the dense slab (repro.spgemm pull + rank)
                    a_elems = (
                        a_fetch_elems[kk]
                        if a_fetch_elems is not None
                        else m_loc * kb_width
                    )
                    total += a_elems * itemsize
                if p_row > 1 and i != owner_row:
                    b_elems = (
                        float(b_live_cols[kk, j])
                        if b_live_cols is not None
                        else float(kb_width * n_loc)
                    )
                    total += b_elems * itemsize
    return 2.0 * total / max(p_row * p_col, 1)


def _resolve_stationarity(
    a_struct,
    b_struct,
    *,
    m: int,
    k: int,
    n: int,
    p_row: int,
    p_col: int,
    itemsize: int,
    stationarity: str,
    c_structure=None,
) -> tuple[str, dict[str, float]]:
    """Resolve ``stationarity="auto"`` through the spgemm chooser and
    return ``(choice, modeled total volumes)`` either way.  Lazy import:
    ``repro.spgemm`` sits downstream of ``core`` in the import graph."""
    from repro.spgemm.stationarity import (
        STATIONARITIES,
        choose_stationarity,
        stationarity_comm_volumes,
    )

    if stationarity == "auto":
        return choose_stationarity(
            a_struct, b_struct, m=m, k=k, n=n, p_row=p_row, p_col=p_col,
            itemsize=itemsize, c_structure=c_structure,
        )
    if stationarity not in STATIONARITIES:
        raise ValueError(
            f"stationarity={stationarity!r}: one of "
            f"{STATIONARITIES + ('auto',)}"
        )
    vols = stationarity_comm_volumes(
        a_struct, b_struct, m=m, k=k, n=n, p_row=p_row, p_col=p_col,
        itemsize=itemsize, c_structure=c_structure,
    )
    return stationarity, vols


def plan_matmul(
    m: int,
    k: int,
    n: int,
    cfg: SummaConfig,
    *,
    a_mask: np.ndarray | None = None,
    b_mask: np.ndarray | None = None,
    a_ranks: BlockRankMap | None = None,
    b_ranks: BlockRankMap | None = None,
    c_mask: np.ndarray | None = None,
    rank_payload: bool = True,
    comm_mode: str = "broadcast",
    stationarity: str = "C",
    itemsize: int = 4,
    a_norms: np.ndarray | None = None,
    b_norms: np.ndarray | None = None,
    filter_eps: float = 0.0,
) -> MatmulPlan:
    """Plan C = A @ B on ``cfg``'s grid; the single schedule source.

    ``a_mask``/``b_mask`` are block masks over the *logical* shapes; block
    sizes must divide them evenly.  Either may be ``None`` (treated as a
    single all-ones block on that side).  ``a_ranks`` refines A's mask
    into per-block numerical ranks (``BlockRankMap``, or anything with a
    ``rank_map()`` such as ``RankCSR``); it replaces ``a_mask`` and makes
    the cost model charge each block its factored gemm cost and its
    factor-sized broadcast bytes.  ``rank_payload=False`` says the caller
    has no factor payload (dense-stored A, rank map for useful-work
    accounting and pruning only): the plan then schedules — and the task
    graph / tuner model — the masked DAG it will actually execute, not
    the factored pipeline.

    SpGEMM extensions (``repro.spgemm``): ``b_ranks`` is B's
    structure-only rank map (replaces ``b_mask``; B stays dense-stored);
    ``c_mask`` is the output block mask — gemm tasks whose C block is
    dead are pruned from the per-device liveness and execution zeroes the
    dead output blocks; ``comm_mode="pull"`` plans one-sided panel
    fetches instead of broadcasts (needs block structure, C-stationary
    only); ``stationarity`` picks which operand stays put ("auto" runs
    the comm-volume chooser over C/A/B).

    Norm filtering (DBCSR-style, ``filter_eps > 0``): ``a_norms`` /
    ``b_norms`` are per-block Frobenius norms on the operand block grids
    (``core.sparsity.block_norms`` / ``rank_csr_norms``).  Every (i, k, j)
    product whose bound ``||A_ik||_F * ||B_kj||_F`` falls below
    ``filter_eps`` is screened: the operand masks, the output mask, and
    the per-device panel liveness are all refined to the surviving
    triples, so downstream consumers — the task graph, the simulator, the
    executors, and ``digest()`` — see the filtered structure.  Pruning is
    applied at the engine's task granularity (mask rows/cols, output
    blocks, per-device k-panels — the projections of the screened triple
    set): a screened (i, k, j) whose row, column, and output block all
    stay live elsewhere is still computed by the panel product, which
    only *lowers* the realized error.  The plan
    records the additive error bound ``filter_bound`` (the sum of the
    screened products): ``||C_exact - C_filtered||_F <= filter_bound``,
    by submultiplicativity of the Frobenius norm per product and the
    triangle inequality over the sum.  ``filter_eps=0`` is a no-op and
    returns a plan bitwise identical (same digest) to one planned without
    norms.

    Returns a plan whose ``padded_shapes`` the caller pads operands to
    before ``core.summa.execute_plan`` (or ``execute_rank_plan`` for
    factorized operands).
    """
    if m <= 0 or k <= 0 or n <= 0:
        raise ValueError(f"bad shape ({m},{k})x({k},{n})")
    if comm_mode not in ("broadcast", "pull"):
        raise ValueError(
            f"comm_mode={comm_mode!r}: one of ('broadcast', 'pull')"
        )
    if comm_mode == "pull" and stationarity not in ("C", "auto"):
        raise ValueError(
            "comm_mode='pull' is a C-stationary pipeline; plan pull and "
            "A-/B-stationary schedules separately"
        )
    if not (np.isfinite(filter_eps) and filter_eps >= 0.0):
        raise ValueError(
            f"filter_eps must be finite and >= 0, got {filter_eps}"
        )
    if (a_norms is None) != (b_norms is None):
        raise ValueError(
            "per-block norms come in pairs: pass both a_norms and b_norms"
        )
    if filter_eps > 0.0 and a_norms is None:
        raise ValueError(
            "filter_eps > 0 needs per-block norms for both operands "
            "(a_norms=/b_norms= — core.sparsity.block_norms)"
        )
    if filter_eps <= 0.0:
        # Filtering off: norms are inert, and the plan must be bitwise
        # identical to one planned without them (the digest/no-op contract
        # the executable cache and ``api.plan``'s cache key rely on).
        a_norms = b_norms = None
    if a_norms is not None:
        # A norm grid carries block structure: synthesize the support masks
        # when the caller gave none, so dense-stored operands can still be
        # screened.
        if a_mask is None and a_ranks is None:
            a_mask = np.asarray(a_norms, np.float64) > 0.0
        if b_mask is None and b_ranks is None:
            b_mask = np.asarray(b_norms, np.float64) > 0.0
    p_row, p_col = cfg.p_row, cfg.p_col
    if a_ranks is not None:
        if a_mask is not None:
            raise ValueError("pass either a_mask or a_ranks for A, not both")
        if hasattr(a_ranks, "rank_map"):  # RankCSR and friends
            a_ranks = a_ranks.rank_map()
        if a_ranks.shape != (m, k):
            raise ValueError(
                f"a_ranks tiles {a_ranks.shape}, expected ({m},{k})"
            )
        a_mask = a_ranks.mask
    if b_ranks is not None:
        if b_mask is not None:
            raise ValueError("pass either b_mask or b_ranks for B, not both")
        if hasattr(b_ranks, "rank_map"):  # RankCSR and friends
            b_ranks = b_ranks.rank_map()
        if b_ranks.shape != (k, n):
            raise ValueError(
                f"b_ranks tiles {b_ranks.shape}, expected ({k},{n})"
            )
        b_mask = b_ranks.mask
    masked = a_mask is not None or b_mask is not None
    if c_mask is not None:
        c_mask = np.asarray(c_mask, dtype=bool)
        if not masked:
            raise ValueError(
                "c_mask needs block structure on A or B to prune against"
            )
    if not masked:
        if comm_mode == "pull":
            raise ValueError(
                "comm_mode='pull' needs block structure to size fetches"
            )
        kmult = math.lcm(p_row, p_col)
        if cfg.k_blocks:
            kmult = math.lcm(kmult, cfg.k_blocks)
        m_pad = _ceil_to(m, p_row)
        n_pad = _ceil_to(n, p_col)
        k_pad = _ceil_to(k, kmult)
        k_steps = cfg.resolve_k_blocks(k_pad)
        kb_width = k_pad // k_steps
        if (k_pad // p_col) % kb_width or (k_pad // p_row) % kb_width:
            raise ValueError(
                f"panel width {kb_width} must divide local K shards "
                f"({k_pad // p_col}, {k_pad // p_row})"
            )
        m_loc, n_loc = m_pad // p_row, n_pad // p_col
        stationarity, stat_vols = _resolve_stationarity(
            None, None, m=m_pad, k=k_pad, n=n_pad, p_row=p_row, p_col=p_col,
            itemsize=itemsize, stationarity=stationarity,
        )
        flops = 2.0 * m_pad * k_pad * n_pad
        comm = _comm_model(
            m_loc=m_loc, n_loc=n_loc, k_pad=k_pad, kb_width=kb_width,
            live=k_steps, k_steps=k_steps, p_row=p_row, p_col=p_col,
            itemsize=itemsize,
        )
        p_all = max(p_row * p_col, 1)
        comm["c_stationary"] = stat_vols["C"] / p_all
        comm["a_stationary"] = stat_vols["A"] / p_all
        comm["b_stationary"] = stat_vols["B"] / p_all
        cost = PlanCost(
            flops_dense=flops,
            flops_sparse=flops,
            comm_bytes=comm,
            fill_in=1.0,
            flops_mask=flops,
        )
        return MatmulPlan(
            cfg=cfg, m=m, k=k, n=n, m_pad=m_pad, k_pad=k_pad, n_pad=n_pad,
            k_steps=k_steps, kb_width=kb_width,
            live_panels=tuple(range(k_steps)),
            a_mask=None, b_mask=None, device_live=None,
            local_cols=None, local_block=None, local_impl="dense",
            cost=cost, itemsize=itemsize,
            comm_mode=comm_mode, stationarity=stationarity,
        )

    # -- masked path ---------------------------------------------------------
    # One-sided masks: synthesize all-ones blocking on the other side.
    # Use one block per grid shard when the extent divides the grid (keeps
    # padding minimal and the kernel block size large); otherwise a single
    # block-per-element fallback so padding stays at the grid minimum.
    if a_mask is None:
        if c_mask is not None and m % c_mask.shape[0] == 0:
            m_blocks = c_mask.shape[0]  # match the output filter's grid
        else:
            m_blocks = p_row if m % p_row == 0 else m
        a_mask = np.ones((m_blocks, np.asarray(b_mask).shape[0]), dtype=bool)
    if b_mask is None:
        if c_mask is not None and n % c_mask.shape[1] == 0:
            n_blocks = c_mask.shape[1]
        else:
            n_blocks = p_col if n % p_col == 0 else n
        b_mask = np.ones((np.asarray(a_mask).shape[1], n_blocks), dtype=bool)
    a_mask = np.asarray(a_mask, dtype=bool)
    b_mask = np.asarray(b_mask, dtype=bool)
    m_blk, k_blk = a_mask.shape
    k_blk2, n_blk = b_mask.shape
    if k_blk != k_blk2:
        raise ValueError(
            f"A col-blocks ({k_blk}) must equal B row-blocks ({k_blk2})"
        )
    if m % m_blk or k % k_blk or n % n_blk:
        raise ValueError(
            f"masks {a_mask.shape}/{b_mask.shape} must evenly block "
            f"({m},{k})x({k},{n})"
        )
    if c_mask is not None and c_mask.shape != (m_blk, n_blk):
        raise ValueError(
            f"c_mask {c_mask.shape} must match the output block grid "
            f"({m_blk},{n_blk})"
        )
    bm_sz, bk_sz, bn_sz = m // m_blk, k // k_blk, n // n_blk
    # Padded shapes stay block-divisible AND grid-divisible; K additionally
    # keeps every panel inside a single device shard on both operands.
    m_pad = _ceil_to(m, math.lcm(bm_sz, p_row))
    n_pad = _ceil_to(n, math.lcm(bn_sz, p_col))
    k_pad = _ceil_to(k, bk_sz * math.lcm(p_row, p_col))
    a_mask_p = _pad_block_mask(a_mask, (m_pad // bm_sz, k_pad // bk_sz))
    b_mask_p = _pad_block_mask(b_mask, (k_pad // bk_sz, n_pad // bn_sz))
    k_steps = k_pad // bk_sz  # one panel per K block
    kb_width = bk_sz

    # -- norm screening (DBCSR-style product filter) -------------------------
    # Refine the structure *before* liveness so every downstream consumer
    # (panel schedule, device liveness, CSR maps, cost model, digest) sees
    # only the surviving triples.
    a_norms_p = b_norms_p = None
    keep = None
    c_norms = None
    filter_bound = 0.0
    if a_norms is not None:
        def _pad_norms(norms, blocks, blocks_pad, side):
            arr = np.asarray(norms, dtype=np.float64)
            if arr.shape != blocks:
                raise ValueError(
                    f"{side} norm grid {arr.shape} must match the block "
                    f"grid {blocks}"
                )
            out = np.zeros(blocks_pad)
            out[: blocks[0], : blocks[1]] = arr
            return out

        a_norms_p = _pad_norms(
            a_norms, (m_blk, k_blk), a_mask_p.shape, "a_norms"
        ) * a_mask_p
        b_norms_p = _pad_norms(
            b_norms, (k_blk, n_blk), b_mask_p.shape, "b_norms"
        ) * b_mask_p
        from repro.spgemm.structure import filter_keep, output_norms

        if filter_eps > 0.0:
            keep, filter_bound = filter_keep(a_norms_p, b_norms_p, filter_eps)
            a_mask_p = a_mask_p & keep.any(axis=2)
            b_mask_p = b_mask_p & keep.any(axis=0)
        c_norms = output_norms(a_norms_p, b_norms_p, keep)

    live, device_live, b_col = _panel_liveness(
        a_mask_p, b_mask_p, k_steps, p_row, p_col
    )
    m_blk_p = m_pad // bm_sz

    c_mask_p = None
    if c_mask is not None:
        c_mask_p = _pad_block_mask(c_mask, (m_pad // bm_sz, n_pad // bn_sz))
    if keep is not None:
        # Screened outputs join the output filter: a C block all of whose
        # addends were dropped is dead (its norm bound rides in c_norms
        # only as 0).
        c_keep = keep.any(axis=1)
        c_mask_p = c_keep if c_mask_p is None else (c_mask_p & c_keep)
    if c_mask_p is not None:
        # Dead-output pruning: drop gemm tasks whose C block the output
        # filter kills, then re-derive the live panel set.
        device_live = _refine_device_live_c(
            device_live, a_mask_p, b_mask_p, c_mask_p, p_row, p_col
        )
        live = [kk for kk in live if device_live[:, :, kk].any()]
    if c_norms is not None and c_mask_p is not None:
        c_norms = np.where(c_mask_p, c_norms, 0.0)

    a_ranks_p = None
    if a_ranks is not None:
        a_ranks_p = np.zeros((m_pad // bm_sz, k_pad // bk_sz), np.int32)
        a_ranks_p[: a_ranks.m_blocks, : a_ranks.k_blocks] = a_ranks.ranks
        if keep is not None:
            a_ranks_p = np.where(a_mask_p, a_ranks_p, 0)
    b_ranks_p = None
    if b_ranks is not None:
        b_ranks_p = np.zeros((k_pad // bk_sz, n_pad // bn_sz), np.int32)
        b_ranks_p[: b_ranks.m_blocks, : b_ranks.k_blocks] = b_ranks.ranks
        if keep is not None:
            b_ranks_p = np.where(b_mask_p, b_ranks_p, 0)

    a_struct = (
        BlockRankMap(ranks=a_ranks_p, bm=bm_sz, bk=bk_sz)
        if a_ranks_p is not None
        else a_mask_p
    )
    b_struct = (
        BlockRankMap(ranks=b_ranks_p, bm=bk_sz, bk=bn_sz)
        if b_ranks_p is not None
        else b_mask_p
    )
    stationarity, stat_vols = _resolve_stationarity(
        a_struct, b_struct, m=m_pad, k=k_pad, n=n_pad,
        p_row=p_row, p_col=p_col, itemsize=itemsize,
        stationarity=stationarity, c_structure=c_mask_p,
    )

    local_cols = None
    local_block = None
    local_impl = "masked"
    # The specialized local executors (factored rank pipeline, Pallas BSMM)
    # exist only for C-stationary pipelines; A-/B-stationary schedules run
    # the masked DAG.  The rank pipeline supports both comm modes — pull
    # fetches the U/V factors themselves (``_exec_ranksparse_pull``) —
    # while BSMM stays broadcast-only.
    plain_pipeline = comm_mode == "broadcast" and stationarity == "C"
    if a_ranks_p is not None:
        # The factor layout (U panels of uniform width, V rows batched per
        # local block row) needs a payload and row blocks aligned to the
        # grid; otherwise execution (and therefore the schedule model) is
        # the dense-stored masked DAG.
        if rank_payload and m_blk_p % p_row == 0 and stationarity == "C":
            local_impl = "ranksparse"
    # BSMM needs row blocks aligned to the grid and big enough to make a
    # sane kernel block (>= 8 rows: TPU sublane minimum).
    elif (
        cfg.local_matmul == "pallas"
        and live
        and m_blk_p % p_row == 0
        and bm_sz >= 8
        and plain_pipeline
    ):
        local_cols = _local_csr_cols(a_mask_p, b_col, live, p_row, p_col)
        local_block = (bm_sz, kb_width, _pick_bn(n_pad // p_col))
        local_impl = "bsmm"

    sparse, dense = mask_matmul_flops(a_mask_p, b_mask_p, bm_sz, bk_sz, bn_sz)
    m_loc, n_loc = m_pad // p_row, n_pad // p_col
    if c_mask_p is not None:
        # Useful flops count only the (i, kk) x (kk, j) pairs whose output
        # block survives the filter.
        pairs = a_mask_p.astype(np.int64) @ b_mask_p.astype(np.int64)
        sparse = 2.0 * bm_sz * bk_sz * bn_sz * float(pairs[c_mask_p].sum())
    mask_flops = float(sparse)
    a_live_elems = None
    a_fetch_elems = None
    if a_ranks_p is not None:
        from repro.core.sparsity import (
            rank_matmul_flops,
            rank_panel_factored_comm,
        )

        padded_map = BlockRankMap(ranks=a_ranks_p, bm=bm_sz, bk=bk_sz)
        rank_flops, _, _ = rank_matmul_flops(padded_map, b_mask_p, bn_sz)
        sparse = rank_flops
        if local_impl == "ranksparse":
            # Broadcast volume of the A-side panels: a factored panel
            # moves a (m_loc, r_k) U panel plus (mb_loc, r_k, bk) V rows
            # (r_k = the panel's max block rank, the executor's static
            # width); past r* = bm·bk/(bm+bk) the panel is reconstructed
            # owner-side and dense panel bytes travel — the exact
            # per-panel comm decision the executor takes
            # (sparsity.rank_panel_factored_comm).
            mb_loc = m_blk_p // p_row
            r_live = a_ranks_p.max(axis=0)  # (K_blk,) per-panel width
            a_live_elems = 0.0
            a_fetch_elems = {}
            for kk in live:
                r_k = int(r_live[kk])
                if rank_panel_factored_comm(r_k, bm_sz, bk_sz):
                    elems = m_loc * r_k + mb_loc * r_k * bk_sz
                else:
                    elems = m_loc * bk_sz
                a_live_elems += elems
                a_fetch_elems[kk] = float(elems)
    b_live_cols = b_panel_live_elems(
        b_mask_p, b_ranks_p, bk_sz=bk_sz, bn_sz=bn_sz, p_col=p_col
    )
    b_live_elems = None
    if b_live_cols is not None:
        b_live_elems = (
            float(b_live_cols[np.asarray(live, dtype=int)].mean(axis=1).sum())
            if live
            else 0.0
        )
    comm = _comm_model(
        m_loc=m_loc, n_loc=n_loc, k_pad=k_pad, kb_width=kb_width,
        live=len(live), k_steps=k_steps, p_row=p_row, p_col=p_col,
        itemsize=itemsize, a_live_elems=a_live_elems,
        b_live_elems=b_live_elems,
    )
    comm["pull"] = _pull_comm_bytes(
        device_live, live, k_steps=k_steps, m_loc=m_loc, kb_width=kb_width,
        n_loc=n_loc, p_row=p_row, p_col=p_col, itemsize=itemsize,
        b_live_cols=b_live_cols,
        a_fetch_elems=a_fetch_elems if local_impl == "ranksparse" else None,
    )
    p_all = max(p_row * p_col, 1)
    comm["c_stationary"] = stat_vols["C"] / p_all
    comm["a_stationary"] = stat_vols["A"] / p_all
    comm["b_stationary"] = stat_vols["B"] / p_all
    cost = PlanCost(
        flops_dense=float(dense),
        flops_sparse=float(sparse),
        comm_bytes=comm,
        fill_in=float(sparse) / float(dense) if dense else 0.0,
        flops_mask=mask_flops,
    )
    return MatmulPlan(
        cfg=cfg, m=m, k=k, n=n, m_pad=m_pad, k_pad=k_pad, n_pad=n_pad,
        k_steps=k_steps, kb_width=kb_width, live_panels=tuple(live),
        a_mask=a_mask_p, b_mask=b_mask_p, device_live=device_live,
        local_cols=local_cols, local_block=local_block,
        local_impl=local_impl, cost=cost, itemsize=itemsize,
        a_ranks=a_ranks_p, b_ranks=b_ranks_p, c_mask=c_mask_p,
        comm_mode=comm_mode, stationarity=stationarity,
        filter_eps=float(filter_eps), filter_bound=filter_bound,
        c_norms=c_norms,
    )
