"""Block-sparse tensor contractions lowered to the ``MatmulPlan`` engine.

The paper is "a step towards block-sparse **tensor** computing"; this
module takes that step.  A binary einsum-style contraction
``contract("abc,cd->abd", x, y)`` of :class:`BlockSparseTensor` operands
is executed by

1. parsing the spec into **batch / contracted / free** modes
   (:func:`parse_contraction`);
2. **matricizing** each operand — modes merge in *block-lexicographic*
   order, so every tensor block maps to one contiguous matrix block and
   the merged dimension carries a real ``core.blocking.Tiling`` (the
   Kronecker product of the mode tilings, nonuniform whenever any mode
   is).  Block masks and per-block rank maps matricize by the same
   reshape, exactly;
3. executing the matricized product through the shared planner
   (``core.plan.plan_matmul`` via ``core.api.DistributedMatmul``): dense,
   masked, rank-sparse (``RankCSR`` factor payloads included) and — when
   a merged tiling is nonuniform — the bucketized
   ``core.api.NonuniformMatmul`` adaptation;
4. un-matricizing C and *inferring its block mask* (live C blocks are
   exactly the boolean product of the operand masks), so contraction
   results chain as first-class block-sparse tensors.

Chaining is scheduled jointly: :func:`contract_chain` plans every step,
materializes the **union task graph** of the consecutive contractions
(``sched.taskgraph.chain_graphs`` — the C tiles of step ``i`` gate only
the A-panel broadcasts of step ``i+1`` that read them, the paper's "no
explicit internodal synchronization lets multiple MMs overlap"),
simulates it (``sched.simulator``), optionally lets the tuner pick the
per-step multiple-issue windows jointly (``sched.tuner.tune_chain``),
and then executes the steps with the chosen windows.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core import blocking as bk
from repro.core.plan import mask_key, rank_key
from repro.core.sparsity import BlockRankMap, RankCSR

__all__ = [
    "ContractionSpec",
    "parse_contraction",
    "BlockSparseTensor",
    "matricize_mask",
    "unmatricize_mask",
    "merge_tilings",
    "contract",
    "contract_chain",
]


# ---------------------------------------------------------------------------
# spec parsing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ContractionSpec:
    """A parsed binary contraction ``"<x>,<y>-><out>"``.

    * ``batch`` — modes in x, y AND the output (einsum batch dims);
    * ``contracted`` — modes in x and y but not the output (summed);
    * ``free_x`` / ``free_y`` — modes of one operand surviving to the
      output.  Orders are the appearance order in the owning operand
      (``contracted`` uses x's order; y is transposed to match).
    """

    x_modes: tuple[str, ...]
    y_modes: tuple[str, ...]
    out_modes: tuple[str, ...]
    batch: tuple[str, ...]
    contracted: tuple[str, ...]
    free_x: tuple[str, ...]
    free_y: tuple[str, ...]

    @property
    def spec(self) -> str:
        return (
            f"{''.join(self.x_modes)},{''.join(self.y_modes)}"
            f"->{''.join(self.out_modes)}"
        )


def parse_contraction(spec: str) -> ContractionSpec:
    """Parse ``"abc,cd->abd"`` into batch / contracted / free modes.

    Exactly two inputs and an explicit output are required; a mode may
    appear at most once per operand (no internal traces), and every
    output mode must come from an input.  Modes of one input absent from
    the output would need a sum-reduction and are rejected — this is a
    *contraction* front-end, not full einsum.
    """
    if "->" not in spec:
        raise ValueError(
            f"contraction spec {spec!r} needs an explicit output "
            "('ab,bc->ac'); implicit-output einsum is not supported"
        )
    inputs, out = spec.replace(" ", "").split("->")
    parts = inputs.split(",")
    if len(parts) != 2:
        raise ValueError(
            f"spec {spec!r} must contract exactly two operands, "
            f"got {len(parts)}"
        )
    xm, ym = tuple(parts[0]), tuple(parts[1])
    om = tuple(out)
    for name, modes in (("x", xm), ("y", ym), ("output", om)):
        if len(set(modes)) != len(modes):
            raise ValueError(
                f"repeated mode in {name} of {spec!r}: internal traces "
                "are not supported"
            )
        bad = [m for m in modes if not m.isalpha()]
        if bad:
            raise ValueError(f"non-letter modes {bad} in {spec!r}")
    xs, ys, os_ = set(xm), set(ym), set(om)
    if not os_ <= (xs | ys):
        raise ValueError(
            f"output modes {sorted(os_ - xs - ys)} of {spec!r} appear in "
            "no input"
        )
    dropped = sorted((xs ^ ys) - os_)
    if dropped:
        raise ValueError(
            f"modes {dropped} of {spec!r} appear in one input but not the "
            "output: sum-reductions are not supported"
        )
    batch = tuple(m for m in xm if m in ys and m in os_)
    contracted = tuple(m for m in xm if m in ys and m not in os_)
    free_x = tuple(m for m in xm if m not in ys)
    free_y = tuple(m for m in ym if m not in xs)
    if not contracted:
        raise ValueError(
            f"spec {spec!r} contracts no mode (outer products are not "
            "supported; use a contraction with at least one summed mode)"
        )
    return ContractionSpec(
        x_modes=xm, y_modes=ym, out_modes=om,
        batch=batch, contracted=contracted,
        free_x=free_x, free_y=free_y,
    )


# ---------------------------------------------------------------------------
# the tensor container
# ---------------------------------------------------------------------------


def _as_tiling(t) -> bk.Tiling:
    if isinstance(t, bk.Tiling):
        return t
    return bk.Tiling(tuple(int(s) for s in t))


@dataclasses.dataclass
class BlockSparseTensor:
    """A dense-stored tensor with per-mode block tilings and block structure.

    * ``data`` — the dense jax/numpy array (``None`` only when
      ``rank_csr`` supplies a factor payload);
    * ``tilings`` — one :class:`core.blocking.Tiling` per mode, possibly
      nonuniform ("physics-driven" extents, paper §4.1);
    * ``mask`` — optional bool array over the block grid
      (``tuple(t.num_blocks for t in tilings)``); ``None`` = all blocks
      present;
    * ``ranks`` — optional int array over the same grid refining the mask
      into per-block numerical ranks (0 = screened out); dense-stored, so
      it drives cost/pruning only (``rank_payload=False`` planning);
    * ``rank_csr`` — optional factorized payload (2-D tensors only):
      the operand *is* the factorization, executed through
      ``execute_rank_plan``;
    * ``norms`` — optional float array over the block grid carrying
      per-block Frobenius norms (:meth:`block_norms` computes them from
      the data when absent).  Contraction results propagate *bounds*
      here (``||C_ij|| <= sum_k ||A_ik||.||B_kj||``), which is what lets
      ``filter_eps`` chains get progressively sparser.
    """

    data: object | None
    tilings: tuple[bk.Tiling, ...]
    mask: np.ndarray | None = None
    ranks: np.ndarray | None = None
    rank_csr: RankCSR | None = None
    norms: np.ndarray | None = None

    def __post_init__(self):
        self.tilings = tuple(_as_tiling(t) for t in self.tilings)
        if self.rank_csr is not None:
            if self.data is not None:
                raise ValueError(
                    "pass data=None with a rank_csr payload: the "
                    "factorization is the tensor (use rank_csr.to_dense())"
                )
            if len(self.tilings) != 2:
                raise ValueError(
                    "rank_csr payloads are 2-D (matricized) structures; "
                    f"got {len(self.tilings)} modes"
                )
            if self.mask is not None or self.ranks is not None:
                raise ValueError(
                    "rank_csr carries its own structure; do not also pass "
                    "mask/ranks"
                )
            want = (
                self.rank_csr.csr.m_blocks * self.rank_csr.bm,
                self.rank_csr.csr.n_blocks * self.rank_csr.bk,
            )
            if self.shape != want:
                raise ValueError(
                    f"tilings extent {self.shape} != rank_csr shape {want}"
                )
        elif self.data is None:
            raise ValueError("data=None requires a rank_csr payload")
        else:
            if tuple(self.data.shape) != self.shape:
                raise ValueError(
                    f"data shape {tuple(self.data.shape)} != tilings "
                    f"extents {self.shape}"
                )
        if self.mask is not None and self.ranks is not None:
            raise ValueError("pass either mask or ranks, not both")
        dtypes = {"mask": bool, "ranks": np.int32, "norms": np.float64}
        for name, dt in dtypes.items():
            arr = getattr(self, name)
            if arr is None:
                continue
            arr = np.asarray(arr)
            if arr.shape != self.block_grid:
                raise ValueError(
                    f"{name} shape {arr.shape} != block grid "
                    f"{self.block_grid}"
                )
            setattr(self, name, arr.astype(dt))

    # -- geometry ------------------------------------------------------------

    @property
    def ndim(self) -> int:
        return len(self.tilings)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(t.extent for t in self.tilings)

    @property
    def block_grid(self) -> tuple[int, ...]:
        return tuple(t.num_blocks for t in self.tilings)

    @property
    def block_mask(self) -> np.ndarray:
        """The effective present/absent block mask (all-True if none)."""
        if self.rank_csr is not None:
            return self.rank_csr.rank_map().mask
        if self.ranks is not None:
            return self.ranks > 0
        if self.mask is not None:
            return self.mask
        return np.ones(self.block_grid, dtype=bool)

    def fill(self) -> float:
        """Live fraction of *elements* (block areas weighted — on
        nonuniform tilings this differs from the live-block count)."""
        if not self.tilings:  # 0-D result of a full contraction
            return 1.0
        mask = self.block_mask
        area = np.asarray(self.tilings[0].sizes, dtype=np.float64)
        for t in self.tilings[1:]:
            area = np.multiply.outer(area, np.asarray(t.sizes, np.float64))
        total = float(area.sum())
        return float((area * mask).sum() / total) if total else 0.0

    def block_norms(self) -> np.ndarray:
        """Per-block Frobenius norms over the block grid.

        Precomputed ``norms`` (e.g. the propagated bounds a filtered
        contraction attaches) pass through; otherwise norms are computed
        from the stored data — exactly for dense storage, from the
        factors for ``rank_csr`` payloads (``||U V||_F`` computed without
        densifying).  Dead blocks (mask / rank screened) report 0, so
        norms agree with the effective structure.
        """
        if self.norms is not None:
            return self.norms
        if self.rank_csr is not None:
            from repro.core.sparsity import rank_csr_norms

            return rank_csr_norms(self.rank_csr)
        if self.data is None:
            raise ValueError("block_norms needs data or precomputed norms")
        sq = np.asarray(self.data, dtype=np.float64) ** 2
        for axis, t in enumerate(self.tilings):
            sq = np.add.reduceat(
                sq, np.asarray(t.offsets, dtype=np.int64), axis=axis
            )
        out = np.sqrt(sq)
        if self.mask is not None or self.ranks is not None:
            out = np.where(self.block_mask, out, 0.0)
        return out

    def to_dense(self) -> np.ndarray:
        """Dense numpy storage with masked blocks zeroed (the oracle view)."""
        if self.rank_csr is not None:
            return self.rank_csr.to_dense()
        data = np.asarray(self.data)
        if self.mask is None and self.ranks is None:
            return data
        fine = expand_block_mask(self.block_mask, self.tilings)
        return np.where(fine, data, np.zeros((), data.dtype))

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_dense(
        cls,
        data,
        tilings=None,
        *,
        block_shape: tuple[int, ...] | None = None,
        mask: np.ndarray | None = None,
        ranks: np.ndarray | None = None,
    ) -> "BlockSparseTensor":
        """Wrap a dense array; ``block_shape`` builds uniform tilings."""
        if tilings is None:
            if block_shape is None:
                tilings = [bk.Tiling((d,)) for d in data.shape]
            else:
                tilings = [
                    bk.uniform_tiling(d, b)
                    for d, b in zip(data.shape, block_shape)
                ]
        return cls(
            data=data, tilings=tuple(tilings), mask=mask, ranks=ranks
        )

    @classmethod
    def from_rank_csr(cls, rank_csr: RankCSR) -> "BlockSparseTensor":
        """A 2-D tensor whose payload is the factorization itself."""
        tilings = (
            bk.uniform_tiling(
                rank_csr.csr.m_blocks * rank_csr.bm, rank_csr.bm
            ),
            bk.uniform_tiling(
                rank_csr.csr.n_blocks * rank_csr.bk, rank_csr.bk
            ),
        )
        return cls(data=None, tilings=tilings, rank_csr=rank_csr)


def _wrap(x) -> BlockSparseTensor:
    if isinstance(x, BlockSparseTensor):
        return x
    if isinstance(x, RankCSR):
        return BlockSparseTensor.from_rank_csr(x)
    return BlockSparseTensor.from_dense(x)


def expand_block_mask(
    mask: np.ndarray, tilings: tuple[bk.Tiling, ...]
) -> np.ndarray:
    """Element-resolution expansion of a block mask (nonuniform-aware)."""
    out = np.asarray(mask, dtype=bool)
    for axis, t in enumerate(tilings):
        out = np.repeat(out, t.sizes, axis=axis)
    return out


# ---------------------------------------------------------------------------
# matricization: block-lexicographic mode merging
# ---------------------------------------------------------------------------


def merge_tilings(
    tilings: tuple[bk.Tiling, ...],
) -> tuple[bk.Tiling, np.ndarray | None]:
    """Merge mode tilings into one block-contiguous dimension.

    The natural row-major flatten of merged modes interleaves blocks
    (element ``(i1, i2)`` ↦ ``i1·E2 + i2`` scatters block ``(b1, b2)``
    into strided segments).  We instead order the merged dimension
    *block-lexicographically* — sort key ``(blk_1, …, blk_n, off_1, …,
    off_n)`` — so every tensor block occupies one contiguous range and
    the merged dimension is a genuine :class:`Tiling` whose sizes are
    the products of the per-mode block sizes in lexicographic block
    order (matching ``mask.reshape(-1)`` on the block grid).

    Returns ``(merged_tiling, perm)`` with ``perm[new] = old_flat_index``
    into the row-major flatten, or ``perm=None`` when the orders
    coincide (single mode, or any prefix of modes with one block each).
    """
    tilings = tuple(tilings)
    if not tilings:
        return bk.Tiling((1,)), None
    sizes = np.asarray(tilings[0].sizes, dtype=np.int64)
    for t in tilings[1:]:
        sizes = np.multiply.outer(sizes, np.asarray(t.sizes, np.int64))
    merged = bk.Tiling(tuple(int(s) for s in sizes.ravel()))
    if len(tilings) == 1 or all(
        t.num_blocks == 1 for t in tilings[1:]
    ):
        # trailing modes contribute a single block each, so every merged
        # block is already a contiguous row-major range
        return merged, None
    shape = tuple(t.extent for t in tilings)
    blk, off = [], []
    for axis, t in enumerate(tilings):
        ids = np.repeat(
            np.arange(t.num_blocks, dtype=np.int64), t.sizes
        )
        offs = (
            np.arange(t.extent, dtype=np.int64)
            - np.asarray(t.offsets, dtype=np.int64)[ids]
        )
        view = [1] * len(shape)
        view[axis] = -1
        blk.append(np.broadcast_to(ids.reshape(view), shape).ravel())
        off.append(np.broadcast_to(offs.reshape(view), shape).ravel())
    # lexsort: last key is primary -> (blk_1 … blk_n, off_1 … off_n)
    perm = np.lexsort(tuple(off[::-1]) + tuple(blk[::-1]))
    if np.array_equal(perm, np.arange(perm.size)):
        return merged, None
    return merged, perm


def matricize_mask(
    mask: np.ndarray,
    modes: tuple[str, ...],
    row_modes: tuple[str, ...],
    col_modes: tuple[str, ...],
) -> np.ndarray:
    """Reshape a block-grid array to the matricized 2-D block grid.

    Exact by construction: merged tilings order blocks
    lexicographically, which is precisely the row-major reshape of the
    transposed block grid.  Works for bool masks and int rank maps.
    """
    mask = np.asarray(mask)
    axes = [modes.index(m) for m in row_modes + col_modes]
    mt = np.transpose(mask, axes)
    rows = int(np.prod(mt.shape[: len(row_modes)], dtype=np.int64))
    return mt.reshape(max(rows, 1), -1)


def unmatricize_mask(
    mask2d: np.ndarray,
    row_modes: tuple[str, ...],
    col_modes: tuple[str, ...],
    grids: dict[str, int],
    out_modes: tuple[str, ...],
) -> np.ndarray:
    """Inverse of :func:`matricize_mask` onto ``out_modes`` order."""
    shape = tuple(grids[m] for m in row_modes) + tuple(
        grids[m] for m in col_modes
    )
    nd = np.asarray(mask2d).reshape(shape or (1,))
    if not shape:
        return nd
    cur = row_modes + col_modes
    return np.transpose(nd, [cur.index(m) for m in out_modes])


def _apply_perm(arr, perm: np.ndarray | None, axis: int):
    if perm is None:
        return arr
    import jax.numpy as jnp

    return jnp.take(arr, jnp.asarray(perm), axis=axis)


def _invert(perm: np.ndarray | None) -> np.ndarray | None:
    if perm is None:
        return None
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    return inv


@dataclasses.dataclass(frozen=True, eq=False)
class _OperandGeom:
    """How one operand matricizes: transpose order, merged tilings, perms."""

    axes: tuple[int, ...]  # transpose order: row modes then col modes
    row_modes: tuple[str, ...]
    col_modes: tuple[str, ...]
    row_tiling: bk.Tiling
    col_tiling: bk.Tiling
    row_perm: np.ndarray | None
    col_perm: np.ndarray | None

    def matricize(self, data):
        import jax.numpy as jnp

        xt = jnp.transpose(jnp.asarray(data), self.axes)
        x2 = xt.reshape(self.row_tiling.extent, self.col_tiling.extent)
        x2 = _apply_perm(x2, self.row_perm, 0)
        return _apply_perm(x2, self.col_perm, 1)

    @property
    def identity(self) -> bool:
        """True when matricization is a pure reshape (no data movement)."""
        return (
            self.axes == tuple(range(len(self.axes)))
            and self.row_perm is None
            and self.col_perm is None
        )


def _operand_geom(
    modes: tuple[str, ...],
    tilings: tuple[bk.Tiling, ...],
    row_modes: tuple[str, ...],
    col_modes: tuple[str, ...],
) -> _OperandGeom:
    tmap = dict(zip(modes, tilings))
    axes = tuple(modes.index(m) for m in row_modes + col_modes)
    row_tiling, row_perm = merge_tilings(
        tuple(tmap[m] for m in row_modes)
    )
    col_tiling, col_perm = merge_tilings(
        tuple(tmap[m] for m in col_modes)
    )
    return _OperandGeom(
        axes=axes, row_modes=row_modes, col_modes=col_modes,
        row_tiling=row_tiling, col_tiling=col_tiling,
        row_perm=row_perm, col_perm=col_perm,
    )


# ---------------------------------------------------------------------------
# one contraction step: geometry + planning + execution
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class _StepGeometry:
    """Everything static about one contraction: resolved once, cached."""

    spec: ContractionSpec
    x_geom: _OperandGeom
    y_geom: _OperandGeom
    a_mask2: np.ndarray | None  # matricized x mask (None = dense)
    b_mask2: np.ndarray | None
    a_ranks2: BlockRankMap | None  # matricized dense-stored rank map
    uniform: bool  # all three merged tilings uniform
    out_tilings: tuple[bk.Tiling, ...]
    out_mask: np.ndarray | None
    #: matricized inferred C mask (spgemm symbolic pass) — fed to the
    #: planner as ``c_mask`` on the uniform path so dead output blocks
    #: never emit gemm tasks; ``out_mask`` is its un-matricized twin
    c_mask2: np.ndarray | None
    out_row_perm_inv: np.ndarray | None
    out_col_perm_inv: np.ndarray | None
    tile: int
    #: the structural key this geometry is cached under (None when the
    #: front-end has no cache) — compiled step programs key off it
    cache_key: tuple | None = None


def _uniform_block(t: bk.Tiling) -> int:
    return t.sizes[0]


def _step_geometry(
    spec: ContractionSpec,
    x: BlockSparseTensor,
    y: BlockSparseTensor,
    tile: int,
) -> _StepGeometry:
    if spec.batch:
        raise ValueError(
            "batch modes must be split before matricization "
            "(contract() handles this)"
        )
    if len(spec.x_modes) != x.ndim or len(spec.y_modes) != y.ndim:
        raise ValueError(
            f"spec {spec.spec!r} expects {len(spec.x_modes)}-D x / "
            f"{len(spec.y_modes)}-D y, got {x.ndim}-D / {y.ndim}-D"
        )
    xt = dict(zip(spec.x_modes, x.tilings))
    yt = dict(zip(spec.y_modes, y.tilings))
    # A structureless operand (no mask/ranks/factors — e.g. a raw array
    # wrapped with one block per mode) adopts its partner's blocking on
    # shared modes, so "masked tensor x raw array" just works.
    x_plain = x.mask is None and x.ranks is None and x.rank_csr is None
    y_plain = y.mask is None and y.ranks is None and y.rank_csr is None
    for m in spec.contracted:  # batch modes were split off in contract()
        if xt[m].sizes == yt[m].sizes:
            continue
        if x_plain and xt[m].num_blocks == 1:
            xt[m] = yt[m]
        elif y_plain and yt[m].num_blocks == 1:
            yt[m] = xt[m]
        else:
            raise ValueError(
                f"mode {m!r} tilings disagree between operands: "
                f"{xt[m].sizes} vs {yt[m].sizes}"
            )
    x_geom = _operand_geom(
        spec.x_modes, tuple(xt[m] for m in spec.x_modes),
        spec.free_x, spec.contracted,
    )
    y_geom = _operand_geom(
        spec.y_modes, tuple(yt[m] for m in spec.y_modes),
        spec.contracted, spec.free_y,
    )

    a_mask2 = b_mask2 = None
    a_ranks2 = None
    if x.rank_csr is None:
        if x.ranks is not None:
            r2 = matricize_mask(
                x.ranks, spec.x_modes, spec.free_x, spec.contracted
            ).astype(np.int32)
            if (
                x_geom.row_tiling.is_uniform
                and x_geom.col_tiling.is_uniform
            ):
                a_ranks2 = BlockRankMap(
                    ranks=r2,
                    bm=_uniform_block(x_geom.row_tiling),
                    bk=_uniform_block(x_geom.col_tiling),
                )
            else:
                # nonuniform merged tilings carry the rank map logically
                a_ranks2 = r2
        elif x.mask is not None:
            a_mask2 = matricize_mask(
                x.mask, spec.x_modes, spec.free_x, spec.contracted
            )
    if y.rank_csr is not None:
        raise NotImplementedError(
            "rank_csr payloads are supported on the first operand only "
            "(the planner factors A); densify y or swap the operands"
        )
    if y.ranks is not None:
        raise NotImplementedError(
            "per-block ranks on the second operand are not supported "
            "(the planner refines A only); pass a mask instead"
        )
    if y.mask is not None:
        b_mask2 = matricize_mask(
            y.mask, spec.y_modes, spec.contracted, spec.free_y
        )

    uniform = (
        x_geom.row_tiling.is_uniform
        and x_geom.col_tiling.is_uniform
        and y_geom.col_tiling.is_uniform
    )

    # -- output geometry + inferred mask -------------------------------------
    grids = {m: t.num_blocks for m, t in {**yt, **xt}.items()}
    out_tilings = tuple(
        {**yt, **xt}[m] for m in spec.out_modes
    )
    xmask = (
        np.ones(tuple(xt[m].num_blocks for m in spec.x_modes), bool)
        if x_plain else x.block_mask
    )
    ymask = (
        np.ones(tuple(yt[m].num_blocks for m in spec.y_modes), bool)
        if y_plain else y.block_mask
    )
    cm2 = None
    if x_plain and y_plain:
        out_mask = None
    else:
        # the symbolic pass is the single source of truth for the
        # inferred output structure — plan_matmul's dead-output pruning
        # consumes the same boolean product (repro.spgemm)
        from repro.spgemm import output_mask as _output_mask

        am = matricize_mask(
            xmask, spec.x_modes, spec.free_x, spec.contracted
        )
        bm = matricize_mask(
            ymask, spec.y_modes, spec.contracted, spec.free_y
        )
        cm2 = _output_mask(am, bm)
        out_mask = unmatricize_mask(
            cm2, spec.free_x, spec.free_y, grids, spec.out_modes
        )
    return _StepGeometry(
        spec=spec,
        x_geom=x_geom,
        y_geom=y_geom,
        a_mask2=a_mask2,
        b_mask2=b_mask2,
        a_ranks2=a_ranks2,
        uniform=uniform,
        out_tilings=out_tilings,
        out_mask=out_mask,
        c_mask2=cm2,
        out_row_perm_inv=_invert(x_geom.row_perm),
        out_col_perm_inv=_invert(y_geom.col_perm),
        tile=tile,
    )


def _tensor_key(t: BlockSparseTensor) -> tuple:
    """Structural cache key: tilings + mask/rank content digests (the
    data itself never keys the geometry)."""
    return (
        tuple(tt.sizes for tt in t.tilings),
        mask_key(t.mask),
        None if t.ranks is None else (t.ranks.shape, t.ranks.tobytes()),
        rank_key(t.rank_csr),
    )


def _geometry_cached(mm, spec_str: str, x, y, tile: int) -> _StepGeometry:
    cache = getattr(mm, "_contract_cache", None)
    spec = parse_contraction(spec_str)
    if cache is None:
        return _step_geometry(spec, x, y, tile)
    stats = getattr(mm, "_cache_stats", None)
    key = (spec.spec, _tensor_key(x), _tensor_key(y), tile)
    geom = cache.get(key)
    if geom is None:
        if stats is not None:
            stats["geom_misses"] += 1
        geom = _step_geometry(spec, x, y, tile)
        geom.cache_key = key
        cache[key] = geom
    elif stats is not None:
        stats["geom_hits"] += 1
    return geom


def _nonuniform_front_end(mm, geom: _StepGeometry):
    """The bucketized adaptation for nonuniform merged tilings (cached)."""
    from repro.core.api import NonuniformMatmul

    cache = getattr(mm, "_contract_cache", None)
    key = (
        "nmm",
        geom.x_geom.row_tiling.sizes,
        geom.x_geom.col_tiling.sizes,
        geom.y_geom.col_tiling.sizes,
        geom.tile,
    )
    nmm = cache.get(key) if cache is not None else None
    if nmm is None:
        nmm = NonuniformMatmul(
            mm,
            geom.x_geom.row_tiling,
            geom.x_geom.col_tiling,
            geom.y_geom.col_tiling,
            tile=geom.tile,
        )
        if cache is not None:
            cache[key] = nmm
    return nmm


def _nonuniform_rank_map(geom: _StepGeometry, x: BlockSparseTensor):
    """Logical rank map feeding ``NonuniformMatmul`` pruning: explicit
    ranks pass through; a plain mask rides as full-rank-where-present
    (``physical_rank_map`` clamps to the tile extents)."""
    if geom.a_ranks2 is not None:
        r = geom.a_ranks2
        return np.asarray(r.ranks if isinstance(r, BlockRankMap) else r)
    if geom.a_mask2 is not None:
        return np.where(geom.a_mask2, np.int32(2**30), np.int32(0))
    if x.rank_csr is not None:
        raise NotImplementedError(
            "rank_csr payloads need uniform merged tilings; densify the "
            "operand for nonuniform mode extents"
        )
    return None


def _matricized_norms(
    t: BlockSparseTensor,
    modes: tuple[str, ...],
    rows: tuple[str, ...],
    cols: tuple[str, ...],
    og: _OperandGeom,
) -> np.ndarray:
    """Per-block Frobenius norms of one operand on its *matricized* block
    grid.

    Norms are data-dependent, so they are computed here at call time and
    never stored on the structurally-cached :class:`_StepGeometry`.
    Precomputed ``norms`` grids (chain intermediates, ``rank_csr``
    payloads) matricize by the exact block reshape; dense-stored data is
    matricized host-side and reduced block by block — this also covers
    plain operands whose blocking was adopted from the partner (their own
    one-block grid would not match the merged tilings).
    """
    want = (og.row_tiling.num_blocks, og.col_tiling.num_blocks)
    if t.norms is not None or t.rank_csr is not None or t.data is None:
        n2 = matricize_mask(t.block_norms(), modes, rows, cols)
        n2 = np.asarray(n2, dtype=np.float64)
        if n2.shape != want:
            raise ValueError(
                f"norm grid {n2.shape} mismatches the matricized block "
                f"grid {want}"
            )
        return n2
    x2 = np.transpose(np.asarray(t.data), og.axes).reshape(
        og.row_tiling.extent, og.col_tiling.extent
    )
    if og.row_perm is not None:
        x2 = x2[og.row_perm]
    if og.col_perm is not None:
        x2 = x2[:, og.col_perm]
    sq = np.asarray(x2, dtype=np.float64) ** 2
    sq = np.add.reduceat(
        sq, np.asarray(og.row_tiling.offsets, np.int64), axis=0
    )
    sq = np.add.reduceat(
        sq, np.asarray(og.col_tiling.offsets, np.int64), axis=1
    )
    n2 = np.sqrt(sq)
    if t.mask is not None or t.ranks is not None:
        m2 = matricize_mask(t.block_mask, modes, rows, cols)
        if m2.shape == n2.shape:
            n2 = np.where(m2, n2, 0.0)
    return n2


def _step_norms(
    geom: _StepGeometry, x: BlockSparseTensor, y: BlockSparseTensor
) -> tuple[np.ndarray, np.ndarray]:
    """Matricized (A, B) norm grids for a ``filter_eps`` step."""
    spec = geom.spec
    an2 = _matricized_norms(
        x, spec.x_modes, spec.free_x, spec.contracted, geom.x_geom
    )
    bn2 = _matricized_norms(
        y, spec.y_modes, spec.contracted, spec.free_y, geom.y_geom
    )
    return an2, bn2


def _filtered_out_structure(
    geom: _StepGeometry,
    a_norms2: np.ndarray,
    b_norms2: np.ndarray,
    filter_eps: float,
) -> tuple[np.ndarray, np.ndarray]:
    """The *filtered* output structure of a ``filter_eps`` step.

    ``(out_mask, out_norms)`` on the output block grid: the mask keeps
    only C blocks with at least one surviving (i, k, j) addend —
    refining the symbolic ``geom.out_mask`` — and the norms are the
    propagated ``sum_k ||A_ik||.||B_kj||`` bounds over surviving
    addends.  This is what a chained step must see as its predecessor
    structure (the chain regression test pins it): the symbolic product
    alone would resurrect screened blocks.
    """
    from repro.spgemm import filter_keep, output_norms

    keep, _bound = filter_keep(a_norms2, b_norms2, filter_eps)
    cn2 = output_norms(a_norms2, b_norms2, keep)
    ckeep2 = keep.any(axis=1)
    spec = geom.spec
    grids = {
        m: t.num_blocks for m, t in zip(spec.out_modes, geom.out_tilings)
    }
    out_norms = unmatricize_mask(
        cn2, spec.free_x, spec.free_y, grids, spec.out_modes
    )
    keep_mask = unmatricize_mask(
        ckeep2, spec.free_x, spec.free_y, grids, spec.out_modes
    ).astype(bool)
    out_mask = (
        keep_mask if geom.out_mask is None else (geom.out_mask & keep_mask)
    )
    return out_mask, np.where(out_mask, out_norms, 0.0)


def _step_c_mask(geom: _StepGeometry) -> np.ndarray | None:
    """The inferred output mask worth forwarding to the planner.

    An all-live product carries no pruning information — forwarding it
    would only perturb plan digests (and recompile cached executables)
    for zero benefit, so only genuinely sparse outputs pass through."""
    cm = geom.c_mask2
    if cm is None or bool(cm.all()):
        return None
    return cm


def _plan_step(
    mm,
    geom: _StepGeometry,
    x: BlockSparseTensor,
    itemsize=4,
    *,
    a_norms2: np.ndarray | None = None,
    b_norms2: np.ndarray | None = None,
    filter_eps: float = 0.0,
):
    """The MatmulPlan this step will execute (for chain scheduling)."""
    m = geom.x_geom.row_tiling.extent
    k = geom.x_geom.col_tiling.extent
    n = geom.y_geom.col_tiling.extent
    if not geom.uniform:
        if filter_eps > 0.0:
            raise NotImplementedError(
                "filter_eps needs uniform merged tilings (the bucketized "
                "adaptation re-blocks norms ambiguously)"
            )
        nmm = _nonuniform_front_end(mm, geom)
        return nmm.plan(
            a_ranks=_nonuniform_rank_map(geom, x), itemsize=itemsize
        )
    if x.rank_csr is not None:
        return mm.plan(
            m, k, n, b_mask=geom.b_mask2, a_ranks=x.rank_csr,
            c_mask=_step_c_mask(geom), itemsize=itemsize,
            a_norms=a_norms2, b_norms=b_norms2, filter_eps=filter_eps,
        )
    a_ranks = geom.a_ranks2 if isinstance(
        geom.a_ranks2, BlockRankMap
    ) else None
    return mm.plan(
        m, k, n, a_mask=geom.a_mask2, b_mask=geom.b_mask2,
        a_ranks=a_ranks, c_mask=_step_c_mask(geom), itemsize=itemsize,
        a_norms=a_norms2, b_norms=b_norms2, filter_eps=filter_eps,
    )


def _execute_step(
    mm,
    geom: _StepGeometry,
    x: BlockSparseTensor,
    y: BlockSparseTensor,
    *,
    lookahead: int | None = None,
    tune: bool = False,
    a_norms2: np.ndarray | None = None,
    b_norms2: np.ndarray | None = None,
    filter_eps: float = 0.0,
):
    """Matricize, multiply through the planner, un-matricize."""
    import jax.numpy as jnp

    b2 = geom.y_geom.matricize(y.data)
    if not geom.uniform:
        if filter_eps > 0.0:
            raise NotImplementedError(
                "filter_eps needs uniform merged tilings"
            )
        # Bucketized path: masks are applied elementwise (exact — pad and
        # dead blocks are zero) and x's structure rides as the logical
        # rank map so screened blocks still prune the physical plan.
        a = x.data
        if x.rank_csr is not None:
            raise NotImplementedError(
                "rank_csr payloads need uniform merged tilings"
            )
        if x.mask is not None or x.ranks is not None:
            a = a * jnp.asarray(
                expand_block_mask(x.block_mask, x.tilings), a.dtype
            )
        if y.mask is not None:
            y_fine = expand_block_mask(y.block_mask, y.tilings)
            y_fine = matricize_mask_elements(y_fine, geom.y_geom)
            b2 = b2 * jnp.asarray(y_fine, b2.dtype)
        a2 = geom.x_geom.matricize(a)
        nmm = _nonuniform_front_end(mm, geom)
        c2 = nmm(
            a2, b2, a_ranks=_nonuniform_rank_map(geom, x),
            lookahead=lookahead, tune=tune,
        )
    elif x.rank_csr is not None:
        if not geom.x_geom.identity:
            raise NotImplementedError(
                f"spec {geom.spec.spec!r} transposes/permutes the "
                "rank_csr operand; factors cannot be re-laid-out — "
                "densify with rank_csr.to_dense() first"
            )
        c2 = mm(
            None, b2, a_ranks=x.rank_csr, b_mask=geom.b_mask2,
            c_mask=_step_c_mask(geom), lookahead=lookahead, tune=tune,
            a_norms=a_norms2, b_norms=b_norms2, filter_eps=filter_eps,
        )
    else:
        a2 = geom.x_geom.matricize(x.data)
        a_ranks = geom.a_ranks2 if isinstance(
            geom.a_ranks2, BlockRankMap
        ) else None
        c2 = mm(
            a2, b2,
            a_mask=geom.a_mask2 if a_ranks is None else None,
            b_mask=geom.b_mask2, a_ranks=a_ranks,
            c_mask=_step_c_mask(geom), lookahead=lookahead, tune=tune,
            a_norms=a_norms2, b_norms=b_norms2, filter_eps=filter_eps,
        )
    fx_ext, fy_ext = _free_extents(geom, x, y)
    return _unmatricize_step(c2, geom, fx_ext, fy_ext)


def _free_extents(
    geom: _StepGeometry, x: BlockSparseTensor, y: BlockSparseTensor
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    spec = geom.spec
    xt = dict(zip(spec.x_modes, x.tilings))
    yt = dict(zip(spec.y_modes, y.tilings))
    return (
        tuple(xt[m].extent for m in spec.free_x),
        tuple(yt[m].extent for m in spec.free_y),
    )


def _unmatricize_step(c2, geom: _StepGeometry, fx_ext, fy_ext):
    """Un-matricize: undo block-lex perms, split merged modes, reorder."""
    import jax.numpy as jnp

    c2 = _apply_perm(c2, geom.out_row_perm_inv, 0)
    c2 = _apply_perm(c2, geom.out_col_perm_inv, 1)
    spec = geom.spec
    c_nd = c2.reshape(fx_ext + fy_ext or (1,))
    cur = spec.free_x + spec.free_y
    if cur:
        c_nd = jnp.transpose(
            c_nd, [cur.index(m) for m in spec.out_modes]
        )
    return c_nd


def matricize_mask_elements(fine: np.ndarray, geom: _OperandGeom):
    """Element-resolution companion of ``_OperandGeom.matricize`` for
    numpy masks (transpose + reshape + block-lex perms)."""
    mt = np.transpose(fine, geom.axes)
    m2 = mt.reshape(geom.row_tiling.extent, geom.col_tiling.extent)
    if geom.row_perm is not None:
        m2 = m2[geom.row_perm]
    if geom.col_perm is not None:
        m2 = m2[:, geom.col_perm]
    return m2


# ---------------------------------------------------------------------------
# compiled step programs: one jitted executable per cached geometry
# ---------------------------------------------------------------------------


def _with_data(t: BlockSparseTensor, data) -> BlockSparseTensor:
    """Structural copy of ``t`` with ``data`` swapped in, no validation.

    Compiled step programs close over a *data-free* twin and rebuild the
    operand from the runtime array at trace time — the closure never
    captures the caller's buffers (they would be pinned for the cache
    lifetime and, worse, baked as constants on a retrace)."""
    s = BlockSparseTensor.__new__(BlockSparseTensor)
    s.data = data
    s.tilings = t.tilings
    s.mask = t.mask
    s.ranks = t.ranks
    s.rank_csr = t.rank_csr
    s.norms = t.norms
    return s


def _any_traced(*datas) -> bool:
    import jax

    return any(
        isinstance(d, jax.core.Tracer) for d in datas if d is not None
    )


def _cached_step(mm, key: tuple, build):
    """Get-or-build a compiled contraction program in ``_contract_cache``
    (hits/misses surface through ``DistributedMatmul.cache_stats``)."""
    from repro.core.summa import _autotune_key_suffix

    key = key + _autotune_key_suffix()
    cache = mm._contract_cache
    stats = getattr(mm, "_cache_stats", None)
    fn = cache.get(key)
    if fn is None:
        if stats is not None:
            stats["step_misses"] += 1
        fn = build()
        cache[key] = fn
    elif stats is not None:
        stats["step_hits"] += 1
    return fn


def _pad2(x2, shape: tuple[int, int]):
    import jax.numpy as jnp

    pads = [(0, t - d) for d, t in zip(x2.shape, shape)]
    if all(p == (0, 0) for p in pads):
        return x2
    return jnp.pad(x2, pads)


def _count_retrace(mm) -> None:
    stats = getattr(mm, "_cache_stats", None)
    if stats is not None:
        stats["step_retraces"] += 1


def _filter_key(
    filter_eps: float,
    a_norms2: np.ndarray | None,
    b_norms2: np.ndarray | None,
) -> tuple:
    """Cache-key suffix for an active norm filter.  Empty at
    ``filter_eps=0`` so unfiltered keys (and their compiled programs)
    stay bitwise identical to pre-filter ones."""
    if filter_eps <= 0.0:
        return ()
    from repro.core.sparsity import norms_key

    return (
        ("filter", float(filter_eps), norms_key(a_norms2),
         norms_key(b_norms2)),
    )


def _execute_step_compiled(
    mm,
    geom: _StepGeometry,
    x: BlockSparseTensor,
    y: BlockSparseTensor,
    *,
    lookahead: int | None = None,
    tune: bool = False,
    a_norms2: np.ndarray | None = None,
    b_norms2: np.ndarray | None = None,
    filter_eps: float = 0.0,
):
    """One cached jitted program for the whole step.

    Matricize → planned product → un-matricize runs as a single compiled
    executable keyed by the geometry's structural cache key + dtypes, so
    a repeated contraction of the same structure is one dispatch with
    zero retraces.  The planner (and for rank payloads the factor
    *layout*) runs on the host at trace time; operand arrays — including
    ``RankCSR`` factors, which a structural key must never bake in — are
    runtime arguments.  Falls back to the eager :func:`_execute_step`
    under an enclosing trace, with ``mm.compiled=False``, or when the
    front-end carries no cache.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import summa as sm

    if (
        getattr(mm, "_contract_cache", None) is None
        or geom.cache_key is None
        or not getattr(mm, "compiled", True)
        or _any_traced(x.data, y.data)
    ):
        return _execute_step(
            mm, geom, x, y, lookahead=lookahead, tune=tune,
            a_norms2=a_norms2, b_norms2=b_norms2, filter_eps=filter_eps,
        )
    fx_ext, fy_ext = _free_extents(geom, x, y)
    fkey = _filter_key(filter_eps, a_norms2, b_norms2)

    if x.rank_csr is not None:
        if not geom.x_geom.identity or not geom.uniform:
            # eager path raises the informative NotImplementedError
            return _execute_step(
                mm, geom, x, y, lookahead=lookahead, tune=tune,
                a_norms2=a_norms2, b_norms2=b_norms2,
                filter_eps=filter_eps,
            )
        m = geom.x_geom.row_tiling.extent
        k = geom.x_geom.col_tiling.extent
        n = geom.y_geom.col_tiling.extent
        plan = mm.plan(
            m, k, n, b_mask=geom.b_mask2, a_ranks=x.rank_csr,
            c_mask=_step_c_mask(geom),
            itemsize=np.dtype(y.data.dtype).itemsize, tune=tune,
            lookahead=lookahead,
            a_norms=a_norms2, b_norms=b_norms2, filter_eps=filter_eps,
        )
        (mp, kp), (_, np_) = plan.padded_shapes
        if plan.local_impl == "ranksparse":
            u_all, v_all = sm.rank_operands(x.rank_csr, plan)

            def build(plan=plan):
                def traced(u, v, yd):
                    _count_retrace(mm)
                    b_p = _pad2(geom.y_geom.matricize(yd), (kp, np_))
                    c2 = sm.execute_rank_plan(u, v, b_p, plan)[:m, :n]
                    return _unmatricize_step(c2, geom, fx_ext, fy_ext)

                return jax.jit(traced)

            key = (
                "exec_rank", geom.cache_key, str(y.data.dtype),
                lookahead, tune,
            ) + fkey
            return _cached_step(mm, key, build)(
                jnp.asarray(u_all), jnp.asarray(v_all), y.data
            )

        # factor layout does not fit the grid: densified masked-DAG
        # product; the dense twin is still a runtime operand
        def build(plan=plan):
            def traced(ad, yd):
                _count_retrace(mm)
                a_p = _pad2(ad, (mp, kp))
                b_p = _pad2(geom.y_geom.matricize(yd), (kp, np_))
                c2 = sm.execute_plan(a_p, b_p, plan)[:m, :n]
                return _unmatricize_step(c2, geom, fx_ext, fy_ext)

            return jax.jit(traced)

        key = (
            "exec_rankdense", geom.cache_key, str(y.data.dtype),
            lookahead, tune,
        ) + fkey
        return _cached_step(mm, key, build)(
            jnp.asarray(x.rank_csr.to_dense()), y.data
        )

    x_sym = _with_data(x, None)
    y_sym = _with_data(y, None)

    def build():
        def traced(xd, yd):
            _count_retrace(mm)
            return _execute_step(
                mm, geom, _with_data(x_sym, xd), _with_data(y_sym, yd),
                lookahead=lookahead, tune=tune,
                a_norms2=a_norms2, b_norms2=b_norms2,
                filter_eps=filter_eps,
            )

        return jax.jit(traced)

    key = (
        "exec_step", geom.cache_key, str(x.data.dtype), str(y.data.dtype),
        lookahead, tune,
    ) + fkey
    return _cached_step(mm, key, build)(x.data, y.data)


# ---------------------------------------------------------------------------
# the public entry points
# ---------------------------------------------------------------------------


def contract(
    spec: str,
    x,
    y,
    *,
    mm,
    tile: int = 64,
    lookahead: int | None = None,
    tune: bool = False,
    filter_eps: float = 0.0,
) -> BlockSparseTensor:
    """Binary block-sparse tensor contraction through the MatmulPlan engine.

    ``x``/``y`` are :class:`BlockSparseTensor` (plain arrays and
    ``RankCSR`` payloads are wrapped automatically); ``mm`` is the
    :class:`core.api.DistributedMatmul` supplying the mesh, strategy and
    plan cache.  Batch modes execute one matricized product per batch
    element (every slice shares one cached plan).  Returns a
    :class:`BlockSparseTensor` whose mask is *inferred* from the operand
    structure (exactly the reachable C blocks), ready to chain.

    ``filter_eps > 0`` screens (i, k, j) block products whose
    ``||X_ik||.||Y_kj||`` norm bound falls below the threshold (DBCSR's
    on-the-fly filtering): the result differs from the exact contraction
    by at most the dropped-product sum in Frobenius norm, and it carries
    the *filtered* output mask plus propagated per-block norm bounds —
    chained filtered contractions get progressively sparser.
    """
    import jax
    import jax.numpy as jnp

    x, y = _wrap(x), _wrap(y)
    pspec = parse_contraction(spec)
    if filter_eps > 0.0 and pspec.batch:
        raise NotImplementedError(
            "filter_eps with batch modes is not supported (filter the "
            "per-slice contractions instead)"
        )
    if filter_eps > 0.0 and _any_traced(x.data, y.data):
        raise ValueError(
            "filter_eps needs concrete operands: per-block norms are "
            "host planning inputs and cannot be traced"
        )
    if not pspec.batch:
        geom = _geometry_cached(mm, spec, x, y, tile)
        a_norms2 = b_norms2 = None
        if filter_eps > 0.0:
            a_norms2, b_norms2 = _step_norms(geom, x, y)
        data = _execute_step_compiled(
            mm, geom, x, y, lookahead=lookahead, tune=tune,
            a_norms2=a_norms2, b_norms2=b_norms2, filter_eps=filter_eps,
        )
        if not pspec.out_modes:  # full contraction to a scalar
            return BlockSparseTensor(
                data=data.reshape(()), tilings=(), mask=None
            )
        if filter_eps > 0.0:
            out_mask, out_norms = _filtered_out_structure(
                geom, a_norms2, b_norms2, filter_eps
            )
            return BlockSparseTensor(
                data=data, tilings=geom.out_tilings, mask=out_mask,
                norms=out_norms,
            )
        return BlockSparseTensor(
            data=data, tilings=geom.out_tilings, mask=geom.out_mask
        )

    # -- batch modes: one matricized product per batch element ---------------
    if x.rank_csr is not None:
        raise NotImplementedError("batch modes with rank_csr payloads")
    sub_spec = (
        "".join(m for m in pspec.x_modes if m not in pspec.batch)
        + ","
        + "".join(m for m in pspec.y_modes if m not in pspec.batch)
        + "->"
        + "".join(m for m in pspec.out_modes if m not in pspec.batch)
    )
    bx = [pspec.x_modes.index(m) for m in pspec.batch]
    by = [pspec.y_modes.index(m) for m in pspec.batch]
    xt = dict(zip(pspec.x_modes, x.tilings))
    yt = dict(zip(pspec.y_modes, y.tilings))
    # Batch slices index elements, but masks/ranks slice by *block* —
    # block indices come from the resolved batch tilings, so the two
    # operands must agree on them wherever block-granular structure is
    # actually sliced; a plain side adopts the structured side's
    # blocking (only extents must always match).
    x_plain = x.mask is None and x.ranks is None
    y_plain = y.mask is None and y.ranks is None
    batch_tilings = []
    for m in pspec.batch:
        if xt[m].extent != yt[m].extent:
            raise ValueError(
                f"batch mode {m!r} extents disagree between operands: "
                f"{xt[m].extent} vs {yt[m].extent}"
            )
        if xt[m].sizes == yt[m].sizes or y_plain:
            batch_tilings.append(xt[m])
        elif x_plain:
            batch_tilings.append(yt[m])
        else:
            raise ValueError(
                f"batch mode {m!r} tilings disagree between operands "
                f"({xt[m].sizes} vs {yt[m].sizes}); masked/ranked "
                "operands must block batch modes identically"
            )
    extents = [t.extent for t in batch_tilings]
    # element -> owning block per batch mode (for mask slicing)
    blk_of = [
        np.repeat(np.arange(t.num_blocks), t.sizes) for t in batch_tilings
    ]

    def _slice(t: BlockSparseTensor, baxes, idx, bblk):
        other = [i for i in range(t.ndim) if i not in baxes]
        data = jnp.asarray(t.data)
        for ax, i in sorted(zip(baxes, idx), reverse=True):
            data = jnp.take(data, i, axis=ax)
        sub_mask = sub_ranks = None
        for name in ("mask", "ranks"):
            arr = getattr(t, name)
            if arr is None:
                continue
            sl = [slice(None)] * t.ndim
            for ax, b in zip(baxes, bblk):
                sl[ax] = b
            val = arr[tuple(sl)]
            if name == "mask":
                sub_mask = val
            else:
                sub_ranks = val
        return BlockSparseTensor(
            data=data,
            tilings=tuple(t.tilings[i] for i in other),
            mask=sub_mask,
            ranks=sub_ranks,
        )

    out_free = tuple(m for m in pspec.out_modes if m not in pspec.batch)
    all_idx = list(itertools.product(*[range(e) for e in extents]))
    bblk_of_idx = [
        tuple(int(blk_of[d][i]) for d, i in enumerate(idx))
        for idx in all_idx
    ]
    slices: list = [None] * len(all_idx)
    masks: dict[tuple, np.ndarray | None] = {}
    sub_tilings = None
    compiled_ok = (
        getattr(mm, "compiled", True)
        and getattr(mm, "_contract_cache", None) is not None
        and not _any_traced(x.data, y.data)
    )
    if compiled_ok:
        # Group batch elements by block signature: every group shares one
        # sub-geometry, so the whole group runs as a *single* compiled
        # program (static-unrolled slicing + per-slice product + stack)
        # instead of a Python loop of dispatches.
        groups: dict[tuple, list] = {}
        for pos, bblk in enumerate(bblk_of_idx):
            groups.setdefault(bblk, []).append(pos)
        x_sym = _with_data(x, None)
        y_sym = _with_data(y, None)
        for bblk, positions in groups.items():
            idx0 = all_idx[positions[0]]
            sub_geom = _geometry_cached(
                mm, sub_spec,
                _slice(x, bx, idx0, bblk), _slice(y, by, idx0, bblk),
                tile,
            )
            sub_tilings = sub_geom.out_tilings
            masks[bblk] = (
                sub_geom.out_mask if sub_geom.spec.out_modes else None
            )
            sub_shape = tuple(tt.extent for tt in sub_tilings)
            group_idx = tuple(all_idx[p] for p in positions)

            def build(
                bblk=bblk, sub_geom=sub_geom, sub_shape=sub_shape,
                group_idx=group_idx,
            ):
                def traced(xd, yd):
                    _count_retrace(mm)
                    xf = _with_data(x_sym, xd)
                    yf = _with_data(y_sym, yd)
                    outs = []
                    for idx in group_idx:
                        d = _execute_step(
                            mm, sub_geom,
                            _slice(xf, bx, idx, bblk),
                            _slice(yf, by, idx, bblk),
                            lookahead=lookahead, tune=tune,
                        )
                        outs.append(d.reshape(sub_shape))
                    return jnp.stack(outs)

                return jax.jit(traced)

            key = (
                "exec_batch", sub_geom.cache_key, bblk, group_idx,
                str(x.data.dtype), str(y.data.dtype), lookahead, tune,
            )
            group_out = _cached_step(mm, key, build)(x.data, y.data)
            for j, pos in enumerate(positions):
                slices[pos] = group_out[j]
    else:
        for pos, (idx, bblk) in enumerate(zip(all_idx, bblk_of_idx)):
            xs = _slice(x, bx, idx, bblk)
            ys = _slice(y, by, idx, bblk)
            out = contract(
                sub_spec, xs, ys, mm=mm, tile=tile,
                lookahead=lookahead, tune=tune,
            )
            slices[pos] = out.data
            sub_tilings = out.tilings
            if bblk not in masks:
                masks[bblk] = out.mask
    stacked = jnp.stack(slices).reshape(
        tuple(extents) + tuple(tt.extent for tt in sub_tilings)
    )
    cur = pspec.batch + out_free
    c_nd = jnp.transpose(
        stacked, [cur.index(m) for m in pspec.out_modes]
    )
    out_mask = None
    if any(v is not None for v in masks.values()):
        bgrids = tuple(t.num_blocks for t in batch_tilings)
        free_grid = tuple(
            dict(zip(out_free, sub_tilings))[m].num_blocks
            for m in out_free
        ) if out_free else ()
        full = np.zeros(bgrids + free_grid, dtype=bool)
        for bblk, msk in masks.items():
            full[bblk] = True if msk is None else msk
        full = np.transpose(
            full, [cur.index(m) for m in pspec.out_modes]
        )
        out_mask = full
    tmap = {**dict(zip(pspec.batch, batch_tilings)),
            **dict(zip(out_free, sub_tilings))}
    return BlockSparseTensor(
        data=c_nd,
        tilings=tuple(tmap[m] for m in pspec.out_modes),
        mask=out_mask,
    )


def contract_chain(
    steps,
    *,
    mm,
    tile: int = 64,
    tune: bool = False,
    machine=None,
    trace: bool = False,
    filter_eps: float = 0.0,
):
    """Execute consecutive contractions under one *jointly scheduled* plan.

    ``steps`` is ``[(spec0, x0, y0), (spec1, y1), (spec2, y2), …]`` —
    each later step contracts the previous result (as its first operand)
    with a fresh second operand.  Before executing anything the chain is
    planned end to end: per-step ``MatmulPlan``s (operand masks propagate
    through the inferred output masks), the **union task graph** of all
    steps (``sched.taskgraph.chain_graphs``: C tiles of step *i* gate
    only the A-panel broadcasts of step *i+1* that read them — B-side
    broadcasts and early panels overlap the previous multiplication),
    and a discrete-event simulation of it.  ``tune=True`` lets
    ``sched.tuner.tune_chain`` pick the per-step multiple-issue windows
    jointly by simulated makespan; execution then honors the chosen
    windows.

    Returns ``(result, report)``: the final :class:`BlockSparseTensor`
    and a dict with the joint / sequential simulated makespans, the
    speedup, per-step lookaheads and plan summaries (and the traced
    ``SimResult`` as ``report["sim"]`` when ``trace=True``).
    """
    from repro.sched.simulator import DEFAULT_MACHINE, simulate
    from repro.sched.taskgraph import chain_graphs, from_plan
    from repro.sched.tuner import tune_chain

    machine = machine or DEFAULT_MACHINE
    if len(steps) < 2:
        raise ValueError("contract_chain needs at least two steps")
    spec0, x0, y0 = steps[0]
    norm = [(parse_contraction(spec0), _wrap(x0), _wrap(y0))]
    for item in steps[1:]:
        spec_i, y_i = item
        norm.append((parse_contraction(spec_i), None, _wrap(y_i)))
    for spec, _x, _y in norm:
        if spec.batch:
            raise NotImplementedError(
                "joint chain scheduling supports non-batch specs only"
            )
    if filter_eps > 0.0 and _any_traced(
        norm[0][1].data, *[y.data for _s, _x, y in norm]
    ):
        raise ValueError(
            "filter_eps needs concrete operands: per-block norms are "
            "host planning inputs and cannot be traced"
        )

    # -- phase 1: symbolic pass (geometry + plans, no data) -----------------
    # Under an active filter every step sees the *filtered* predecessor
    # structure: the symbolic intermediate carries the screened mask and
    # the propagated norm bounds, so step i+1's geometry / plan / norms
    # derive from what step i actually computed — not from the symbolic
    # product, which would resurrect screened blocks.
    geoms = []
    plans = []
    syms = []  # per-step symbolic outputs (filtered structure when active)
    norms_steps = []  # per-step matricized (A, B) norm grids (None pairs)
    x_cur = norm[0][1]
    for spec, _x, y in norm:
        geom = _geometry_cached(mm, spec.spec, x_cur, y, tile)
        geoms.append(geom)
        if filter_eps > 0.0:
            an2, bn2 = _step_norms(geom, x_cur, y)
            norms_steps.append((an2, bn2))
            plans.append(_plan_step(
                mm, geom, x_cur,
                a_norms2=an2, b_norms2=bn2, filter_eps=filter_eps,
            ))
            out_mask, out_norms = _filtered_out_structure(
                geom, an2, bn2, filter_eps
            )
            x_cur = _symbolic_out(geom)
            x_cur.mask = out_mask
            x_cur.norms = out_norms
        else:
            norms_steps.append((None, None))
            plans.append(_plan_step(mm, geom, x_cur))
            x_cur = _symbolic_out(geom)  # structure only; data in phase 3
        syms.append(x_cur)

    # -- phase 2: union graph, simulation, joint window tuning ---------------
    builders = [
        (lambda la, p=p: from_plan(p, lookahead=la)) for p in plans
    ]
    default_graphs = [b(None) for b in builders]
    seq_sims = [simulate(g, machine) for g in default_graphs]
    sequential = float(sum(s.makespan_s for s in seq_sims))
    tuned_record = None
    if tune:
        lookaheads, joint, tuned_record = tune_chain(
            builders, machine=machine, default_graphs=default_graphs
        )
        joint_default_s = tuned_record["default_makespan_s"]
        if trace:  # re-simulate the winner only to record spans
            joint = simulate(
                chain_graphs(
                    [b(la) for b, la in zip(builders, lookaheads)]
                ),
                machine, trace=True,
            )
    else:
        lookaheads = [g.lookahead for g in default_graphs]
        joint = simulate(chain_graphs(default_graphs), machine, trace=trace)
        joint_default_s = joint.makespan_s

    # -- phase 3: execute with the chosen per-step windows --------------------
    # The whole chain compiles into ONE program: intermediates live as
    # XLA values inside the executable (zero host round-trips between
    # steps, freed as soon as the next step consumes them).
    import jax

    x0 = norm[0][1]
    ys = [y for _spec, _x, y in norm]
    las = tuple(int(la) for la in lookaheads)
    compiled_ok = (
        getattr(mm, "compiled", True)
        and getattr(mm, "_contract_cache", None) is not None
        and all(g.cache_key is not None for g in geoms)
        and x0.rank_csr is None
        and not _any_traced(x0.data, *[y.data for y in ys])
    )
    if compiled_ok:
        x0_sym = _with_data(x0, None)
        y_syms = [_with_data(y, None) for y in ys]

        def build():
            def traced(x0d, *yds):
                _count_retrace(mm)
                x_cur = _with_data(x0_sym, x0d)
                for geom, la, y_sym, yd, sym_out, (an2, bn2) in zip(
                    geoms, las, y_syms, yds, syms, norms_steps
                ):
                    data = _execute_step(
                        mm, geom, x_cur, _with_data(y_sym, yd),
                        lookahead=la,
                        a_norms2=an2, b_norms2=bn2, filter_eps=filter_eps,
                    )
                    x_cur = _with_data(sym_out, data)
                return x_cur.data

            return jax.jit(traced)

        key = (
            "exec_chain", tuple(g.cache_key for g in geoms), las,
            str(x0.data.dtype), tuple(str(y.data.dtype) for y in ys),
        ) + tuple(
            k for an2, bn2 in norms_steps
            for k in _filter_key(filter_eps, an2, bn2)
        )
        data = _cached_step(mm, key, build)(
            x0.data, *[y.data for y in ys]
        )
        x_cur = BlockSparseTensor(
            data=data, tilings=geoms[-1].out_tilings,
            mask=syms[-1].mask, norms=syms[-1].norms,
        )
    else:
        x_cur = x0
        for y, geom, la, sym_out, (an2, bn2) in zip(
            ys, geoms, las, syms, norms_steps
        ):
            data = _execute_step_compiled(
                mm, geom, x_cur, y, lookahead=la,
                a_norms2=an2, b_norms2=bn2, filter_eps=filter_eps,
            )
            x_cur = BlockSparseTensor(
                data=data, tilings=geom.out_tilings,
                mask=sym_out.mask, norms=sym_out.norms,
            )

    report = {
        "steps": [g.spec.spec for g in geoms],
        "lookaheads": [int(la) for la in lookaheads],
        "joint_makespan_s": joint.makespan_s,
        "joint_default_makespan_s": joint_default_s,
        "sequential_makespan_s": sequential,
        "sequential_makespans_s": [s.makespan_s for s in seq_sims],
        "speedup_vs_sequential": (
            sequential / joint.makespan_s if joint.makespan_s > 0 else 1.0
        ),
        "plans": [p.summary() for p in plans],
        "tuned": tuned_record,
    }
    if filter_eps > 0.0:
        report["filter_eps"] = float(filter_eps)
        report["filter_bounds"] = [
            float(getattr(p, "filter_bound", 0.0)) for p in plans
        ]
    if trace:
        report["sim"] = joint
    return x_cur, report


def _symbolic_out(geom: _StepGeometry) -> BlockSparseTensor:
    """A data-free stand-in carrying the step's output structure (used by
    the chain's symbolic planning pass)."""
    t = BlockSparseTensor.__new__(BlockSparseTensor)
    t.data = None
    t.tilings = geom.out_tilings
    t.mask = geom.out_mask
    t.ranks = None
    t.rank_csr = None
    t.norms = None
    return t
