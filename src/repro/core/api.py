"""User-facing API for distributed (block-sparse) matrix multiplication.

``DistributedMatmul`` is a thin front-end over the ``core.plan`` planner:
every call — dense, block-sparse, one-sided mask, nonuniform — resolves
to one cached ``MatmulPlan`` (keyed by shapes + mask content + strategy)
that ``core.summa.execute_plan`` interprets.  The front-end only pads
operands to the plan's physical shapes and crops the result.
``NonuniformMatmul`` adds the bucketized expand/compact adaptation for
nonuniformly blocked matrices.  This is the object the LM stack and the
examples use.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import blocking as bk
from repro.core import summa as sm
from repro.core.plan import MatmulPlan, mask_key, plan_matmul, rank_key
from repro.core.sparsity import BlockRankMap, RankCSR

__all__ = ["DistributedMatmul", "pad_to_multiple", "NonuniformMatmul"]


def pad_to_multiple(x: jax.Array, multiples: tuple[int, ...]) -> jax.Array:
    """Zero-pad each dim of ``x`` up to the next multiple."""
    pads = []
    for dim, mult in zip(x.shape, multiples):
        target = -(-dim // mult) * mult
        pads.append((0, target - dim))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _pad_to_shape(x: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    pads = [(0, t - d) for d, t in zip(x.shape, shape)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@dataclasses.dataclass
class DistributedMatmul:
    """C = A @ B on a 2-D mesh slice, task-based SUMMA under the hood.

    Example::

        mesh = jax.make_mesh((4, 4), ("data", "model"))
        mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=8)
        c = mm(a, b)                       # dense
        c = mm(a, b, a_mask=am, b_mask=bm) # block-sparse
        c = mm(a, b, b_mask=bm)            # one-sided block structure

    Each distinct (shapes, masks, strategy) builds its ``MatmulPlan``
    once; repeated (re)traces — scanned layers, prefill vs decode shapes
    — hit the cache instead of re-deriving the schedule.
    """

    mesh: Mesh
    row_axis: str = "data"
    col_axis: str = "model"
    strategy: str = "taskbased"
    k_blocks: int | None = None
    lookahead: int | None = None
    accum_dtype: Any = jnp.float32
    local_matmul: str = "xla"
    #: dispatch cached jitted executables (core.summa / core.contract);
    #: False forces the eager interpreters everywhere (oracle baseline)
    compiled: bool = True
    _plan_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    # spec/tiling-keyed matricization geometry + compiled contraction
    # programs for core.contract
    _contract_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    _cache_stats: dict = dataclasses.field(
        default_factory=lambda: {
            "plan_hits": 0, "plan_misses": 0,
            "geom_hits": 0, "geom_misses": 0,
            "step_hits": 0, "step_misses": 0, "step_retraces": 0,
        },
        repr=False, compare=False,
    )

    def config(self, strategy: str | None = None) -> sm.SummaConfig:
        return sm.SummaConfig(
            mesh=self.mesh,
            row_axis=self.row_axis,
            col_axis=self.col_axis,
            strategy=strategy or self.strategy,  # type: ignore[arg-type]
            k_blocks=self.k_blocks,
            lookahead=self.lookahead,
            accum_dtype=self.accum_dtype,
            local_matmul=self.local_matmul,  # type: ignore[arg-type]
        )

    # -- sharding helpers ---------------------------------------------------

    def operand_shardings(self):
        spec = P(self.row_axis, self.col_axis)
        s = NamedSharding(self.mesh, spec)
        return s, s, s

    def shard(self, a: jax.Array, b: jax.Array):
        """Place (padded) operands with SUMMA shardings."""
        sa, sb, _ = self.operand_shardings()
        return jax.device_put(a, sa), jax.device_put(b, sb)

    # -- planning ------------------------------------------------------------

    def plan(
        self,
        m: int,
        k: int,
        n: int,
        *,
        a_mask: np.ndarray | None = None,
        b_mask: np.ndarray | None = None,
        a_ranks: BlockRankMap | RankCSR | None = None,
        b_ranks: BlockRankMap | RankCSR | None = None,
        c_mask: np.ndarray | None = None,
        strategy: str | None = None,
        itemsize: int = 4,
        tune: bool = False,
        lookahead: int | None = None,
        comm_mode: str = "broadcast",
        stationarity: str = "C",
        a_norms: np.ndarray | None = None,
        b_norms: np.ndarray | None = None,
        filter_eps: float = 0.0,
        k_blocks: int | None = None,
    ) -> MatmulPlan:
        """The (cached) execution plan for a (M, K) x (K, N) product.

        ``a_ranks`` (a ``BlockRankMap`` or ``RankCSR``) plans A as
        block-rank-sparse: costs/schedule follow the per-block ranks.  The
        cache key digests the *rank structure*, not factor values — two
        ``RankCSR`` with the same ranks share a plan.  ``b_ranks`` is B's
        structure (rank-aware pruning; B stays dense-stored) and
        ``c_mask`` the output block filter — the sparse x sparse planning
        inputs of ``repro.spgemm``, like ``comm_mode`` ("broadcast" |
        "pull") and ``stationarity`` ("C" | "A" | "B" | "auto").
        ``tune=True`` runs the schedule autotuner (repro.sched.tuner)
        over the plan: the cached result carries the
        simulated-makespan-optimal strategy / k_blocks / lookahead /
        comm mode instead of the static config.  ``lookahead`` pins the
        per-plan multiple-issue window explicitly (the chain scheduler
        uses this to execute jointly tuned windows); it overrides a tuned
        window.  ``a_norms`` / ``b_norms`` (per-block Frobenius norms)
        with ``filter_eps > 0`` screen small products DBCSR-style; the
        cache key digests the norm grids only when a filter is active, so
        ``filter_eps=0`` calls key (and plan) identically to norm-free
        ones.  ``k_blocks`` overrides the config's K over-decomposition;
        together with ``strategy``/``lookahead`` it lets the persistent
        plan service (``serve.plan_service``) re-apply a stored tuned
        schedule without re-running the tuner.
        """
        from repro.core.sparsity import norms_key

        rank_payload = isinstance(a_ranks, RankCSR)
        key = (
            m, k, n, mask_key(a_mask), mask_key(b_mask), rank_key(a_ranks),
            rank_payload, strategy or self.strategy, itemsize, tune,
            lookahead, rank_key(b_ranks), mask_key(c_mask), comm_mode,
            stationarity,
        )
        if k_blocks is not None:
            key = key + ("k_blocks", int(k_blocks))
        if filter_eps > 0.0:
            key = key + (
                float(filter_eps), norms_key(a_norms), norms_key(b_norms),
            )
        plan = self._plan_cache.get(key)
        if plan is None:
            self._cache_stats["plan_misses"] += 1
            rank_map = a_ranks.rank_map() if rank_payload else a_ranks
            b_rank_map = (
                b_ranks.rank_map()
                if isinstance(b_ranks, RankCSR)
                else b_ranks
            )
            cfg = self.config(strategy)
            if k_blocks is not None:
                cfg = dataclasses.replace(cfg, k_blocks=int(k_blocks))
            plan = plan_matmul(
                m, k, n, cfg,
                a_mask=a_mask, b_mask=b_mask, a_ranks=rank_map,
                b_ranks=b_rank_map, c_mask=c_mask,
                rank_payload=rank_payload, comm_mode=comm_mode,
                stationarity=stationarity, itemsize=itemsize,
                a_norms=a_norms, b_norms=b_norms, filter_eps=filter_eps,
            )
            if tune:
                from repro.sched.tuner import tune_plan  # deferred: no cycle

                plan = tune_plan(plan)
            if lookahead is not None:
                plan = dataclasses.replace(plan, lookahead=int(lookahead))
            self._plan_cache[key] = plan
        else:
            self._cache_stats["plan_hits"] += 1
        return plan

    # -- observability -------------------------------------------------------

    def cache_stats(self) -> dict:
        """Hit/miss/retrace counters for every cache on the hot path.

        ``plan``: the ``MatmulPlan`` cache on this instance.  ``contract``:
        the matricization-geometry cache (``geom_*``) and the compiled
        contraction-step programs (``step_*`` — ``step_retraces`` counts
        actual jax traces, which must equal ``step_misses`` when keys are
        stable).  ``executable``: the process-wide plan-digest-keyed
        executable cache in ``core.summa``.
        """
        s = self._cache_stats
        return {
            "plan": {
                "size": len(self._plan_cache),
                "hits": s["plan_hits"], "misses": s["plan_misses"],
            },
            "contract": {
                "size": len(self._contract_cache),
                "geom_hits": s["geom_hits"], "geom_misses": s["geom_misses"],
                "step_hits": s["step_hits"], "step_misses": s["step_misses"],
                "step_retraces": s["step_retraces"],
            },
            "executable": sm.executable_cache_stats(),
        }

    def reset_cache_stats(self) -> None:
        """Zero the counters (cache *contents* are kept)."""
        for k in self._cache_stats:
            self._cache_stats[k] = 0

    # -- call paths ----------------------------------------------------------

    def __call__(
        self,
        a: jax.Array | None,
        b: jax.Array,
        *,
        a_mask: np.ndarray | None = None,
        b_mask: np.ndarray | None = None,
        a_ranks: BlockRankMap | RankCSR | None = None,
        b_ranks: BlockRankMap | RankCSR | None = None,
        c_mask: np.ndarray | None = None,
        strategy: str | None = None,
        tune: bool = False,
        lookahead: int | None = None,
        comm_mode: str = "broadcast",
        stationarity: str = "C",
        a_norms: np.ndarray | None = None,
        b_norms: np.ndarray | None = None,
        filter_eps: float = 0.0,
    ) -> jax.Array:
        """C = A @ B.  ``a_ranks`` plans A block-rank-sparse:

        * a ``RankCSR`` supplies the factor payload — ``a`` may be
          ``None`` (A *is* the factorization) and execution multiplies the
          factors (``execute_rank_plan``), FLOPs and broadcast bytes
          following per-panel ranks;
        * a bare ``BlockRankMap`` refines the cost model / schedule only —
          ``a`` must be the dense-stored operand and execution runs the
          masked DAG over the ``rank > 0`` mask.

        SpGEMM planning inputs (``repro.spgemm``): ``b_ranks`` gives B's
        structure rank-aware (B stays dense-stored), ``c_mask`` filters
        the output block grid (dead C blocks are pruned from the schedule
        and zeroed in the result), ``comm_mode="pull"`` plans one-sided
        panel fetches, ``stationarity="auto"`` lets the comm-volume
        chooser pick the stationary operand.  ``a_norms`` / ``b_norms``
        (per-block Frobenius norm grids, e.g. ``sparsity.block_norms``)
        with ``filter_eps > 0`` drop every (i, k, j) product whose
        ``||A_ik||.||B_kj||`` bound falls below the threshold; the
        result then differs from the exact product by at most the plan's
        recorded ``filter_bound`` in Frobenius norm.
        """
        if a_mask is not None and a_ranks is not None:
            # same rule the planner enforces for the BlockRankMap path —
            # a RankCSR must not silently override an explicit mask
            raise ValueError("pass either a_mask or a_ranks for A, not both")
        if isinstance(a_ranks, RankCSR):
            if a is not None:
                # a RankCSR *is* the A operand; a dense twin would be
                # silently ignored (the factors may be a lossy truncation
                # of it) — make the caller choose one representation
                raise ValueError(
                    "pass a=None when a_ranks is a RankCSR: the "
                    "factorization is the A operand (use "
                    "RankCSR.to_dense() if you meant the dense product)"
                )
            return self._call_ranksparse(
                a_ranks, b, b_mask=b_mask, b_ranks=b_ranks, c_mask=c_mask,
                strategy=strategy, tune=tune, lookahead=lookahead,
                comm_mode=comm_mode, stationarity=stationarity,
                a_norms=a_norms, b_norms=b_norms, filter_eps=filter_eps,
            )
        if a is None:
            raise ValueError("a=None requires a_ranks to be a RankCSR")
        m, k = a.shape
        k2, n = b.shape
        if k != k2:
            raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
        plan = self.plan(
            m, k, n, a_mask=a_mask, b_mask=b_mask, a_ranks=a_ranks,
            b_ranks=b_ranks, c_mask=c_mask, strategy=strategy,
            itemsize=a.dtype.itemsize, tune=tune, lookahead=lookahead,
            comm_mode=comm_mode, stationarity=stationarity,
            a_norms=a_norms, b_norms=b_norms, filter_eps=filter_eps,
        )
        (mp, kp), (_, np_) = plan.padded_shapes
        a_p = _pad_to_shape(a, (mp, kp))
        b_p = _pad_to_shape(b, (kp, np_))
        c_p = sm.execute_plan(a_p, b_p, plan, compiled=self.compiled)
        return c_p[:m, :n]

    # -- tensor contractions -------------------------------------------------

    def contract(self, spec: str, x, y, **kwargs):
        """Einsum-style binary block-sparse tensor contraction.

        Thin delegate to :func:`core.contract.contract` with this
        instance supplying the mesh/strategy, the plan cache, and the
        spec/tiling-keyed matricization-geometry cache — repeated
        contractions of the same structure (scanned layers, chained
        steps) re-derive nothing.
        """
        from repro.core.contract import contract as _contract

        return _contract(spec, x, y, mm=self, **kwargs)

    def contract_chain(self, steps, **kwargs):
        """Jointly scheduled chain of contractions
        (:func:`core.contract.contract_chain`)."""
        from repro.core.contract import contract_chain as _chain

        return _chain(steps, mm=self, **kwargs)

    def _call_ranksparse(
        self,
        a_ranks: RankCSR,
        b: jax.Array,
        *,
        b_mask: np.ndarray | None = None,
        b_ranks: BlockRankMap | RankCSR | None = None,
        c_mask: np.ndarray | None = None,
        strategy: str | None = None,
        tune: bool = False,
        lookahead: int | None = None,
        comm_mode: str = "broadcast",
        stationarity: str = "C",
        a_norms: np.ndarray | None = None,
        b_norms: np.ndarray | None = None,
        filter_eps: float = 0.0,
    ) -> jax.Array:
        m, k = a_ranks.shape
        k2, n = b.shape
        if k != k2:
            raise ValueError(
                f"contraction mismatch {a_ranks.shape} @ {b.shape}"
            )
        if filter_eps > 0.0 and a_norms is None:
            # the factor payload carries its own norms (||A_ik||_F =
            # ||U_ik V_ik||_F computed exactly from the factors)
            from repro.core.sparsity import rank_csr_norms

            a_norms = rank_csr_norms(a_ranks)
        plan = self.plan(
            m, k, n, b_mask=b_mask, b_ranks=b_ranks, c_mask=c_mask,
            a_ranks=a_ranks, strategy=strategy,
            itemsize=b.dtype.itemsize, tune=tune, lookahead=lookahead,
            comm_mode=comm_mode, stationarity=stationarity,
            a_norms=a_norms, b_norms=b_norms, filter_eps=filter_eps,
        )
        (mp, kp), (_, np_) = plan.padded_shapes
        b_p = _pad_to_shape(b, (kp, np_))
        if plan.local_impl != "ranksparse":
            # factor layout does not fit this grid: densify and run the
            # planned masked DAG (correct, mask-level pruning only)
            a_p = _pad_to_shape(jnp.asarray(a_ranks.to_dense()), (mp, kp))
            c_p = sm.execute_plan(a_p, b_p, plan, compiled=self.compiled)
            return c_p[:m, :n]
        u_all, v_all = sm.rank_operands(a_ranks, plan)
        c_p = sm.execute_rank_plan(
            jnp.asarray(u_all), jnp.asarray(v_all), b_p, plan,
            compiled=self.compiled,
        )
        return c_p[:m, :n]


@dataclasses.dataclass
class NonuniformMatmul:
    """Matmul over *nonuniformly blocked* matrices (paper §4.1/§4.4).

    Logical nonuniform tilings are bucketed into uniform physical tiles
    (core.blocking.bucketize); operands are gathered into the padded
    physical layout (zeros in the pad), multiplied through the shared
    ``MatmulPlan`` engine, and the result is scattered back to the
    compact layout.  Zero padding is exact: pad rows/cols contribute
    nothing.

    This is the TPU-native realisation of the paper's arbitrary-block-size
    support; ``padding_waste`` quantifies the cost of the adaptation.
    """

    mm: DistributedMatmul
    row_tiling: bk.Tiling
    inner_tiling: bk.Tiling
    col_tiling: bk.Tiling
    tile: int | str = 256

    def __post_init__(self):
        if self.tile == "auto":
            # physical tile from the kernel autotune cache: pick the
            # measured-fastest square bucket (normalized per flop) that
            # the logical block sizes can fill; static 256 on a cold cache.
            from repro.kernels.autotune import preferred_tile

            max_block = max(
                max(self.row_tiling.sizes),
                max(self.inner_tiling.sizes),
                max(self.col_tiling.sizes),
            )
            self.tile = preferred_tile(max_block) or 256
        self.row_b = bk.bucketize(self.row_tiling, self.tile)
        self.inner_b = bk.bucketize(self.inner_tiling, self.tile)
        self.col_b = bk.bucketize(self.col_tiling, self.tile)

    @property
    def padding_waste(self) -> dict[str, float]:
        return {
            "rows": self.row_b.padding_waste,
            "inner": self.inner_b.padding_waste,
            "cols": self.col_b.padding_waste,
        }

    def plan(
        self,
        *,
        a_ranks: np.ndarray | None = None,
        itemsize: int = 4,
        lookahead: int | None = None,
        tune: bool = False,
    ) -> MatmulPlan:
        """The underlying uniform-tile plan for the bucketized product.

        ``a_ranks`` is a *logical* (row_blocks, inner_blocks) per-block
        rank map; see :meth:`physical_rank_map`.
        """
        return self.mm.plan(
            self.row_b.padded_extent,
            self.inner_b.padded_extent,
            self.col_b.padded_extent,
            a_ranks=(
                self.physical_rank_map(a_ranks)
                if a_ranks is not None else None
            ),
            itemsize=itemsize,
            lookahead=lookahead,
            tune=tune,
        )

    def physical_rank_map(self, logical_ranks: np.ndarray) -> BlockRankMap:
        """Expand a logical per-block rank map onto the physical tile grid.

        Every physical tile inherits its logical block's rank, clamped by
        the tile's valid extents (a submatrix cannot exceed its parent
        block's rank, nor its own dimensions).  Rank 0 means the logical
        block is screened out — its tiles are pruned like masked blocks.
        """
        ranks = np.asarray(logical_ranks, dtype=np.int32)
        want = (self.row_tiling.num_blocks, self.inner_tiling.num_blocks)
        if ranks.shape != want:
            raise ValueError(
                f"logical rank map {ranks.shape} must match the logical "
                f"block grid {want}"
            )
        bid_r = np.asarray(self.row_b.block_id)
        bid_i = np.asarray(self.inner_b.block_id)
        valid_r = np.asarray(self.row_b.valid)
        valid_i = np.asarray(self.inner_b.valid)
        phys = ranks[np.ix_(bid_r, bid_i)]
        cap = np.minimum(valid_r[:, None], valid_i[None, :])
        return BlockRankMap(
            ranks=np.minimum(phys, cap).astype(np.int32),
            bm=self.tile,
            bk=self.tile,
        )

    def _expand(self, x: jax.Array, bdim: bk.BucketedTiling, axis: int):
        idx = jnp.asarray(bdim.gather_indices())
        safe = jnp.maximum(idx, 0)
        out = jnp.take(x, safe, axis=axis)
        shape = [1, 1]
        shape[axis] = -1
        keep = (idx >= 0).reshape(shape)
        return jnp.where(keep, out, jnp.zeros((), x.dtype))

    def _compact(self, c: jax.Array):
        ridx = self.row_b.gather_indices()
        cidx = self.col_b.gather_indices()
        rsel = np.nonzero(ridx >= 0)[0]
        csel = np.nonzero(cidx >= 0)[0]
        # physical order of valid elements == logical order (blocks packed
        # in order, tiles in order within a block)
        return c[jnp.asarray(rsel)][:, jnp.asarray(csel)]

    def __call__(
        self,
        a: jax.Array,
        b: jax.Array,
        *,
        a_ranks: np.ndarray | None = None,
        lookahead: int | None = None,
        tune: bool = False,
    ) -> jax.Array:
        """``a_ranks`` (logical per-block rank map) plans A's physical
        tiles rank-sparse: rank-0 logical blocks are screened out of the
        product and the plan's costs/schedule follow the tile ranks."""
        if a.shape != (self.row_tiling.extent, self.inner_tiling.extent):
            raise ValueError(f"A shape {a.shape} mismatches tilings")
        if b.shape != (self.inner_tiling.extent, self.col_tiling.extent):
            raise ValueError(f"B shape {b.shape} mismatches tilings")
        a_p = self._expand(self._expand(a, self.row_b, 0), self.inner_b, 1)
        b_p = self._expand(self._expand(b, self.inner_b, 0), self.col_b, 1)
        c_p = self.mm(
            a_p,
            b_p,
            a_ranks=(
                self.physical_rank_map(a_ranks)
                if a_ranks is not None else None
            ),
            lookahead=lookahead,
            tune=tune,
        )
        return self._compact(c_p)
