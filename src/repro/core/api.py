"""User-facing API for distributed (block-sparse) matrix multiplication.

``DistributedMatmul`` wraps ``core.summa`` with the ergonomics a framework
needs: automatic padding to grid multiples, nonuniform-blocking support
via bucketization (core.blocking), mask plumbing, and jit-compiled call
paths.  This is the object the LM stack and the examples use.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import blocking as bk
from repro.core import summa as sm

__all__ = ["DistributedMatmul", "pad_to_multiple", "NonuniformMatmul"]


def pad_to_multiple(x: jax.Array, multiples: tuple[int, ...]) -> jax.Array:
    """Zero-pad each dim of ``x`` up to the next multiple."""
    pads = []
    for dim, mult in zip(x.shape, multiples):
        target = -(-dim // mult) * mult
        pads.append((0, target - dim))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@dataclasses.dataclass
class DistributedMatmul:
    """C = A @ B on a 2-D mesh slice, task-based SUMMA under the hood.

    Example::

        mesh = jax.make_mesh((4, 4), ("data", "model"))
        mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=8)
        c = mm(a, b)                       # dense
        c = mm(a, b, a_mask=am, b_mask=bm) # block-sparse
    """

    mesh: Mesh
    row_axis: str = "data"
    col_axis: str = "model"
    strategy: str = "taskbased"
    k_blocks: int | None = None
    lookahead: int | None = None
    accum_dtype: Any = jnp.float32
    local_matmul: str = "xla"

    def config(self) -> sm.SummaConfig:
        return sm.SummaConfig(
            mesh=self.mesh,
            row_axis=self.row_axis,
            col_axis=self.col_axis,
            strategy=self.strategy,  # type: ignore[arg-type]
            k_blocks=self.k_blocks,
            lookahead=self.lookahead,
            accum_dtype=self.accum_dtype,
            local_matmul=self.local_matmul,  # type: ignore[arg-type]
        )

    # -- sharding helpers ---------------------------------------------------

    def operand_shardings(self):
        spec = P(self.row_axis, self.col_axis)
        s = NamedSharding(self.mesh, spec)
        return s, s, s

    def shard(self, a: jax.Array, b: jax.Array):
        """Place (padded) operands with SUMMA shardings."""
        sa, sb, _ = self.operand_shardings()
        return jax.device_put(a, sa), jax.device_put(b, sb)

    # -- call paths ----------------------------------------------------------

    def __call__(
        self,
        a: jax.Array,
        b: jax.Array,
        *,
        a_mask: np.ndarray | None = None,
        b_mask: np.ndarray | None = None,
    ) -> jax.Array:
        cfg = self.config()
        m, k = a.shape
        _, n = b.shape
        kmult = int(np.lcm(cfg.p_row, cfg.p_col))
        if cfg.k_blocks:
            kmult = int(np.lcm(kmult, cfg.k_blocks))
        a_p = pad_to_multiple(a, (cfg.p_row, kmult))
        b_p = pad_to_multiple(b, (kmult, cfg.p_col))
        if a_mask is None and b_mask is None:
            c_p = sm.summa_matmul(a_p, b_p, cfg)
        else:
            if a_mask is None or b_mask is None:
                raise ValueError("provide both masks or neither")
            # pad masks to match padded shapes (pad blocks are all-zero)
            a_mask = _pad_mask(a_mask, a.shape, a_p.shape)
            b_mask = _pad_mask(b_mask, b.shape, b_p.shape)
            c_p = sm.summa_blocksparse_matmul(a_p, b_p, a_mask, b_mask, cfg)
        return c_p[:m, :n]


def _pad_mask(mask, orig_shape, padded_shape):
    """Extend a block mask to a padded array; padded blocks are zero."""
    mask = np.asarray(mask, dtype=bool)
    rb, cb = mask.shape
    br, bc = orig_shape[0] // rb, orig_shape[1] // cb
    if orig_shape[0] % rb or orig_shape[1] % cb:
        raise ValueError("mask must evenly block the original array")
    # padded array must stay block-divisible with the same block sizes
    if padded_shape[0] % br or padded_shape[1] % bc:
        raise ValueError(
            f"padded shape {padded_shape} not divisible by block ({br},{bc});"
            " choose k_blocks so padding preserves blocking"
        )
    new = np.zeros((padded_shape[0] // br, padded_shape[1] // bc), dtype=bool)
    new[:rb, :cb] = mask
    return new


@dataclasses.dataclass
class NonuniformMatmul:
    """Matmul over *nonuniformly blocked* matrices (paper §4.1/§4.4).

    Logical nonuniform tilings are bucketed into uniform physical tiles
    (core.blocking.bucketize); operands are gathered into the padded
    physical layout (zeros in the pad), multiplied with the uniform-tile
    SUMMA engine, and the result is scattered back to the compact layout.
    Zero padding is exact: pad rows/cols contribute nothing.

    This is the TPU-native realisation of the paper's arbitrary-block-size
    support; ``padding_waste`` quantifies the cost of the adaptation.
    """

    mm: DistributedMatmul
    row_tiling: bk.Tiling
    inner_tiling: bk.Tiling
    col_tiling: bk.Tiling
    tile: int = 256

    def __post_init__(self):
        self.row_b = bk.bucketize(self.row_tiling, self.tile)
        self.inner_b = bk.bucketize(self.inner_tiling, self.tile)
        self.col_b = bk.bucketize(self.col_tiling, self.tile)

    @property
    def padding_waste(self) -> dict[str, float]:
        return {
            "rows": self.row_b.padding_waste,
            "inner": self.inner_b.padding_waste,
            "cols": self.col_b.padding_waste,
        }

    def _expand(self, x: jax.Array, bdim: bk.BucketedTiling, axis: int):
        idx = jnp.asarray(bdim.gather_indices())
        safe = jnp.maximum(idx, 0)
        out = jnp.take(x, safe, axis=axis)
        shape = [1, 1]
        shape[axis] = -1
        keep = (idx >= 0).reshape(shape)
        return jnp.where(keep, out, jnp.zeros((), x.dtype))

    def _compact(self, c: jax.Array):
        ridx = self.row_b.gather_indices()
        cidx = self.col_b.gather_indices()
        rsel = np.nonzero(ridx >= 0)[0]
        csel = np.nonzero(cidx >= 0)[0]
        # physical order of valid elements == logical order (blocks packed
        # in order, tiles in order within a block)
        return c[jnp.asarray(rsel)][:, jnp.asarray(csel)]

    def __call__(self, a: jax.Array, b: jax.Array) -> jax.Array:
        if a.shape != (self.row_tiling.extent, self.inner_tiling.extent):
            raise ValueError(f"A shape {a.shape} mismatches tilings")
        if b.shape != (self.inner_tiling.extent, self.col_tiling.extent):
            raise ValueError(f"B shape {b.shape} mismatches tilings")
        a_p = self._expand(self._expand(a, self.row_b, 0), self.inner_b, 1)
        b_p = self._expand(self._expand(b, self.inner_b, 0), self.col_b, 1)
        c_p = self.mm(a_p, b_p)
        return self._compact(c_p)
