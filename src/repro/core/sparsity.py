"""Block-sparsity structure: masks, generators, and CSR-of-blocks maps.

The paper targets matrices that are "sparse in a general sense" — block
sparse with physics-driven structure (distance decay), not element sparse.
We model that with a boolean block mask over the logical block grid plus
generators for the structures named in the paper: random fill, banded
(local interactions), and exponential distance decay.

``BlockCSR`` is the scalar-prefetch-friendly layout consumed by the Pallas
block-sparse matmul kernel (kernels/bsmm.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "random_block_mask",
    "banded_block_mask",
    "decay_block_mask",
    "BlockCSR",
    "block_csr_from_mask",
    "mask_matmul_flops",
]


def random_block_mask(
    m_blocks: int, n_blocks: int, fill: float, seed: int = 0
) -> np.ndarray:
    """Uniform random block mask with expected fill-in ``fill``.

    Guarantees every block row and column has at least one nonzero so the
    product stays full-rank-ish and load stats are well defined.
    """
    if not 0.0 < fill <= 1.0:
        raise ValueError("fill must be in (0, 1]")
    rng = np.random.default_rng(seed)
    mask = rng.random((m_blocks, n_blocks)) < fill
    # ensure no empty row/col
    for i in range(m_blocks):
        if not mask[i].any():
            mask[i, rng.integers(n_blocks)] = True
    for j in range(n_blocks):
        if not mask[:, j].any():
            mask[rng.integers(m_blocks), j] = True
    return mask


def banded_block_mask(m_blocks: int, n_blocks: int, bandwidth: int) -> np.ndarray:
    """Banded structure: |i - j·(m/n)| <= bandwidth (local interactions)."""
    i = np.arange(m_blocks)[:, None]
    j = np.arange(n_blocks)[None, :]
    scale = m_blocks / n_blocks
    return np.abs(i - j * scale) <= bandwidth


def decay_block_mask(
    m_blocks: int,
    n_blocks: int,
    decay: float = 0.5,
    threshold: float = 1e-2,
) -> np.ndarray:
    """Exponential distance decay screening: keep exp(-decay·|i-j|) > thr.

    Models the operator-kernel distance decay of the paper's quantum
    chemistry motivation (§1: block-sparsity "due to the distance decay of
    the operator kernel").
    """
    i = np.arange(m_blocks)[:, None]
    j = np.arange(n_blocks)[None, :]
    scale = m_blocks / n_blocks
    dist = np.abs(i - j * scale)
    return np.exp(-decay * dist) > threshold


@dataclasses.dataclass(frozen=True)
class BlockCSR:
    """CSR over the *block* grid — the kernel-facing sparse map.

    ``row_ptr[i]:row_ptr[i+1]`` indexes ``col_idx`` with the nonzero block
    columns of block row ``i``.  ``max_row_nnz`` is the padded per-row
    iteration bound used by the static Pallas grid; rows shorter than the
    bound are padded with ``col_idx = -1`` sentinels in ``padded_cols``.
    """

    row_ptr: np.ndarray  # (M_blocks + 1,) int32
    col_idx: np.ndarray  # (nnz,) int32
    m_blocks: int
    n_blocks: int

    @property
    def nnz(self) -> int:
        return int(self.col_idx.shape[0])

    @property
    def max_row_nnz(self) -> int:
        return int(np.max(np.diff(self.row_ptr))) if self.nnz else 0

    def padded_cols(self, bound: int | None = None) -> np.ndarray:
        """(M_blocks, bound) int32, -1-padded nonzero columns per row."""
        bound = self.max_row_nnz if bound is None else bound
        out = np.full((self.m_blocks, bound), -1, dtype=np.int32)
        for i in range(self.m_blocks):
            cols = self.col_idx[self.row_ptr[i] : self.row_ptr[i + 1]]
            out[i, : len(cols)] = cols
        return out

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.row_ptr).astype(np.int32)

    def to_dense(self) -> np.ndarray:
        mask = np.zeros((self.m_blocks, self.n_blocks), dtype=bool)
        for i in range(self.m_blocks):
            mask[i, self.col_idx[self.row_ptr[i] : self.row_ptr[i + 1]]] = True
        return mask


def block_csr_from_mask(mask: np.ndarray) -> BlockCSR:
    mask = np.asarray(mask, dtype=bool)
    m_blocks, n_blocks = mask.shape
    row_ptr = np.zeros(m_blocks + 1, dtype=np.int32)
    cols: list[int] = []
    for i in range(m_blocks):
        nz = np.nonzero(mask[i])[0]
        cols.extend(int(c) for c in nz)
        row_ptr[i + 1] = len(cols)
    return BlockCSR(
        row_ptr=row_ptr,
        col_idx=np.asarray(cols, dtype=np.int32),
        m_blocks=m_blocks,
        n_blocks=n_blocks,
    )


def mask_matmul_flops(
    a_mask: np.ndarray, b_mask: np.ndarray, bm: int, bk: int, bn: int
) -> tuple[int, int]:
    """(sparse_flops, dense_flops) for C = A·B with uniform block sizes.

    A useful-work accounting used by benchmarks: a C block (i,j) needs a
    multiply for every k with A[i,k] and B[k,j] both nonzero.
    """
    a = np.asarray(a_mask, dtype=np.int64)
    b = np.asarray(b_mask, dtype=np.int64)
    pair_count = int((a @ b).sum())  # number of (i,k,j) nonzero triples
    sparse = 2 * pair_count * bm * bk * bn
    dense = 2 * a.shape[0] * a.shape[1] * b.shape[1] * bm * bk * bn
    return sparse, dense
