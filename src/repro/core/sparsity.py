"""Block-sparsity structure: masks, ranks, generators, and CSR-of-blocks.

The paper targets matrices that are "sparse in a general sense" — block
sparse with physics-driven structure (distance decay), not element sparse.
We model that with a boolean block mask over the logical block grid plus
generators for the structures named in the paper: random fill, banded
(local interactions), and exponential distance decay.

Its sequel (*Scalable Task-Based Algorithm for Multiplication of
Block-Rank-Sparse Matrices*, Calvin/Lewis/Valeev 2015) refines
present/absent blocks into **block-rank sparsity**: each surviving block
carries a numerical rank ``r`` and is stored factorized as ``U (bm x r)``
times ``V (r x bk)``, so a gemm task's cost follows the block's rank, not
its area.  ``BlockRankMap`` is the static rank structure, ``RankCSR`` the
factorized storage (CSR over blocks + stacked U/V panels).

``BlockCSR`` is the scalar-prefetch-friendly layout consumed by the Pallas
block-sparse matmul kernel (kernels/bsmm.py); ``RankCSR`` is consumed by
the rank-sparse executor (core/summa.py::_exec_ranksparse) and the
grouped-gemm local kernel (kernels/ops.py::ranksparse_matmul).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "random_block_mask",
    "banded_block_mask",
    "block_diag_block_mask",
    "decay_block_mask",
    "BlockCSR",
    "block_csr_from_mask",
    "mask_matmul_flops",
    "BlockRankMap",
    "RankCSR",
    "decay_rank_map",
    "random_rank_map",
    "rank_csr_from_dense",
    "synthesize_rank_csr",
    "block_rank_flops",
    "rank_panel_flops",
    "rank_panel_factored_comm",
    "rank_panel_factored_compute",
    "rank_matmul_flops",
    "block_norms",
    "rank_csr_norms",
    "norms_key",
]


def random_block_mask(
    m_blocks: int, n_blocks: int, fill: float, seed: int = 0
) -> np.ndarray:
    """Uniform random block mask with expected fill-in ``fill``.

    Guarantees every block row and column has at least one nonzero so the
    product stays full-rank-ish and load stats are well defined, and
    clamps the realized fill so the coverage fix-up cannot silently push
    it far past the request: surplus blocks are removed unless they are
    the sole support of their row or column, so ``mask.sum() <=
    max(ceil(fill * size), m_blocks + n_blocks)`` is guaranteed (every
    surviving surplus block uniquely covers a row or a column), and the
    typical realized count is ``max(ceil(fill * size), max(m_blocks,
    n_blocks))`` — previously a 1 x n grid at tiny fill came back dense.
    """
    if not 0.0 < fill <= 1.0:
        raise ValueError("fill must be in (0, 1]")
    rng = np.random.default_rng(seed)
    mask = rng.random((m_blocks, n_blocks)) < fill
    # ensure no empty row/col
    for i in range(m_blocks):
        if not mask[i].any():
            mask[i, rng.integers(n_blocks)] = True
    for j in range(n_blocks):
        if not mask[:, j].any():
            mask[rng.integers(m_blocks), j] = True
    # Clamp: on tiny grids / low fills the fix-up above (and Bernoulli
    # variance) can overshoot the request.  Remove surplus blocks that are
    # not the sole support of their row or column, in random order.
    target = max(
        math.ceil(fill * m_blocks * n_blocks), max(m_blocks, n_blocks)
    )
    surplus = int(mask.sum()) - target
    if surplus > 0:
        row_nnz = mask.sum(axis=1)
        col_nnz = mask.sum(axis=0)
        cand = np.argwhere(mask)
        for i, j in cand[rng.permutation(len(cand))]:
            if surplus <= 0:
                break
            if row_nnz[i] > 1 and col_nnz[j] > 1:
                mask[i, j] = False
                row_nnz[i] -= 1
                col_nnz[j] -= 1
                surplus -= 1
    return mask


def banded_block_mask(m_blocks: int, n_blocks: int, bandwidth: int) -> np.ndarray:
    """Banded structure: |i - j·(m/n)| <= bandwidth (local interactions)."""
    i = np.arange(m_blocks)[:, None]
    j = np.arange(n_blocks)[None, :]
    scale = m_blocks / n_blocks
    return np.abs(i - j * scale) <= bandwidth


def block_diag_block_mask(m_blocks: int, n_blocks: int) -> np.ndarray:
    """Block-diagonal structure: block (i, j) lives iff it sits on the
    (scaled) diagonal — the disconnected-fragment limit of a banded mask
    (``bandwidth=0``), named separately because SpGEMM products of two
    block-diagonal operands stay block-diagonal (closed under the
    symbolic product, the sharpest output-structure pruning case)."""
    return banded_block_mask(m_blocks, n_blocks, 0)


def _decay_factors(
    m_blocks: int, n_blocks: int, decay: float, threshold: float
) -> np.ndarray:
    """Validated exp(-decay·dist) grid shared by the decay mask and the
    decay rank map, so the two generators can never screen differently
    for the same parameters."""
    if m_blocks < 1 or n_blocks < 1:
        raise ValueError(
            f"block grid must be at least 1x1, got {m_blocks}x{n_blocks}"
        )
    if decay <= 0.0:
        raise ValueError(
            f"decay must be > 0 (got {decay}); non-positive decay never "
            "screens any block — use a dense (mask-free) product instead"
        )
    if not 0.0 < threshold < 1.0:
        raise ValueError(
            f"threshold must be in (0, 1) (got {threshold}); blocks are "
            "kept while exp(-decay*dist) > threshold, so threshold >= 1 "
            "keeps nothing and threshold <= 0 screens nothing"
        )
    i = np.arange(m_blocks)[:, None]
    j = np.arange(n_blocks)[None, :]
    scale = m_blocks / n_blocks
    return np.exp(-decay * np.abs(i - j * scale))


def decay_block_mask(
    m_blocks: int,
    n_blocks: int,
    decay: float = 0.5,
    threshold: float = 1e-2,
) -> np.ndarray:
    """Exponential distance decay screening: keep exp(-decay·|i-j|) > thr.

    Models the operator-kernel distance decay of the paper's quantum
    chemistry motivation (§1: block-sparsity "due to the distance decay of
    the operator kernel").
    """
    return _decay_factors(m_blocks, n_blocks, decay, threshold) > threshold


@dataclasses.dataclass(frozen=True)
class BlockCSR:
    """CSR over the *block* grid — the kernel-facing sparse map.

    ``row_ptr[i]:row_ptr[i+1]`` indexes ``col_idx`` with the nonzero block
    columns of block row ``i``.  ``max_row_nnz`` is the padded per-row
    iteration bound used by the static Pallas grid; rows shorter than the
    bound are padded with ``col_idx = -1`` sentinels in ``padded_cols``.
    """

    row_ptr: np.ndarray  # (M_blocks + 1,) int32
    col_idx: np.ndarray  # (nnz,) int32
    m_blocks: int
    n_blocks: int

    @property
    def nnz(self) -> int:
        return int(self.col_idx.shape[0])

    @property
    def max_row_nnz(self) -> int:
        return int(np.max(np.diff(self.row_ptr))) if self.nnz else 0

    def padded_cols(self, bound: int | None = None) -> np.ndarray:
        """(M_blocks, bound) int32, -1-padded nonzero columns per row."""
        bound = self.max_row_nnz if bound is None else bound
        out = np.full((self.m_blocks, bound), -1, dtype=np.int32)
        for i in range(self.m_blocks):
            cols = self.col_idx[self.row_ptr[i] : self.row_ptr[i + 1]]
            out[i, : len(cols)] = cols
        return out

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.row_ptr).astype(np.int32)

    def to_dense(self) -> np.ndarray:
        mask = np.zeros((self.m_blocks, self.n_blocks), dtype=bool)
        for i in range(self.m_blocks):
            mask[i, self.col_idx[self.row_ptr[i] : self.row_ptr[i + 1]]] = True
        return mask


def block_csr_from_mask(mask: np.ndarray) -> BlockCSR:
    mask = np.asarray(mask, dtype=bool)
    m_blocks, n_blocks = mask.shape
    row_ptr = np.zeros(m_blocks + 1, dtype=np.int32)
    cols: list[int] = []
    for i in range(m_blocks):
        nz = np.nonzero(mask[i])[0]
        cols.extend(int(c) for c in nz)
        row_ptr[i + 1] = len(cols)
    return BlockCSR(
        row_ptr=row_ptr,
        col_idx=np.asarray(cols, dtype=np.int32),
        m_blocks=m_blocks,
        n_blocks=n_blocks,
    )


def mask_matmul_flops(
    a_mask: np.ndarray, b_mask: np.ndarray, bm: int, bk: int, bn: int
) -> tuple[int, int]:
    """(sparse_flops, dense_flops) for C = A·B with uniform block sizes.

    A useful-work accounting used by benchmarks: a C block (i,j) needs a
    multiply for every k with A[i,k] and B[k,j] both nonzero.
    """
    a = np.asarray(a_mask, dtype=np.int64)
    b = np.asarray(b_mask, dtype=np.int64)
    pair_count = int((a @ b).sum())  # number of (i,k,j) nonzero triples
    sparse = 2 * pair_count * bm * bk * bn
    dense = 2 * a.shape[0] * a.shape[1] * b.shape[1] * bm * bk * bn
    return sparse, dense


# ---------------------------------------------------------------------------
# Block-rank sparsity (the sequel's refinement: low-rank *within* blocks)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockRankMap:
    """Static per-block numerical ranks over a uniform block grid.

    ``ranks[i, j]`` is the rank of block (i, j) of an (m_blocks*bm,
    k_blocks*bk) matrix; 0 means the block is screened out entirely (the
    plain block-sparse mask is the ``rank > 0`` special case with rank ==
    min(bm, bk)).  Ranks never exceed ``min(bm, bk)``.
    """

    ranks: np.ndarray  # (m_blocks, k_blocks) int32, 0 = absent block
    bm: int  # block row extent
    bk: int  # block column extent

    def __post_init__(self):
        ranks = np.asarray(self.ranks, dtype=np.int32)
        if ranks.ndim != 2:
            raise ValueError(f"ranks must be 2-D, got shape {ranks.shape}")
        if self.bm < 1 or self.bk < 1:
            raise ValueError(f"block extents must be >= 1, got ({self.bm},{self.bk})")
        cap = min(self.bm, self.bk)
        if (ranks < 0).any() or (ranks > cap).any():
            raise ValueError(
                f"ranks must lie in [0, min(bm, bk)={cap}]; got "
                f"[{int(ranks.min())}, {int(ranks.max())}]"
            )
        object.__setattr__(self, "ranks", ranks)

    @property
    def m_blocks(self) -> int:
        return int(self.ranks.shape[0])

    @property
    def k_blocks(self) -> int:
        return int(self.ranks.shape[1])

    @property
    def shape(self) -> tuple[int, int]:
        return (self.m_blocks * self.bm, self.k_blocks * self.bk)

    @property
    def mask(self) -> np.ndarray:
        """The present/absent block mask this rank structure refines."""
        return self.ranks > 0

    @property
    def max_rank(self) -> int:
        return int(self.ranks.max()) if self.ranks.size else 0

    @property
    def mean_rank(self) -> float:
        """Average rank over the *present* blocks (0 if none)."""
        nz = self.ranks[self.ranks > 0]
        return float(nz.mean()) if nz.size else 0.0


def decay_rank_map(
    m_blocks: int,
    k_blocks: int,
    bm: int,
    bk: int,
    *,
    max_rank: int | None = None,
    decay: float = 0.5,
    threshold: float = 1e-2,
) -> BlockRankMap:
    """Decay-structured ranks: r[i,j] ~ max_rank·exp(-decay·|i-j|).

    The rank analogue of :func:`decay_block_mask` — near-diagonal blocks
    are (nearly) full rank, far blocks decay smoothly and are screened out
    entirely once the decay factor drops below ``threshold``.  This is the
    structure operator kernels with distance decay produce after SVD
    truncation of each block.  Screening (``rank == 0``) coincides with
    :func:`decay_block_mask` for the same parameters by construction.
    """
    cap = min(bm, bk)
    max_rank = cap if max_rank is None else int(max_rank)
    if not 1 <= max_rank <= cap:
        raise ValueError(
            f"max_rank must be in [1, min(bm, bk)={cap}], got {max_rank}"
        )
    factor = _decay_factors(m_blocks, k_blocks, decay, threshold)
    ranks = np.where(
        factor > threshold,
        np.maximum(1, np.ceil(max_rank * factor)).astype(np.int32),
        np.int32(0),
    )
    return BlockRankMap(ranks=ranks, bm=bm, bk=bk)


def random_rank_map(
    m_blocks: int,
    k_blocks: int,
    bm: int,
    bk: int,
    fill: float,
    *,
    max_rank: int | None = None,
    seed: int = 0,
) -> BlockRankMap:
    """Random block mask with uniform random ranks in [1, max_rank]."""
    cap = min(bm, bk)
    max_rank = cap if max_rank is None else int(max_rank)
    if not 1 <= max_rank <= cap:
        raise ValueError(
            f"max_rank must be in [1, min(bm, bk)={cap}], got {max_rank}"
        )
    mask = random_block_mask(m_blocks, k_blocks, fill, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ranks = rng.integers(1, max_rank + 1, size=mask.shape, dtype=np.int32)
    return BlockRankMap(ranks=np.where(mask, ranks, 0), bm=bm, bk=bk)


def _pad_up(x: int, mult: int) -> int:
    return max(mult, -(-x // mult) * mult)


@dataclasses.dataclass(frozen=True)
class RankCSR:
    """Factorized block-rank-sparse storage: block CSR + stacked U/V panels.

    Block ``s`` of the CSR (block row ``i``, block column ``csr.col_idx[s]``)
    is stored as ``u[s] (bm x r_pad)`` times ``v[s] (r_pad x bk)`` with true
    rank ``ranks[s]``; factor columns/rows beyond the true rank are zero,
    so padded multiplication is exact.  ``r_pad`` is uniform across blocks
    (a multiple of 8 — the TPU f32 sublane — so factor panels tile
    cleanly); raggedness in the true ranks is carried by ``ranks`` and
    exploited by the per-panel widths of the rank-sparse executor and the
    grouped-gemm local kernel.
    """

    csr: BlockCSR
    ranks: np.ndarray  # (nnz,) int32 true rank per stored block
    u: np.ndarray  # (nnz, bm, r_pad) float32
    v: np.ndarray  # (nnz, r_pad, bk) float32
    bm: int
    bk: int

    def __post_init__(self):
        nnz = self.csr.nnz
        if self.ranks.shape != (nnz,):
            raise ValueError(f"ranks shape {self.ranks.shape} != ({nnz},)")
        if self.u.shape[:2] != (nnz, self.bm) or self.v.shape[0] != nnz:
            raise ValueError(
                f"factor shapes {self.u.shape}/{self.v.shape} do not match "
                f"nnz={nnz}, bm={self.bm}, bk={self.bk}"
            )
        if self.u.shape[2] != self.v.shape[1] or self.v.shape[2] != self.bk:
            raise ValueError(
                f"factor shapes {self.u.shape}/{self.v.shape} disagree on "
                f"r_pad/bk"
            )

    @property
    def nnz(self) -> int:
        return self.csr.nnz

    @property
    def r_pad(self) -> int:
        return int(self.u.shape[2])

    @property
    def shape(self) -> tuple[int, int]:
        return (self.csr.m_blocks * self.bm, self.csr.n_blocks * self.bk)

    def rank_map(self) -> BlockRankMap:
        """The static rank structure (dense grid of per-block ranks).
        Memoized — the instance is frozen, and plan-cache lookups call
        this on every matmul invocation."""
        cached = self.__dict__.get("_rank_map")
        if cached is None:
            ranks = np.zeros((self.csr.m_blocks, self.csr.n_blocks), np.int32)
            for i in range(self.csr.m_blocks):
                lo, hi = self.csr.row_ptr[i], self.csr.row_ptr[i + 1]
                ranks[i, self.csr.col_idx[lo:hi]] = self.ranks[lo:hi]
            cached = BlockRankMap(ranks=ranks, bm=self.bm, bk=self.bk)
            self.__dict__["_rank_map"] = cached
        return cached

    def to_dense(self) -> np.ndarray:
        """Reconstruct the dense-stored matrix (oracle / fallback path)."""
        m, k = self.shape
        out = np.zeros((m, k), dtype=self.u.dtype)
        for i in range(self.csr.m_blocks):
            lo, hi = self.csr.row_ptr[i], self.csr.row_ptr[i + 1]
            for s in range(lo, hi):
                j = int(self.csr.col_idx[s])
                out[i * self.bm : (i + 1) * self.bm,
                    j * self.bk : (j + 1) * self.bk] = self.u[s] @ self.v[s]
        return out


def rank_csr_from_dense(
    a: np.ndarray,
    bm: int,
    bk: int,
    *,
    tol: float = 1e-6,
    max_rank: int | None = None,
    pad_to: int = 8,
) -> RankCSR:
    """SVD-truncate each (bm, bk) block of ``a`` into a :class:`RankCSR`.

    A block keeps the singular values above ``tol`` times the matrix's
    largest singular value (and at most ``max_rank`` of them); blocks with
    no surviving singular value are absent from the structure.  The square
    roots of the singular values are folded into both factors so ``u`` and
    ``v`` stay balanced in magnitude.
    """
    a = np.asarray(a, dtype=np.float32)
    m, k = a.shape
    if m % bm or k % bk:
        raise ValueError(f"matrix {a.shape} not divisible by block ({bm},{bk})")
    cap = min(bm, bk)
    max_rank = cap if max_rank is None else min(int(max_rank), cap)
    m_blocks, k_blocks = m // bm, k // bk
    svds = {}
    s_max = 0.0
    for i in range(m_blocks):
        for j in range(k_blocks):
            blk = a[i * bm : (i + 1) * bm, j * bk : (j + 1) * bk]
            uu, ss, vt = np.linalg.svd(blk, full_matrices=False)
            svds[i, j] = (uu, ss, vt)
            if ss.size:
                s_max = max(s_max, float(ss[0]))
    cut = tol * s_max
    ranks_grid = np.zeros((m_blocks, k_blocks), np.int32)
    for (i, j), (_, ss, _) in svds.items():
        ranks_grid[i, j] = min(int((ss > cut).sum()), max_rank)
    csr = block_csr_from_mask(ranks_grid > 0)
    nnz = csr.nnz
    ranks = np.zeros(nnz, np.int32)
    r_pad = _pad_up(int(ranks_grid.max()) if nnz else 1, pad_to)
    u = np.zeros((nnz, bm, r_pad), np.float32)
    v = np.zeros((nnz, r_pad, bk), np.float32)
    for i in range(m_blocks):
        lo, hi = csr.row_ptr[i], csr.row_ptr[i + 1]
        for s in range(lo, hi):
            j = int(csr.col_idx[s])
            uu, ss, vt = svds[i, j]
            r = int(ranks_grid[i, j])
            ranks[s] = r
            root = np.sqrt(ss[:r])
            u[s, :, :r] = uu[:, :r] * root
            v[s, :r, :] = root[:, None] * vt[:r, :]
    return RankCSR(csr=csr, ranks=ranks, u=u, v=v, bm=bm, bk=bk)


def synthesize_rank_csr(
    rank_map: BlockRankMap, *, seed: int = 0, pad_to: int = 8
) -> RankCSR:
    """Random factorized matrix with *exactly* the given per-block ranks.

    Factors are drawn i.i.d. normal and scaled by 1/sqrt(r·bk) so block
    magnitudes stay O(1) regardless of rank — the synthetic workload the
    rank-sparsity benchmarks and the differential oracle sweep use.
    """
    rng = np.random.default_rng(seed)
    csr = block_csr_from_mask(rank_map.mask)
    nnz = csr.nnz
    bm, bk = rank_map.bm, rank_map.bk
    r_pad = _pad_up(rank_map.max_rank if nnz else 1, pad_to)
    ranks = np.zeros(nnz, np.int32)
    u = np.zeros((nnz, bm, r_pad), np.float32)
    v = np.zeros((nnz, r_pad, bk), np.float32)
    for i in range(rank_map.m_blocks):
        lo, hi = csr.row_ptr[i], csr.row_ptr[i + 1]
        for s in range(lo, hi):
            j = int(csr.col_idx[s])
            r = int(rank_map.ranks[i, j])
            ranks[s] = r
            scale = 1.0 / np.sqrt(r * bk)
            u[s, :, :r] = rng.normal(size=(bm, r)) * scale
            v[s, :r, :] = rng.normal(size=(r, bk))
    return RankCSR(csr=csr, ranks=ranks, u=u, v=v, bm=bm, bk=bk)


# ---------------------------------------------------------------------------
# Per-block Frobenius norms (DBCSR-style on-the-fly filtering support)
# ---------------------------------------------------------------------------


def block_norms(
    a: np.ndarray, m_blocks: int, k_blocks: int, *, mask: np.ndarray | None = None
) -> np.ndarray:
    """Per-block Frobenius norms of a dense-stored matrix.

    Returns an (m_blocks, k_blocks) float64 grid with ``norms[i, k] =
    ||A_ik||_F``; blocks outside ``mask`` (when given) are exactly 0 so a
    norm grid always refines its block mask (``norms > 0`` implies the
    mask).  This is the payload the DBCSR-style product filter
    (``plan_matmul(filter_eps=...)``) screens against: a gemm task (i, k,
    j) contributes at most ``||A_ik||_F * ||B_kj||_F`` to ``||C_ij||_F``
    (submultiplicativity of the Frobenius norm), so dropping every triple
    whose bound falls below threshold perturbs C by at most the *sum* of
    the dropped bounds — the additive error bound the planner records.
    """
    a = np.asarray(a, dtype=np.float64)
    m, k = a.shape
    if m % m_blocks or k % k_blocks:
        raise ValueError(
            f"matrix {a.shape} not divisible by block grid "
            f"({m_blocks},{k_blocks})"
        )
    bm, bk = m // m_blocks, k // k_blocks
    sq = a.reshape(m_blocks, bm, k_blocks, bk) ** 2
    norms = np.sqrt(sq.sum(axis=(1, 3)))
    if mask is not None:
        norms = np.where(np.asarray(mask, bool), norms, 0.0)
    return norms


def rank_csr_norms(rk: RankCSR) -> np.ndarray:
    """Per-block Frobenius norms of a factorized :class:`RankCSR`.

    ``||U_s V_s||_F^2 = <U_s^T U_s, V_s V_s^T>`` (trace of the product of
    the two r x r Grams), so the norms come out of r-sized contractions
    without reconstructing any bm x bk block.  Absent blocks are 0, same
    contract as :func:`block_norms`.
    """
    norms = np.zeros((rk.csr.m_blocks, rk.csr.n_blocks), np.float64)
    if rk.nnz:
        u = np.asarray(rk.u, np.float64)
        v = np.asarray(rk.v, np.float64)
        gram_u = np.einsum("smr,smt->srt", u, u)  # (nnz, r_pad, r_pad)
        gram_v = np.einsum("srk,stk->srt", v, v)
        sq = np.einsum("srt,srt->s", gram_u, gram_v)
        vals = np.sqrt(np.maximum(sq, 0.0))
        for i in range(rk.csr.m_blocks):
            lo, hi = rk.csr.row_ptr[i], rk.csr.row_ptr[i + 1]
            norms[i, rk.csr.col_idx[lo:hi]] = vals[lo:hi]
    return norms


def norms_key(norms: np.ndarray | None) -> str | None:
    """Stable content digest of a norm grid (plan-cache key component)."""
    if norms is None:
        return None
    import hashlib

    arr = np.ascontiguousarray(np.asarray(norms, np.float64))
    h = hashlib.sha1()
    h.update(str(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


#: executed-efficiency margin for the factored-compute decision: the
#: two-stage skinny-gemm pipeline sustains a lower fraction of peak than
#: one fused dense dot, so factored compute must win by this factor on
#: modeled FLOPs before the executor picks it (measured ~0.7-0.9 of dense
#: efficiency on CPU BLAS and MXU-tiled shapes; 0.85 flips only the
#: near-threshold panels).
RANK_COMPUTE_MARGIN = 0.85


def rank_panel_flops(
    r: int, bm: int, bk: int, bn: int
) -> tuple[float, float]:
    """(factored, densified) modeled FLOPs per block row of a width-``r``
    factor panel: factored ``U @ (V @ B)`` vs reconstruct-then-dense-dot."""
    factored = 2.0 * r * (bm + bk) * bn
    densified = 2.0 * bm * r * bk + 2.0 * bm * bk * bn
    return factored, densified


def rank_panel_factored_comm(r: int, bm: int, bk: int) -> bool:
    """Broadcast factors instead of the dense panel?  Pure bytes: a
    width-``r`` factor panel moves r·(bm+bk) elements per block row where
    the dense panel moves bm·bk — crossover at r* = bm·bk/(bm+bk).
    Shared by the planner's comm model, the task graph, and the executor.
    """
    return r * (bm + bk) < bm * bk


def rank_panel_factored_compute(r: int, bm: int, bk: int, bn: int) -> bool:
    """Run the factored two-stage contraction instead of a dense dot?
    FLOPs comparison with the ``RANK_COMPUTE_MARGIN`` efficiency factor.
    A panel can broadcast factors yet compute densely (receiver-side
    reconstruction) — the two decisions are independent."""
    factored, densified = rank_panel_flops(r, bm, bk, bn)
    return factored <= RANK_COMPUTE_MARGIN * densified


def block_rank_flops(r: int, bm: int, bk: int, bn: int) -> float:
    """Modeled FLOPs of one rank-``r`` block gemm against a (bk, bn) panel.

    The factored evaluation ``U @ (V @ B)`` costs ``2·r·bk·bn +
    2·bm·r·bn``; a block is executed densely (reconstruct-free, dense-
    stored operand) at ``2·bm·bk·bn`` when that is cheaper — the per-block
    ordering choice the rank-sparse executor makes per panel.
    """
    if r <= 0:
        return 0.0
    return float(min(2.0 * r * (bm + bk) * bn, 2.0 * bm * bk * bn))


def rank_matmul_flops(
    rank_map: BlockRankMap, b_mask: np.ndarray, bn: int
) -> tuple[float, int, int]:
    """(rank_flops, mask_flops, dense_flops) for C = A·B with A rank-sparse.

    ``rank_flops`` charges each live (i, k, j) triple the factored block
    cost (:func:`block_rank_flops`); ``mask_flops``/``dense_flops`` are the
    mask-only and dense accountings of :func:`mask_matmul_flops` for the
    same structure — the three-way comparison the benchmarks report.
    """
    b = np.asarray(b_mask, dtype=np.int64)
    if b.shape[0] != rank_map.k_blocks:
        raise ValueError(
            f"B row-blocks {b.shape[0]} != A col-blocks {rank_map.k_blocks}"
        )
    bm, bk = rank_map.bm, rank_map.bk
    # per-(i,k) factored cost, times the number of live j's for that k
    live_j = b.sum(axis=1)  # (k_blocks,)
    per_block = np.minimum(
        2.0 * rank_map.ranks * (bm + bk) * bn,
        2.0 * bm * bk * bn,
    ) * (rank_map.ranks > 0)
    rank_flops = float((per_block * live_j[None, :]).sum())
    mask_flops, dense_flops = mask_matmul_flops(
        rank_map.mask, b > 0, bm, bk, bn
    )
    return rank_flops, mask_flops, dense_flops
