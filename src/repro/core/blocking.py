"""Tilings: uniform and nonuniform blockings of matrix dimensions.

This module reproduces the paper's data model: a matrix dimension is split
into logical blocks (possibly nonuniform, "physics-driven" sizes), blocks
are embedded cyclically onto a process grid, and — because TPUs need
uniform tiles — nonuniform logical blocks are *bucketed* into padded
uniform physical tiles with validity metadata.

Also implements the paper's §4.1 nonuniform block generation procedure and
§4.4 / Table 1 load-variability statistics.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "Tiling",
    "uniform_tiling",
    "nonuniform_tiling",
    "paper_nonuniform_sizes",
    "cyclic_owner",
    "load_stats",
    "LoadStats",
    "bucketize",
    "BucketedTiling",
]


@dataclasses.dataclass(frozen=True)
class Tiling:
    """A blocking of one matrix dimension into contiguous logical blocks."""

    sizes: tuple[int, ...]  # size of each logical block, in elements

    def __post_init__(self):
        if len(self.sizes) == 0:
            raise ValueError("Tiling must have at least one block")
        if any(s <= 0 for s in self.sizes):
            raise ValueError(f"block sizes must be positive, got {self.sizes}")

    @property
    def extent(self) -> int:
        return int(sum(self.sizes))

    @property
    def num_blocks(self) -> int:
        return len(self.sizes)

    @property
    def offsets(self) -> tuple[int, ...]:
        """Start offset of each block."""
        return tuple(np.concatenate([[0], np.cumsum(self.sizes)[:-1]]).tolist())

    @property
    def is_uniform(self) -> bool:
        return len(set(self.sizes)) == 1

    def block_of(self, index: int) -> int:
        """Logical block containing element ``index``."""
        if not 0 <= index < self.extent:
            raise IndexError(index)
        return int(np.searchsorted(np.cumsum(self.sizes), index, side="right"))


def uniform_tiling(extent: int, block: int) -> Tiling:
    """Uniform blocking; last block may be ragged if ``block`` ∤ ``extent``."""
    if extent <= 0 or block <= 0:
        raise ValueError("extent and block must be positive")
    full, rem = divmod(extent, block)
    sizes = (block,) * full + ((rem,) if rem else ())
    return Tiling(sizes)


def paper_nonuniform_sizes(
    extent: int, num_blocks: int, rng: np.random.Generator
) -> tuple[int, ...]:
    """The paper's §4.1 nonuniform block-size generation procedure.

    "we first start by constructing M empty row blocks ... we then randomly
    [add] one to the size [of] a row block, and repeat this step until the
    total number of rows among all blocks is equal to the number of rows in
    the uniformly blocked matrices."

    The paper notes a *low-quality* RNG was used deliberately to create
    significant inhomogeneity.  We bias the per-block row preference with
    a ±30 % uniform weight, which lands the min:max ratios in the paper's
    Table-1 band for its matrix sizes (memory ~1:3–1:4 as a 2-way block
    product, work ~1:4.5–1:7 as the 3-way task product).
    """
    if num_blocks <= 0 or extent < num_blocks:
        raise ValueError("need extent >= num_blocks >= 1")
    # Weighted preference per block — emulates the paper's "low-quality RNG"
    # bias.  Each block gets at least one row.
    weights = rng.uniform(0.9, 1.1, size=num_blocks)
    weights /= weights.sum()
    counts = rng.multinomial(extent - num_blocks, weights) + 1
    return tuple(int(c) for c in counts)


def nonuniform_tiling(
    extent: int, num_blocks: int, seed: int = 0
) -> Tiling:
    """Nonuniform tiling via the paper's generation procedure (§4.1)."""
    rng = np.random.default_rng(seed)
    return Tiling(paper_nonuniform_sizes(extent, num_blocks, rng))


def cyclic_owner(block_index: int | np.ndarray, num_procs: int):
    """Cyclic embedding of logical blocks onto a 1-D process group."""
    return block_index % num_procs


@dataclasses.dataclass(frozen=True)
class LoadStats:
    """Min:max load ratios as in the paper's Table 1 / §4.4."""

    memory_min_max: float  # max(mem)/min(mem) over units
    work_min_max: float  # max(work)/min(work) over units

    def as_row(self) -> str:
        return f"1:{self.memory_min_max:.2f}  1:{self.work_min_max:.2f}"


def load_stats(
    row_tiling: Tiling,
    col_tiling: Tiling,
    inner_tiling: Tiling | None = None,
    *,
    grid: tuple[int, int] | None = None,
) -> LoadStats:
    """Memory (elements of C) and work (FLOP) min:max ratios.

    With ``grid=None`` the statistic is per *block* (paper Table 1:
    block-level inhomogeneity).  With a ``(P_row, P_col)`` grid, blocks are
    cyclically embedded and the statistic is per *process* (paper §4.4:
    effective imbalance, e.g. the 1:1.35 claim for N=32768, P=256).
    """
    rows = np.asarray(row_tiling.sizes, dtype=np.int64)
    cols = np.asarray(col_tiling.sizes, dtype=np.int64)
    inner = (
        np.asarray(inner_tiling.sizes, dtype=np.int64)
        if inner_tiling is not None
        else cols  # square C = A·B: K blocking ~ N blocking
    )
    # memory: one C block, |C_ij| = m_i * n_j
    # work:   one task = one block triple (i, k, j): 2 * m_i * k_k * n_j
    #         (3-way product => wider spread than memory, cf. Table 1)
    mem = rows[:, None] * cols[None, :]
    k_total = int(inner.sum())
    if grid is None:
        work_ratio = float(
            (rows.max() * inner.max() * cols.max())
            / (rows.min() * inner.min() * cols.min())
        )
        return LoadStats(
            memory_min_max=float(mem.max() / mem.min()),
            work_min_max=work_ratio,
        )
    p_row, p_col = grid
    owners_r = np.arange(len(rows)) % p_row
    owners_c = np.arange(len(cols)) % p_col
    mem_per = np.zeros((p_row, p_col), dtype=np.float64)
    np.add.at(
        mem_per,
        (owners_r[:, None].repeat(len(cols), 1), owners_c[None, :].repeat(len(rows), 0)),
        mem,
    )
    work_per = mem_per * (2.0 * k_total)
    return LoadStats(
        memory_min_max=float(mem_per.max() / mem_per.min()),
        work_min_max=float(work_per.max() / work_per.min()),
    )


@dataclasses.dataclass(frozen=True)
class BucketedTiling:
    """Nonuniform logical blocks packed into uniform physical TPU tiles.

    TPU compute wants uniform (MXU-aligned) tiles.  A nonuniform logical
    tiling is *bucketed*: each logical block is placed in ``ceil(size /
    tile)`` physical tiles; the final physical tile of a block is padded.
    ``valid`` records how many elements of each physical tile are real.

    This is the documented hardware adaptation of the paper's
    arbitrary-block-size support (README.md §Paper-to-code map).
    """

    logical: Tiling
    tile: int  # uniform physical tile size (MXU-aligned, e.g. 128/256)
    # Per physical tile: owning logical block and number of valid elements.
    block_id: tuple[int, ...]
    valid: tuple[int, ...]

    @property
    def num_tiles(self) -> int:
        return len(self.block_id)

    @property
    def padded_extent(self) -> int:
        return self.num_tiles * self.tile

    @property
    def padding_waste(self) -> float:
        """Fraction of physical elements that are padding."""
        return 1.0 - self.logical.extent / self.padded_extent

    def gather_indices(self) -> np.ndarray:
        """Map physical (padded) positions -> source positions (or -1 pad).

        Used to materialise the padded operand from the compact one with a
        single gather; -1 marks padding (caller substitutes zeros).
        """
        idx = np.full(self.padded_extent, -1, dtype=np.int64)
        offsets = self.logical.offsets
        pos = 0  # physical cursor
        for t in range(self.num_tiles):
            b = self.block_id[t]
            v = self.valid[t]
            # offset within the logical block for this tile:
            prior = sum(
                self.valid[u] for u in range(t) if self.block_id[u] == b
            )
            src0 = offsets[b] + prior
            idx[pos : pos + v] = np.arange(src0, src0 + v)
            pos += self.tile
        return idx


def bucketize(logical: Tiling, tile: int) -> BucketedTiling:
    """Pack a (possibly nonuniform) logical tiling into uniform tiles."""
    if tile <= 0:
        raise ValueError("tile must be positive")
    block_id: list[int] = []
    valid: list[int] = []
    for b, size in enumerate(logical.sizes):
        full, rem = divmod(size, tile)
        block_id.extend([b] * full)
        valid.extend([tile] * full)
        if rem:
            block_id.append(b)
            valid.append(rem)
    return BucketedTiling(
        logical=logical, tile=tile, block_id=tuple(block_id), valid=tuple(valid)
    )
