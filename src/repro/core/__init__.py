"""Core: task-based SUMMA for block-sparse tensor computing (the paper)."""
from repro.core.api import DistributedMatmul, NonuniformMatmul, pad_to_multiple
from repro.core.plan import MatmulPlan, PlanCost, plan_matmul
from repro.core.blocking import (
    BucketedTiling,
    LoadStats,
    Tiling,
    bucketize,
    cyclic_owner,
    load_stats,
    nonuniform_tiling,
    paper_nonuniform_sizes,
    uniform_tiling,
)
from repro.core.sparsity import (
    BlockCSR,
    banded_block_mask,
    block_csr_from_mask,
    decay_block_mask,
    mask_matmul_flops,
    random_block_mask,
)
from repro.core.summa import (
    SummaConfig,
    execute_plan,
    multi_issue_limit,
    reference_blocksparse_matmul,
    reference_matmul,
    summa_25d_matmul,
    summa_blocksparse_matmul,
    summa_matmul,
)
