"""Core: task-based SUMMA for block-sparse tensor computing (the paper)."""
from repro.core.api import DistributedMatmul, NonuniformMatmul, pad_to_multiple
from repro.core.contract import (
    BlockSparseTensor,
    ContractionSpec,
    contract,
    contract_chain,
    parse_contraction,
)
from repro.core.plan import MatmulPlan, PlanCost, mask_key, plan_matmul, rank_key
from repro.core.blocking import (
    BucketedTiling,
    LoadStats,
    Tiling,
    bucketize,
    cyclic_owner,
    load_stats,
    nonuniform_tiling,
    paper_nonuniform_sizes,
    uniform_tiling,
)
from repro.core.sparsity import (
    BlockCSR,
    BlockRankMap,
    RankCSR,
    banded_block_mask,
    block_csr_from_mask,
    block_diag_block_mask,
    block_rank_flops,
    decay_block_mask,
    decay_rank_map,
    mask_matmul_flops,
    random_block_mask,
    random_rank_map,
    rank_csr_from_dense,
    rank_matmul_flops,
    synthesize_rank_csr,
)
from repro.core.summa import (
    SummaConfig,
    clear_executable_cache,
    executable_cache_stats,
    execute_plan,
    execute_rank_plan,
    multi_issue_limit,
    rank_operands,
    reference_blocksparse_matmul,
    reference_matmul,
    reference_ranksparse_matmul,
    summa_25d_matmul,
    summa_blocksparse_matmul,
    summa_matmul,
    warm_plan_executable,
)
