"""Task-based 2D SUMMA, adapted to static SPMD on TPU meshes.

Implements the paper's algorithm family as `shard_map` programs over a
2-D slice ``(row_axis, col_axis)`` of a device mesh.  Since the
``MatmulPlan`` refactor every entry point builds one static plan
(``core.plan.plan_matmul``) and hands it to ``execute_plan``; the
strategies below are *plan interpreters*:

* ``_exec_procedural`` — the paper's *baseline* (its Algorithm 1 without
  the non-blocking part): a sequential K-step loop; each step broadcasts
  one column-panel of A along grid rows and one row-panel of B along grid
  columns, then performs the rank-k update.  Iterations are serialized
  through the loop carry — collectives cannot overlap compute of other
  iterations, mirroring procedural SUMMA's sequence dependencies (paper
  Fig. 1, dashed edges).

* ``_exec_taskbased`` — the paper's contribution (§3.2), statically
  scheduled: *multiple-issue* lookahead of ``I`` iterations (paper Eq. 1)
  realised as an ``I``-deep panel-prefetch pipeline.  The broadcast for
  step ``k+I`` is issued in iteration ``k`` and is data-independent of
  every rank-k update in flight, so XLA's latency-hiding scheduler
  overlaps ICI transfers with MXU compute — the static analogue of
  MADNESS tasks firing on data availability.

* ``_exec_allgather`` — the ``I = K_steps`` extreme of Eq. 1 (every
  broadcast issued up-front), i.e. one all-gather per operand followed by
  a local GEMM.  Maximum memory, minimum exposure to per-step latency.

* ``_exec_sparse_dag`` — static block-sparsity: panels the plan marks
  globally dead are *skipped at trace time* (no broadcast, no compute),
  and surviving rank-k updates run on masked operands.  Communication
  volume shrinks with the block fill-in.

* ``_exec_sparse_bsmm`` — the plan's per-device refinement: live panels
  are gathered once, then the Pallas scalar-prefetch BSMM kernel
  (kernels/bsmm.py) consumes *this device's* CSR column map — blocks
  dead for this grid row/column are never loaded or multiplied, so local
  FLOPs scale with the per-device fill-in, finer than global pruning.

* ``_exec_sparse_pull`` — the one-sided SpGEMM route
  (``plan.comm_mode="pull"``, repro.spgemm): gather-by-index emulation
  of RDMA panel gets; the fetch cost model lives in the task graph.
  A-/B-stationary plans (``plan.stationarity``) run a single local
  contraction with a C reduce-scatter instead of the K pipeline, and
  ``plan.c_mask`` zeroes dead output blocks on every route.

Broadcast realisation: a panel broadcast from its owner is expressed as a
masked ``psum`` ("broadcast-as-allreduce"), the standard static-SPMD
idiom.  It costs ~2× the bytes of an optimal tree broadcast; the
``allgather`` strategy is the bandwidth-optimal endpoint.  See
EXPERIMENTS.md §Perf for the measured trade-off.

Data layout: A is ``(M, K)`` sharded (row_axis, col_axis); B is ``(K, N)``
sharded (row_axis, col_axis); C is ``(M, N)`` sharded (row_axis,
col_axis).  The K dimension is split into ``k_blocks`` panels, each
contained within a single device's shard (``k_blocks`` must be a multiple
of both grid dims unless it equals them).  Over-decomposition (paper
§3.2) = choosing ``k_blocks`` > grid dim, giving finer pipeline slots.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from jax.sharding import Mesh

from repro.compat import shard_map

__all__ = [
    "SummaConfig",
    "multi_issue_limit",
    "resolve_multi_issue",
    "reference_matmul",
    "reference_blocksparse_matmul",
    "reference_ranksparse_matmul",
    "execute_plan",
    "execute_rank_plan",
    "rank_operands",
    "summa_matmul",
    "summa_blocksparse_matmul",
    "summa_25d_matmul",
    "executable_cache_stats",
    "clear_executable_cache",
    "warm_plan_executable",
]

Strategy = Literal["procedural", "taskbased", "allgather"]


def multi_issue_limit(p_row: int, p_col: int, k_steps: int) -> int:
    """Paper Eq. (1): the number of concurrently scheduled iterations I."""
    if p_row < 2 or p_col < 2:
        return 2
    if p_row >= k_steps and p_col >= k_steps:
        return k_steps
    return min(p_row, p_col)


def resolve_multi_issue(
    p_row: int, p_col: int, k_steps: int, lookahead: int | None = None
) -> int:
    """The executed multiple-issue window: ``lookahead`` when given, Eq. (1)
    otherwise — always clamped to ``[1, max(k_steps, 1)]`` so degenerate
    schedules (k_steps of 0 or 1, windows beyond the panel count) stay
    well-formed.  The single clamp shared by ``SummaConfig``,
    ``MatmulPlan``, and the ``repro.sched`` graph builders."""
    cap = max(k_steps, 1)
    if lookahead is not None:
        return max(1, min(lookahead, cap))
    return max(1, min(multi_issue_limit(p_row, p_col, k_steps), cap))


@dataclasses.dataclass(frozen=True)
class SummaConfig:
    """Configuration for a distributed SUMMA matmul.

    ``row_axis``/``col_axis`` may be a single mesh-axis name or a tuple of
    names (e.g. ``("pod", "data")`` — the grid dimension is their product).
    """

    mesh: Mesh
    row_axis: str | tuple[str, ...] = "data"
    col_axis: str | tuple[str, ...] = "model"
    strategy: Strategy = "taskbased"
    k_blocks: int | None = None  # number of K panels (over-decomposition)
    lookahead: int | None = None  # None => paper Eq. (1)
    accum_dtype: Any = jnp.float32
    # Local block-multiply implementation: "xla" (jnp.dot) or "pallas"
    # (kernels.tiled_matmul dense / kernels.bsmm block-sparse).
    local_matmul: Literal["xla", "pallas"] = "xla"

    def _axis_size(self, axis) -> int:
        if isinstance(axis, tuple):
            out = 1
            for a in axis:
                out *= self.mesh.shape[a]
            return out
        return self.mesh.shape[axis]

    @property
    def p_row(self) -> int:
        return self._axis_size(self.row_axis)

    @property
    def p_col(self) -> int:
        return self._axis_size(self.col_axis)

    def resolve_k_blocks(self, k: int) -> int:
        kb = self.k_blocks
        if kb is None:
            # default: one panel per grid column (classic SUMMA)
            kb = max(self.p_col, self.p_row)
        lcm = math.lcm(self.p_row, self.p_col)
        if kb % lcm and kb not in (self.p_row, self.p_col):
            raise ValueError(
                f"k_blocks={kb} must be a multiple of lcm(grid)={lcm}"
            )
        if k % kb:
            raise ValueError(f"K={k} not divisible by k_blocks={kb}")
        return kb

    def resolve_lookahead(self, k_steps: int) -> int:
        """The executed multiple-issue window (see ``resolve_multi_issue``)."""
        return resolve_multi_issue(
            self.p_row, self.p_col, k_steps, self.lookahead
        )


# ---------------------------------------------------------------------------
# Pure-jnp oracles
# ---------------------------------------------------------------------------


def reference_matmul(a: jax.Array, b: jax.Array, accum_dtype=jnp.float32):
    """Oracle: plain matmul with fp32 accumulation."""
    out = jnp.matmul(a, b, preferred_element_type=accum_dtype)
    return out.astype(a.dtype)


def _expand_mask(mask: np.ndarray, bm: int, bn: int) -> np.ndarray:
    return np.kron(np.asarray(mask, dtype=bool), np.ones((bm, bn), dtype=bool))


def reference_blocksparse_matmul(
    a: jax.Array,
    b: jax.Array,
    a_mask: np.ndarray,
    b_mask: np.ndarray,
    accum_dtype=jnp.float32,
):
    """Oracle for block-sparse matmul: zero masked blocks, then matmul."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    mb, kb_a = a_mask.shape
    kb_b, nb = b_mask.shape
    assert kb_a == kb_b, "A col-blocks must equal B row-blocks"
    am = _expand_mask(a_mask, m // mb, k // kb_a)
    bm_ = _expand_mask(b_mask, k // kb_b, n // nb)
    a_z = jnp.where(jnp.asarray(am), a, 0)
    b_z = jnp.where(jnp.asarray(bm_), b, 0)
    return reference_matmul(a_z, b_z, accum_dtype)


def reference_ranksparse_matmul(
    a_ranks,
    b: jax.Array,
    b_mask: np.ndarray | None = None,
    accum_dtype=jnp.float32,
):
    """Oracle for rank-sparse matmul: densify the ``RankCSR``, then matmul
    (optionally with B's block mask applied)."""
    a = jnp.asarray(a_ranks.to_dense()).astype(b.dtype)
    if b_mask is not None:
        mb, kb = a_ranks.rank_map().ranks.shape
        return reference_blocksparse_matmul(
            a, b, np.ones((mb, kb), dtype=bool), b_mask, accum_dtype
        )
    return reference_matmul(a, b, accum_dtype)


# ---------------------------------------------------------------------------
# shard_map building blocks
# ---------------------------------------------------------------------------


def _bcast_panel(local_slab, owner, axis_name):
    """Broadcast ``local_slab`` from ``owner`` to the whole axis group.

    Static-SPMD broadcast-as-allreduce: non-owners contribute zeros.
    ``owner`` may be a traced int32.
    """
    idx = jax.lax.axis_index(axis_name)
    contrib = jnp.where(idx == owner, local_slab, jnp.zeros_like(local_slab))
    return jax.lax.psum(contrib, axis_name)


def _local_dot(a_panel, b_panel, accum, cfg: SummaConfig):
    """Local panel product; consults the kernel autotune cache.

    ``cfg.local_matmul`` is the static policy, but when the autotune
    cache (``kernels.autotune``) holds a measured winner for this panel
    shape's bucket, the cached route overrides the generic choice —
    lookup-only, so a cold or disabled cache reproduces the pre-autotune
    trace bitwise (the cache fingerprint is part of the executable key).
    """
    from repro.kernels.autotune import autotune_cache

    route = "pallas" if cfg.local_matmul == "pallas" else "xla"
    entry = autotune_cache().lookup(
        a_panel.shape[0], a_panel.shape[1], b_panel.shape[1],
        dtype=a_panel.dtype,
    )
    tiles = None
    if entry is not None and entry["winner"] in ("pallas", "xla"):
        route = entry["winner"]
        tiles = entry.get("tiles")
    if route == "pallas":
        from repro.kernels import ops as kops

        tile_kw = (
            {"bm": tiles[0], "bk": tiles[1], "bn": tiles[2]}
            if tiles else {}
        )
        prod = kops.tiled_matmul(
            a_panel, b_panel, accum_dtype=cfg.accum_dtype, **tile_kw
        ).astype(cfg.accum_dtype)
        return accum + prod
    prod = jnp.matmul(a_panel, b_panel, preferred_element_type=cfg.accum_dtype)
    return accum + prod


def _panel_slices(a_loc, b_loc, k, kb_width, t_a, t_b):
    """Extract the k-th K-panel slices + their owners from local shards.

    Global panel k lives in A's grid-column ``k // t_a`` at local panel
    index ``k % t_a`` and in B's grid-row ``k // t_b`` at local index
    ``k % t_b`` (contiguous panel schedule).
    """
    owner_col = k // t_a
    owner_row = k // t_b
    a_panel = jax.lax.dynamic_slice_in_dim(a_loc, (k % t_a) * kb_width, kb_width, 1)
    b_panel = jax.lax.dynamic_slice_in_dim(b_loc, (k % t_b) * kb_width, kb_width, 0)
    return a_panel, b_panel, owner_col, owner_row


# ---------------------------------------------------------------------------
# Plan interpreters (local, inside shard_map)
# ---------------------------------------------------------------------------


def _exec_procedural(a_loc, b_loc, plan, *, k_steps=None, k_start=0):
    """Paper baseline: sequential iterations, no cross-iteration overlap."""
    cfg = plan.cfg
    kb_width = plan.kb_width
    k_steps = plan.k_steps if k_steps is None else k_steps
    m_loc, n_loc = a_loc.shape[0], b_loc.shape[1]
    t_a = a_loc.shape[1] // kb_width
    t_b = b_loc.shape[0] // kb_width

    def body(k, c_acc):
        a_panel, b_panel, owner_col, owner_row = _panel_slices(
            a_loc, b_loc, k + k_start, kb_width, t_a, t_b
        )
        a_bc = _bcast_panel(a_panel, owner_col, cfg.col_axis)
        b_bc = _bcast_panel(b_panel, owner_row, cfg.row_axis)
        return _local_dot(a_bc, b_bc, c_acc, cfg)

    c0 = jnp.zeros((m_loc, n_loc), cfg.accum_dtype)
    return jax.lax.fori_loop(0, k_steps, body, c0)


def _exec_taskbased(a_loc, b_loc, plan, *, k_steps=None, k_start=0):
    """Multiple-issue SUMMA: I-deep panel prefetch pipeline (paper §3.2).

    The carry holds ``I`` broadcast panels.  Iteration ``k`` consumes the
    buffer head (panel ``k``) and issues the broadcast for panel ``k+I``;
    the two are data-independent, so the collective overlaps the GEMM.
    ``k_start`` (possibly traced) offsets the panel range — the 2.5D
    variant gives each replica pod its own K sub-range.
    """
    cfg = plan.cfg
    kb_width = plan.kb_width
    k_steps = plan.k_steps if k_steps is None else k_steps
    m_loc, n_loc = a_loc.shape[0], b_loc.shape[1]
    t_a = a_loc.shape[1] // kb_width
    t_b = b_loc.shape[0] // kb_width
    # Per-plan window (tuner-chosen) wins over the config's Eq.-(1) default.
    lookahead = plan.resolve_lookahead(k_steps)

    def fetch(k):
        k = k + k_start
        a_panel, b_panel, owner_col, owner_row = _panel_slices(
            a_loc, b_loc, k, kb_width, t_a, t_b
        )
        return (
            _bcast_panel(a_panel, owner_col, cfg.col_axis),
            _bcast_panel(b_panel, owner_row, cfg.row_axis),
        )

    # Prologue: issue the first I broadcasts (multiple-issue).  Unrolled at
    # trace time; mutually independent.
    a_buf = []
    b_buf = []
    for k in range(lookahead):
        a_bc, b_bc = fetch(k)
        a_buf.append(a_bc)
        b_buf.append(b_bc)
    a_buf = jnp.stack(a_buf)  # (I, m_loc, kb)
    b_buf = jnp.stack(b_buf)  # (I, kb, n_loc)

    steady = k_steps - lookahead

    def body(carry, k):
        c_acc, a_b, b_b = carry
        a_head, b_head = a_b[0], b_b[0]
        # Issue broadcast for step k + I (independent of the GEMM below).
        a_next, b_next = fetch(k + lookahead)
        c_acc = _local_dot(a_head, b_head, c_acc, cfg)
        a_b = jnp.concatenate([a_b[1:], a_next[None]], axis=0)
        b_b = jnp.concatenate([b_b[1:], b_next[None]], axis=0)
        return (c_acc, a_b, b_b), None

    c0 = jnp.zeros((m_loc, n_loc), cfg.accum_dtype)
    if steady > 0:
        (c_acc, a_buf, b_buf), _ = jax.lax.scan(
            body, (c0, a_buf, b_buf), jnp.arange(steady)
        )
    else:
        c_acc = c0
    # Epilogue: drain the remaining I buffered panels (unrolled).
    for i in range(lookahead):
        c_acc = _local_dot(a_buf[i], b_buf[i], c_acc, cfg)
    return c_acc


def _exec_allgather(a_loc, b_loc, plan, *, k_steps=None, k_start=0):
    """I = K extreme of Eq. (1): gather every panel up-front."""
    cfg = plan.cfg
    a_full = jax.lax.all_gather(a_loc, cfg.col_axis, axis=1, tiled=True)
    b_full = jax.lax.all_gather(b_loc, cfg.row_axis, axis=0, tiled=True)
    c0 = jnp.zeros((a_loc.shape[0], b_loc.shape[1]), cfg.accum_dtype)
    return _local_dot(a_full, b_full, c0, cfg)


def _bcast_live_panels(a_loc, b_loc, plan):
    """Broadcast every globally-live panel (static unroll).

    One (A, B) broadcast pair per live panel, sliced and owner-addressed
    through ``_panel_slices`` so the sparse executors share the dense
    pipeline's panel layout.  Returns the two lists of broadcast panels.
    """
    cfg = plan.cfg
    kb_width = plan.kb_width
    t_a = a_loc.shape[1] // kb_width
    t_b = b_loc.shape[0] // kb_width
    a_parts = []
    b_parts = []
    for kk in plan.live_panels:
        a_panel, b_panel, owner_col, owner_row = _panel_slices(
            a_loc, b_loc, kk, kb_width, t_a, t_b
        )
        a_parts.append(_bcast_panel(a_panel, owner_col, cfg.col_axis))
        b_parts.append(_bcast_panel(b_panel, owner_row, cfg.row_axis))
    return a_parts, b_parts


def _exec_sparse_dag(a_loc, b_loc, plan):
    """Globally-live panels as a fully unrolled static task DAG.

    The closest XLA analogue of the paper's task graph: every surviving
    broadcast is independent of every rank-k update except its own, giving
    the scheduler maximal freedom to overlap (multiple-issue falls out for
    free).  Dead panels are absent from the trace entirely.
    """
    cfg = plan.cfg
    m_loc, n_loc = a_loc.shape[0], b_loc.shape[1]
    c = jnp.zeros((m_loc, n_loc), cfg.accum_dtype)
    a_parts, b_parts = _bcast_live_panels(a_loc, b_loc, plan)
    for a_bc, b_bc in zip(a_parts, b_parts):
        c = _local_dot(a_bc, b_bc, c, cfg)
    return c


def _exec_sparse_pull(a_loc, b_loc, plan):
    """One-sided pull route (``plan.comm_mode == "pull"``).

    RDMA-SpGEMM-style gets (each surviving gemm pulling exactly the
    panels it reads from their owners) are not expressible in static
    SPMD, so this route *emulates* them: one all-gather per operand, then
    static indexed reads of exactly the live panels — dead panels are
    never touched by compute.  The fetch-level cost model (factor-1.0
    bytes, owner-clock contention) lives in ``sched.taskgraph`` /
    ``sched.simulator``.  Numerically this accumulates the same panels in
    the same order as the masked DAG, so pull and broadcast plans pin
    bitwise-equal in the differential oracle.
    """
    cfg = plan.cfg
    kb = plan.kb_width
    m_loc, n_loc = a_loc.shape[0], b_loc.shape[1]
    a_full = jax.lax.all_gather(a_loc, cfg.col_axis, axis=1, tiled=True)
    b_full = jax.lax.all_gather(b_loc, cfg.row_axis, axis=0, tiled=True)
    c = jnp.zeros((m_loc, n_loc), cfg.accum_dtype)
    for kk in plan.live_panels:
        a_panel = jax.lax.slice_in_dim(a_full, kk * kb, (kk + 1) * kb, axis=1)
        b_panel = jax.lax.slice_in_dim(b_full, kk * kb, (kk + 1) * kb, axis=0)
        c = _local_dot(a_panel, b_panel, c, cfg)
    return c


def _exec_sparse_bsmm(a_loc, b_loc, cols_loc, plan):
    """Per-device block-sparse rank-k update via the Pallas BSMM kernel.

    Gathers the globally-live panels (same broadcast traffic as the DAG
    executor), then runs ONE scalar-prefetch kernel over the gathered
    operands with this device's CSR column map: blocks dead for this grid
    row/column are never copied to VMEM nor multiplied, so local FLOPs
    follow the per-device fill-in the planner computed.
    """
    from repro.kernels.bsmm import bsmm_pallas

    cfg = plan.cfg
    a_parts, b_parts = _bcast_live_panels(a_loc, b_loc, plan)
    a_g = jnp.concatenate(a_parts, axis=1)  # (m_loc, L*kb)
    b_g = jnp.concatenate(b_parts, axis=0)  # (L*kb, n_loc)
    bm, bk, bn = plan.local_block
    c = bsmm_pallas(
        a_g,
        b_g,
        cols_loc,
        bm=bm,
        bk=bk,
        bn=bn,
        out_dtype=cfg.accum_dtype,
        interpret=jax.default_backend() != "tpu",
    )
    return c.astype(cfg.accum_dtype)


def _rank_panel_widths(plan) -> dict[int, int]:
    """Static per-live-panel factor width: the max block rank in that
    panel's (padded) column of the rank grid (>= 1 on live panels)."""
    return {
        kk: max(int(plan.a_ranks[:, kk].max()), 1)
        for kk in plan.live_panels
    }


def _exec_ranksparse(u_loc, v_loc, b_loc, plan, *, r_pad: int):
    """Block-rank-sparse rank-k updates from factorized A panels.

    A's blocks arrive as stacked factors (``rank_operands`` layout): for
    live panel ``kk`` this broadcasts a width-``r_k`` U panel, the matching
    V rows, and B's dense panel, then evaluates every local block row as
    ``U @ (V @ B)`` — two skinny gemms whose FLOPs follow the panel rank.
    Two independent per-panel fallbacks (static, shared with the planner's
    comm model and the task graph):

    * comm — past r* = bm·bk/(bm+bk) the factors outweigh the dense
      panel, so the owner column reconstructs locally and the dense panel
      is broadcast instead;
    * compute — near the threshold the fused dense dot beats the
      two-stage contraction (``RANK_COMPUTE_MARGIN``); factors may still
      travel (they're smaller) and be reconstructed receiver-side.

    Rank raggedness *within* a panel is carried by zero factor columns
    (the executed width is the panel max — the plan's ``flops_sparse``
    stays per-block useful work, the same padding-vs-useful gap
    ``NonuniformMatmul.padding_waste`` documents for block extents).
    """
    from repro.core.sparsity import (
        rank_panel_factored_comm,
        rank_panel_factored_compute,
    )

    cfg = plan.cfg
    bk = plan.kb_width
    k_steps = plan.k_steps
    m_loc, n_loc = u_loc.shape[0], b_loc.shape[1]
    t_a = k_steps // max(cfg.p_col, 1) or 1  # A-side panels per grid column
    t_b = b_loc.shape[0] // bk
    mb_loc = v_loc.shape[0] // r_pad
    bm = m_loc // mb_loc
    widths = _rank_panel_widths(plan)

    c = jnp.zeros((m_loc, n_loc), cfg.accum_dtype)
    u_parts = []  # factored panels: (mb_loc, bm, r_k) U factors ...
    w_parts = []  # ... and their (mb_loc, r_k, n_loc) V·B intermediates
    for kk in plan.live_panels:
        r_k = min(widths[kk], r_pad)
        owner_col = kk // t_a
        owner_row = kk // t_b
        u_panel = jax.lax.dynamic_slice_in_dim(
            u_loc, (kk % t_a) * r_pad, r_k, 1
        )
        v_panel = jax.lax.dynamic_slice_in_dim(
            v_loc, (kk % t_a) * bk, bk, 1
        ).reshape(mb_loc, r_pad, bk)[:, :r_k, :]
        b_panel = jax.lax.dynamic_slice_in_dim(
            b_loc, (kk % t_b) * bk, bk, 0
        )
        b_bc = _bcast_panel(b_panel, owner_row, cfg.row_axis)
        if rank_panel_factored_comm(r_k, bm, bk):
            u_bc = _bcast_panel(u_panel, owner_col, cfg.col_axis)
            v_bc = _bcast_panel(v_panel, owner_col, cfg.col_axis)
            if rank_panel_factored_compute(r_k, bm, bk, n_loc):
                u_parts.append(u_bc.reshape(mb_loc, bm, r_k))
                w_parts.append(
                    jnp.einsum(
                        "irk,kn->irn", v_bc, b_bc,
                        preferred_element_type=cfg.accum_dtype,
                    )
                )
            else:
                # factors travel (smaller), receivers reconstruct the
                # dense panel and run the fused dot
                a_panel = jnp.einsum(
                    "ibr,irk->ibk", u_bc.reshape(mb_loc, bm, r_k), v_bc,
                    preferred_element_type=cfg.accum_dtype,
                ).reshape(m_loc, bk).astype(u_loc.dtype)
                c = _local_dot(a_panel, b_bc, c, cfg)
        else:
            # Owner-side reconstruction: every device rebuilds the dense
            # panel from its *local* factors (garbage off the owner
            # column, zeroed by the masked psum), so only dense panel
            # bytes travel.
            u3 = u_panel.reshape(mb_loc, bm, r_k)
            a_panel = jnp.einsum(
                "ibr,irk->ibk", u3, v_panel,
                preferred_element_type=cfg.accum_dtype,
            ).reshape(m_loc, bk).astype(u_loc.dtype)
            a_bc = _bcast_panel(a_panel, owner_col, cfg.col_axis)
            c = _local_dot(a_bc, b_bc, c, cfg)
    if u_parts:
        # All factored panels resolve in ONE batched contraction over the
        # concatenated rank axis — per local block row, a (bm, sum r_k) x
        # (sum r_k, n_loc) gemm.  Panel-at-a-time accumulation would run
        # sum-r_k skinny gemms instead, which is ~17x slower on CPU BLAS
        # and wastes MXU occupancy on TPU.
        u_cat = jnp.concatenate(u_parts, axis=2)
        w_cat = jnp.concatenate(w_parts, axis=1)
        c = c + jnp.einsum(
            "ibR,iRn->ibn", u_cat, w_cat,
            preferred_element_type=cfg.accum_dtype,
        ).reshape(m_loc, n_loc)
    return c


def _exec_ranksparse_pull(u_loc, v_loc, b_loc, plan, *, r_pad: int):
    """One-sided pull of *factorized* A panels (``comm_mode="pull"``).

    The RDMA-SpGEMM gets fetch the U/V factors themselves — bytes follow
    the per-block rank, never the dense panel, until a panel crosses
    r* = bm·bk/(bm+bk) (``rank_panel_factored_comm``), where the owner
    would serve the reconstructed dense panel instead.  Like
    ``_exec_sparse_pull`` this *emulates* the gets in static SPMD: one
    all-gather per factor operand, then static indexed reads of exactly
    the live panels; the fetch-level cost model (factor-1.0 rank-sized
    bytes, owner-clock contention) lives in ``sched.taskgraph``.  The
    per-panel compute decisions mirror ``_exec_ranksparse`` term for
    term — same panels, same order, same batched factored contraction —
    so pull pins bitwise-equal against the broadcast rank path in the
    differential oracle.
    """
    from repro.core.sparsity import (
        rank_panel_factored_comm,
        rank_panel_factored_compute,
    )

    cfg = plan.cfg
    bk = plan.kb_width
    m_loc, n_loc = u_loc.shape[0], b_loc.shape[1]
    mb_loc = v_loc.shape[0] // r_pad
    bm = m_loc // mb_loc
    widths = _rank_panel_widths(plan)
    u_full = jax.lax.all_gather(u_loc, cfg.col_axis, axis=1, tiled=True)
    v_full = jax.lax.all_gather(v_loc, cfg.col_axis, axis=1, tiled=True)
    b_full = jax.lax.all_gather(b_loc, cfg.row_axis, axis=0, tiled=True)

    c = jnp.zeros((m_loc, n_loc), cfg.accum_dtype)
    u_parts = []
    w_parts = []
    for kk in plan.live_panels:
        r_k = min(widths[kk], r_pad)
        u_panel = jax.lax.slice_in_dim(
            u_full, kk * r_pad, kk * r_pad + r_k, axis=1
        )
        v_panel = jax.lax.slice_in_dim(
            v_full, kk * bk, (kk + 1) * bk, axis=1
        ).reshape(mb_loc, r_pad, bk)[:, :r_k, :]
        b_panel = jax.lax.slice_in_dim(
            b_full, kk * bk, (kk + 1) * bk, axis=0
        )
        if rank_panel_factored_comm(r_k, bm, bk) and (
            rank_panel_factored_compute(r_k, bm, bk, n_loc)
        ):
            u_parts.append(u_panel.reshape(mb_loc, bm, r_k))
            w_parts.append(
                jnp.einsum(
                    "irk,kn->irn", v_panel, b_panel,
                    preferred_element_type=cfg.accum_dtype,
                )
            )
        else:
            # dense-panel fetch (past the comm crossover) or fused-dot
            # compute preference: reconstruct and run the dense dot —
            # identical arithmetic to the broadcast executor's fallbacks
            a_panel = jnp.einsum(
                "ibr,irk->ibk", u_panel.reshape(mb_loc, bm, r_k), v_panel,
                preferred_element_type=cfg.accum_dtype,
            ).reshape(m_loc, bk).astype(u_loc.dtype)
            c = _local_dot(a_panel, b_panel, c, cfg)
    if u_parts:
        u_cat = jnp.concatenate(u_parts, axis=2)
        w_cat = jnp.concatenate(w_parts, axis=1)
        c = c + jnp.einsum(
            "ibR,iRn->ibn", u_cat, w_cat,
            preferred_element_type=cfg.accum_dtype,
        ).reshape(m_loc, n_loc)
    return c


def _exec_ranksparse_grouped(u_loc, v_loc, b_loc, plan, *, r_pad: int):
    """Rank-sparse update through the grouped-gemm Pallas kernel.

    Gathers the live factor panels (full ``r_pad`` width — the kernel
    wants uniform tiles), then runs stage 1 (every block's ``V @ B_panel``,
    ragged across panels) as ONE grouped gemm: V rows are the tokens,
    each ``r_pad``-row tile's "expert" is its gathered panel position, and
    the B panels are the expert weights.  Stage 2 (``U @ ·`` + the segment
    sum into C rows) is a batched contraction over local block rows.

    Panels past the comm crossover (``rank_panel_factored_comm`` on the
    broadcast width ``r_pad``) are densified owner-side and run as dense
    dots outside the grouped stage, exactly like the jnp executor — the
    kernel's uniform ``r_pad`` padding (vs the model's per-panel ``r_k``)
    is the only remaining model-vs-executed comm gap.
    """
    from repro.core.sparsity import rank_panel_factored_comm
    from repro.kernels.grouped_gemm import grouped_gemm_pallas

    cfg = plan.cfg
    bk = plan.kb_width
    k_steps = plan.k_steps
    m_loc, n_loc = u_loc.shape[0], b_loc.shape[1]
    t_a = k_steps // max(cfg.p_col, 1) or 1
    t_b = b_loc.shape[0] // bk
    mb_loc = v_loc.shape[0] // r_pad
    bm = m_loc // mb_loc

    c = jnp.zeros((m_loc, n_loc), cfg.accum_dtype)
    u_parts, v_parts, b_parts = [], [], []
    for kk in plan.live_panels:
        owner_col = kk // t_a
        owner_row = kk // t_b
        u_panel = jax.lax.dynamic_slice_in_dim(
            u_loc, (kk % t_a) * r_pad, r_pad, 1
        )
        v_panel = jax.lax.dynamic_slice_in_dim(
            v_loc, (kk % t_a) * bk, bk, 1
        )
        b_panel = jax.lax.dynamic_slice_in_dim(
            b_loc, (kk % t_b) * bk, bk, 0
        )
        b_bc = _bcast_panel(b_panel, owner_row, cfg.row_axis)
        if rank_panel_factored_comm(r_pad, bm, bk):
            u_parts.append(_bcast_panel(u_panel, owner_col, cfg.col_axis))
            v_parts.append(_bcast_panel(v_panel, owner_col, cfg.col_axis))
            b_parts.append(b_bc)
        else:
            a_panel = jnp.einsum(
                "ibr,irk->ibk",
                u_panel.reshape(mb_loc, bm, r_pad),
                v_panel.reshape(mb_loc, r_pad, bk),
                preferred_element_type=cfg.accum_dtype,
            ).reshape(m_loc, bk).astype(u_loc.dtype)
            a_bc = _bcast_panel(a_panel, owner_col, cfg.col_axis)
            c = _local_dot(a_bc, b_bc, c, cfg)

    if not u_parts:
        return c
    from repro.kernels.ops import _pick_tile

    live = len(b_parts)
    b_g = jnp.stack(b_parts)  # (L, bk, n_loc) — the "expert" weights
    v_tokens = jnp.concatenate(v_parts, axis=0)  # (L*mb_loc*r_pad, bk)
    tile_expert = jnp.asarray(
        np.repeat(np.arange(live, dtype=np.int32), mb_loc)
    )
    # same tile selection + pad/slice handling as ops.ranksparse_matmul,
    # so awkward n_loc stays lane-aligned on TPU
    bn = _pick_tile(n_loc, 256)
    n_pad_loc = -(-n_loc // bn) * bn
    y = grouped_gemm_pallas(
        v_tokens,
        jnp.pad(b_g, ((0, 0), (0, 0), (0, n_pad_loc - n_loc))),
        tile_expert,
        bt=r_pad,
        bk=bk,
        bn=bn,
        out_dtype=cfg.accum_dtype,
        interpret=jax.default_backend() != "tpu",
    )[:, :n_loc]
    y4 = y.reshape(live, mb_loc, r_pad, n_loc)
    u_g = jnp.stack(u_parts).reshape(live, mb_loc, bm, r_pad)
    c = c + jnp.einsum(
        "libr,lirn->ibn", u_g, y4, preferred_element_type=cfg.accum_dtype
    ).reshape(m_loc, n_loc)
    return c.astype(cfg.accum_dtype)


_EXEC_IMPLS: dict[str, Callable] = {
    "procedural": _exec_procedural,
    "taskbased": _exec_taskbased,
    "allgather": _exec_allgather,
}


# ---------------------------------------------------------------------------
# The executable cache: plan-digest-keyed jitted programs
# ---------------------------------------------------------------------------

#: (kind, plan digest, local_impl, lookahead, dtypes, shapes) -> jitted fn.
#: One entry per distinct static execution — repeated eager calls of the
#: same plan dispatch a cached compiled program instead of re-tracing the
#: interpreter loop op by op.
_EXEC_CACHE: dict = {}
_EXEC_STATS = {"hits": 0, "misses": 0, "retraces": 0}


def executable_cache_stats() -> dict:
    """Hit/miss/retrace counters + current size of the executable cache.

    ``retraces`` counts actual jax trace executions of cached wrappers —
    with stable plan digests and dtypes it must equal ``misses`` (every
    program traced exactly once); a retrace without a miss means a cache
    key is unstable."""
    return {**_EXEC_STATS, "size": len(_EXEC_CACHE)}


def clear_executable_cache() -> None:
    """Drop every cached executable and zero the counters (tests)."""
    _EXEC_CACHE.clear()
    for k in _EXEC_STATS:
        _EXEC_STATS[k] = 0


def _is_traced(*arrays) -> bool:
    return any(isinstance(x, jax.core.Tracer) for x in arrays)


def _autotune_key_suffix() -> tuple:
    # A non-empty kernel-autotune cache changes what ``_local_dot`` traces,
    # so its content fingerprint joins executable cache keys; when the
    # cache is empty or disabled the suffix is empty and keys stay bitwise
    # pre-autotune.
    from repro.kernels.autotune import cache_fingerprint

    fp = cache_fingerprint()
    return (fp,) if fp else ()


def _cached_executable(key: tuple, build: Callable) -> Callable:
    key = key + _autotune_key_suffix()
    fn = _EXEC_CACHE.get(key)
    if fn is None:
        _EXEC_STATS["misses"] += 1
        fn = build()
        _EXEC_CACHE[key] = fn
    else:
        _EXEC_STATS["hits"] += 1
    return fn


def warm_plan_executable(plan, dtype, *, out_dtype: Any | None = None):
    """Compile (and cache) the executable for ``plan`` ahead of use.

    Drives the jitted wrapper with zero operands of the plan's padded
    shapes — ``jax.jit``'s dispatch cache is populated by a real call, so
    AOT lowering alone would not make the first production call cheap.
    Rank-sparse plans need a factor payload and cannot be warmed here
    (returns ``False``); everything else returns ``True``.
    """
    if plan.local_impl == "ranksparse":
        return False
    (mp, kp), (_, np_) = plan.padded_shapes
    a = jnp.zeros((mp, kp), dtype)
    b = jnp.zeros((kp, np_), dtype)
    execute_plan(a, b, plan, out_dtype=out_dtype).block_until_ready()
    return True


# ---------------------------------------------------------------------------
# Plan execution (the single entry into shard_map)
# ---------------------------------------------------------------------------


def execute_plan(
    a: jax.Array,
    b: jax.Array,
    plan,
    *,
    out_dtype: Any | None = None,
    compiled: bool = True,
) -> jax.Array:
    """Run C = A @ B according to a precomputed ``core.plan.MatmulPlan``.

    ``a``/``b`` must already be padded to ``plan.padded_shapes`` and
    sharded P(row_axis, col_axis).  Every public matmul entry point —
    dense, block-sparse, nonuniform — funnels through here.

    Eager calls dispatch one cached jitted program per ``(plan digest,
    dtypes)`` (``compiled=False`` opts out — the differential-oracle
    harness compares the two routes).  Accumulators live entirely inside
    the compiled program (XLA-managed buffers, freed on exit); operand
    buffers are deliberately *not* donated, since callers routinely reuse
    them across timing iterations.  Inside an enclosing ``jax.jit`` the
    interpreter body inlines into the caller's trace unchanged.
    """
    _check_plan_operands(a, b, plan)
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    if not compiled or _is_traced(a, b):
        return _execute_plan_eager(a, b, plan, out_dtype=out_dtype)
    key = (
        "plan", plan.digest(), plan.local_impl, plan.resolve_lookahead(),
        str(a.dtype), str(b.dtype), str(out_dtype),
    )

    def build():
        def traced(a, b):
            _EXEC_STATS["retraces"] += 1
            return _execute_plan_eager(a, b, plan, out_dtype=out_dtype)

        return jax.jit(traced)

    return _cached_executable(key, build)(a, b)


def _check_plan_operands(a, b, plan) -> None:
    (mp, kp), (_, np_) = plan.padded_shapes
    if a.shape != (mp, kp) or b.shape != (kp, np_):
        raise ValueError(
            f"operands {a.shape} @ {b.shape} do not match the plan's padded "
            f"shapes ({mp},{kp}) @ ({kp},{np_})"
        )


def _execute_plan_eager(
    a: jax.Array,
    b: jax.Array,
    plan,
    *,
    out_dtype: Any | None = None,
) -> jax.Array:
    """The strategy-interpreter body (trace-level; see ``execute_plan``)."""
    cfg = plan.cfg
    out_dtype = out_dtype or a.dtype
    spec2 = P(cfg.row_axis, cfg.col_axis)
    if plan.a_mask is not None:
        # Zero masked blocks so padded/garbage data cannot contribute.
        a = _apply_block_mask(a, plan.a_mask)
        b = _apply_block_mask(b, plan.b_mask)

    if getattr(plan, "stationarity", "C") != "C":
        # A-/B-stationary schedules (repro.spgemm): the stationary operand
        # keeps its canonical (row, col) layout; the other is re-laid-out
        # with K over the opposite grid axis and consumed in place; the
        # per-device partials reduce-scatter (bandwidth-optimal, factor 1)
        # into C's canonical layout.  No K pipeline — masked operands are
        # already zeroed above, so structure still prunes arithmetic work
        # at the value level.
        if plan.stationarity == "A":
            in_specs = (spec2, P(cfg.col_axis, None))
            scatter_axis, scatter_dim = cfg.col_axis, 1
        else:
            in_specs = (P(None, cfg.row_axis), spec2)
            scatter_axis, scatter_dim = cfg.row_axis, 0

        def fn_stat(a_loc, b_loc):
            c0 = jnp.zeros((a_loc.shape[0], b_loc.shape[1]), cfg.accum_dtype)
            part = _local_dot(a_loc, b_loc, c0, cfg)
            c = jax.lax.psum_scatter(
                part, scatter_axis, scatter_dimension=scatter_dim, tiled=True
            )
            return c.astype(out_dtype)

        out = shard_map(
            fn_stat,
            mesh=cfg.mesh,
            in_specs=in_specs,
            out_specs=spec2,
            check_vma=False,
        )(a, b)
        return _filter_c(out, plan)

    if plan.local_impl == "bsmm":
        cols = jnp.asarray(plan.local_cols)
        cols_spec = P(cfg.row_axis, cfg.col_axis, None, None)

        def fn_bsmm(a_loc, b_loc, cols_loc):
            c = _exec_sparse_bsmm(a_loc, b_loc, cols_loc[0, 0], plan)
            return c.astype(out_dtype)

        out = shard_map(
            fn_bsmm,
            mesh=cfg.mesh,
            in_specs=(spec2, spec2, cols_spec),
            out_specs=spec2,
            check_vma=False,
        )(a, b, cols)
        return _filter_c(out, plan)

    if plan.local_impl in ("masked", "ranksparse"):
        # Rank plans given dense-stored operands run the masked DAG: the
        # ranks informed the cost model / scheduler, but without factors
        # there is nothing rank-sized to multiply (execute_rank_plan is
        # the factorized path).
        run = (
            _exec_sparse_pull
            if getattr(plan, "comm_mode", "broadcast") == "pull"
            else _exec_sparse_dag
        )

        def fn_masked(a_loc, b_loc):
            return run(a_loc, b_loc, plan).astype(out_dtype)

        out = shard_map(
            fn_masked,
            mesh=cfg.mesh,
            in_specs=(spec2, spec2),
            out_specs=spec2,
            check_vma=False,
        )(a, b)
        return _filter_c(out, plan)

    local = _EXEC_IMPLS[cfg.strategy]

    def fn_dense(a_loc, b_loc):
        return local(a_loc, b_loc, plan).astype(out_dtype)

    out = shard_map(
        fn_dense,
        mesh=cfg.mesh,
        in_specs=(spec2, spec2),
        out_specs=spec2,
        check_vma=False,
    )(a, b)
    return _filter_c(out, plan)


def rank_operands(a_ranks, plan) -> tuple[np.ndarray, np.ndarray]:
    """Lay a ``RankCSR`` out as the dense-stored factor operands the
    rank-sparse executor consumes.

    Returns ``(u_all, v_all)``: ``u_all`` is (m_pad, k_steps·r_pad) with
    block row ``i``, panel ``kk`` holding ``U[i,kk]`` at column offset
    ``kk·r_pad`` (zero beyond the true rank); ``v_all`` is
    (m_blocks·r_pad, k_pad) with ``V[i,kk]`` at row offset ``i·r_pad``,
    column offset ``kk·bk``.  Both shard P(row_axis, col_axis) exactly
    like A — every U/V panel lives on the device that owns the matching A
    panel, so ``_bcast_panel``'s owner arithmetic carries over unchanged.
    Memoized per padded geometry on the (frozen) ``RankCSR`` so repeated
    eager calls don't re-lay-out the factors.
    """
    cache_key = ("_rank_operands", plan.m_pad, plan.k_pad, plan.k_steps)
    cached = a_ranks.__dict__.get(cache_key)
    if cached is not None:
        return cached
    bm, bk = a_ranks.bm, a_ranks.bk
    r_pad = a_ranks.r_pad
    csr = a_ranks.csr
    m_blk_p = plan.m_pad // bm
    k_steps = plan.k_steps
    u_all = np.zeros((plan.m_pad, k_steps * r_pad), np.float32)
    v_all = np.zeros((m_blk_p * r_pad, plan.k_pad), np.float32)
    for i in range(csr.m_blocks):
        lo, hi = csr.row_ptr[i], csr.row_ptr[i + 1]
        for s in range(lo, hi):
            kk = int(csr.col_idx[s])
            u_all[i * bm : (i + 1) * bm, kk * r_pad : (kk + 1) * r_pad] = (
                a_ranks.u[s]
            )
            v_all[i * r_pad : (i + 1) * r_pad, kk * bk : (kk + 1) * bk] = (
                a_ranks.v[s]
            )
    a_ranks.__dict__[cache_key] = (u_all, v_all)
    return u_all, v_all


def execute_rank_plan(
    u: jax.Array,
    v: jax.Array,
    b: jax.Array,
    plan,
    *,
    out_dtype: Any | None = None,
    compiled: bool = True,
) -> jax.Array:
    """Run C = A @ B with A given as factorized rank-sparse operands.

    ``u``/``v`` come from :func:`rank_operands` (already padded); ``b``
    must be padded to the plan's (k_pad, n_pad).  All three are sharded
    P(row_axis, col_axis).  Requires ``plan.local_impl == "ranksparse"``
    (the planner guarantees the factor layout fits the grid).  With
    ``local_matmul="pallas"`` the gathered live panels run through the
    grouped-gemm kernel (kernels/grouped_gemm.py), stage 1 being the
    ragged per-rank V·B gemms.

    Eager calls dispatch a cached jitted program keyed by the plan digest
    + operand shapes/dtypes.  The factors are *runtime arguments*, never
    trace constants — the digest (like ``plan.rank_key``) sees only the
    rank structure, so baking values in would silently serve stale
    factors to a same-structure payload.
    """
    out_dtype = jnp.dtype(out_dtype or b.dtype)
    if compiled and not _is_traced(u, v, b):
        _check_rank_operands(u, v, b, plan)  # eager, caller-friendly errors
        key = (
            "rank", plan.digest(), plan.resolve_lookahead(),
            u.shape, v.shape, str(u.dtype), str(v.dtype), str(b.dtype),
            str(out_dtype),
        )

        def build():
            def traced(u, v, b):
                _EXEC_STATS["retraces"] += 1
                return _execute_rank_plan_eager(
                    u, v, b, plan, out_dtype=out_dtype
                )

            return jax.jit(traced)

        return _cached_executable(key, build)(u, v, b)
    return _execute_rank_plan_eager(u, v, b, plan, out_dtype=out_dtype)


def _check_rank_operands(u, v, b, plan) -> None:
    if plan.local_impl != "ranksparse":
        raise ValueError(
            f"plan.local_impl={plan.local_impl!r}: not a rank-sparse plan "
            "(factor layout needs M blocks aligned to the grid rows; "
            "densify with RankCSR.to_dense() and use execute_plan)"
        )
    k_r = u.shape[1]
    if k_r % plan.k_steps:
        raise ValueError(
            f"U width {k_r} must be k_steps={plan.k_steps} factor panels"
        )
    r_pad = k_r // plan.k_steps
    (mp, kp), (_, np_) = plan.padded_shapes
    m_blk_p = v.shape[0] // r_pad
    if (
        u.shape[0] != mp
        or v.shape != (m_blk_p * r_pad, kp)
        or b.shape != (kp, np_)
    ):
        raise ValueError(
            f"factor operands u{u.shape}/v{v.shape}/b{b.shape} do not "
            f"match the plan's padded shapes ({mp},{kp}) @ ({kp},{np_})"
        )


def _execute_rank_plan_eager(
    u: jax.Array,
    v: jax.Array,
    b: jax.Array,
    plan,
    *,
    out_dtype: Any | None = None,
) -> jax.Array:
    """The factorized-interpreter body (see ``execute_rank_plan``)."""
    cfg = plan.cfg
    _check_rank_operands(u, v, b, plan)  # shapes are static under a trace
    r_pad = u.shape[1] // plan.k_steps
    out_dtype = out_dtype or b.dtype
    spec2 = P(cfg.row_axis, cfg.col_axis)
    if plan.b_mask is not None:
        b = _apply_block_mask(b, plan.b_mask)
    if getattr(plan, "comm_mode", "broadcast") == "pull":
        # factor-fetching pull route (repro.spgemm): rank-sized gets for
        # both local_matmul flavors — the grouped kernel's gather stage is
        # broadcast-shaped, so pull always runs the indexed-read emulation
        local = _exec_ranksparse_pull
    else:
        local = (
            _exec_ranksparse_grouped
            if cfg.local_matmul == "pallas"
            else _exec_ranksparse
        )

    def fn_rank(u_loc, v_loc, b_loc):
        c = local(u_loc, v_loc, b_loc, plan, r_pad=r_pad)
        return c.astype(out_dtype)

    out = shard_map(
        fn_rank,
        mesh=cfg.mesh,
        in_specs=(spec2, spec2, spec2),
        out_specs=spec2,
        check_vma=False,
    )(u, v, b)
    return _filter_c(out, plan)


# ---------------------------------------------------------------------------
# Public entry points (thin wrappers planning + executing)
# ---------------------------------------------------------------------------


def summa_matmul(
    a: jax.Array,
    b: jax.Array,
    cfg: SummaConfig,
    *,
    out_dtype: Any | None = None,
) -> jax.Array:
    """Distributed C = A @ B with the configured SUMMA strategy.

    ``a``: (M, K) sharded P(row_axis, col_axis); ``b``: (K, N) sharded
    P(row_axis, col_axis); returns (M, N) sharded P(row_axis, col_axis).
    Shapes must divide evenly by the grid (use core.api.DistributedMatmul
    for auto-padding).
    """
    from repro.core.plan import plan_matmul

    (m, k), (k2, n) = a.shape, b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    p_row, p_col = cfg.p_row, cfg.p_col
    if m % p_row or n % p_col or k % math.lcm(p_row, p_col):
        raise ValueError(
            f"shapes ({m},{k})x({k2},{n}) must divide grid ({p_row},{p_col})"
        )
    plan = plan_matmul(m, k, n, cfg, itemsize=a.dtype.itemsize)
    if plan.padded_shapes != (a.shape, b.shape):
        raise ValueError(
            f"shapes ({m},{k})x({k2},{n}) need padding for grid/k_blocks; "
            "use core.api.DistributedMatmul for auto-padding"
        )
    return execute_plan(a, b, plan, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# 2.5D task-based SUMMA (paper §3: "immediately applicable to the 2.5D
# variant since it's based on 2D SUMMA")
# ---------------------------------------------------------------------------


def summa_25d_matmul(
    a: jax.Array,
    b: jax.Array,
    cfg: SummaConfig,
    *,
    rep_axis: str = "pod",
    out_dtype: Any | None = None,
    plan=None,
) -> jax.Array:
    """2.5D task-based SUMMA: operands replicated over ``rep_axis`` (c
    copies), each replica executes a disjoint 1/c of the SUMMA iterations
    (multiple-issue within its range), and the partial C's are summed
    across replicas — Solomonik-Demmel's memory-for-communication trade
    with the paper's task pipeline inside each replica.

    Per-replica broadcast traffic drops by c at the cost of c× operand
    memory + one C all-reduce over ``rep_axis``.

    ``plan`` accepts a precomputed (possibly tuned) ``MatmulPlan`` for
    these shapes; by default one is derived here.
    """
    from repro.core.plan import plan_matmul

    (m, k), (k2, n) = a.shape, b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    if rep_axis not in cfg.mesh.shape:
        raise ValueError(
            f"rep_axis {rep_axis!r} is not a mesh axis; "
            f"available: {tuple(cfg.mesh.shape)}"
        )
    c_rep = cfg.mesh.shape[rep_axis]
    if plan is None:
        plan = plan_matmul(m, k, n, cfg, itemsize=a.dtype.itemsize)
    if plan.padded_shapes != (a.shape, b.shape):
        raise ValueError(
            f"shapes ({m},{k})x({k2},{n}) need padding for grid/k_blocks"
        )
    k_steps = plan.k_steps
    if k_steps % c_rep:
        raise ValueError(
            f"replica count {c_rep} (mesh axis {rep_axis!r}) must divide "
            f"k_blocks={k_steps} so each replica owns an equal K sub-range"
        )
    per_rep = k_steps // c_rep
    out_dtype = jnp.dtype(out_dtype or a.dtype)

    def run(a, b):
        def fn(a_loc, b_loc):
            k_start = jax.lax.axis_index(rep_axis) * per_rep
            c_acc = _exec_taskbased(
                a_loc, b_loc, plan, k_steps=per_rep, k_start=k_start
            )
            c_acc = jax.lax.psum(c_acc, rep_axis)
            return c_acc.astype(out_dtype)

        # no rep_axis in the specs: replicated operands
        spec2 = P(cfg.row_axis, cfg.col_axis)
        return shard_map(
            fn,
            mesh=cfg.mesh,
            in_specs=(spec2, spec2),
            out_specs=spec2,
            check_vma=False,
        )(a, b)

    if _is_traced(a, b):
        return run(a, b)
    key = (
        "25d", plan.digest(), rep_axis, per_rep,
        str(a.dtype), str(b.dtype), str(out_dtype),
    )

    def build():
        def traced(a, b):
            _EXEC_STATS["retraces"] += 1
            return run(a, b)

        return jax.jit(traced)

    return _cached_executable(key, build)(a, b)


# ---------------------------------------------------------------------------
# Block-sparse SUMMA (the paper's target use case)
# ---------------------------------------------------------------------------


def summa_blocksparse_matmul(
    a: jax.Array,
    b: jax.Array,
    a_mask: np.ndarray,
    b_mask: np.ndarray,
    cfg: SummaConfig,
    *,
    out_dtype: Any | None = None,
) -> jax.Array:
    """Block-sparse distributed C = A @ B.

    ``a_mask``: (M_blk, K_blk) bool; ``b_mask``: (K_blk, N_blk) bool — the
    *static* block-structure (distance decay / screening in the paper's
    domain).  One SUMMA panel per K block.  Panels the plan marks globally
    dead are skipped at trace time (no broadcast, no compute); with
    ``local_matmul="pallas"`` the surviving panels run through the BSMM
    scalar-prefetch kernel on the plan's per-device CSR maps, so FLOPs
    follow the per-device fill-in.
    """
    from repro.core.plan import plan_matmul

    (m, k), (k2, n) = a.shape, b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    plan = plan_matmul(
        m, k, n, cfg, a_mask=a_mask, b_mask=b_mask,
        itemsize=a.dtype.itemsize,
    )
    if plan.padded_shapes != (a.shape, b.shape):
        raise ValueError(
            f"shape/grid/blocking mismatch: ({m},{k})x({k2},{n}) on grid "
            f"({cfg.p_row},{cfg.p_col}) with {plan.k_steps} K blocks needs "
            f"padding to {plan.padded_shapes}; use core.api.DistributedMatmul"
        )
    return execute_plan(a, b, plan, out_dtype=out_dtype)


def _filter_c(out: jax.Array, plan) -> jax.Array:
    """Apply the plan's output filter: dead C blocks are zeroed, so an
    execution can never populate blocks the output structure excludes
    (numerically significant when ``c_mask`` is narrower than the
    symbolic ``a (.) b`` product)."""
    c_mask = getattr(plan, "c_mask", None)
    if c_mask is not None:
        out = _apply_block_mask(out, c_mask)
    return out


def _apply_block_mask(x: jax.Array, mask: np.ndarray) -> jax.Array:
    """Zero out masked blocks of a (R, C) array given an (Rb, Cb) mask."""
    r, c = x.shape
    rb, cb = mask.shape
    if r % rb or c % cb:
        raise ValueError(f"array {x.shape} not divisible by mask {mask.shape}")
    fine = jnp.asarray(np.repeat(np.repeat(mask, r // rb, 0), c // cb, 1))
    return jnp.where(fine, x, jnp.zeros((), x.dtype))
