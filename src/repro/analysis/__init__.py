"""Compiled-artifact analysis: HLO parsing, roofline terms."""
