"""HLO analysis: trip-count-weighted FLOPs, bytes, and collective traffic.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
under-reports scanned layer stacks and microbatch loops by orders of
magnitude.  This module parses the optimized HLO text instead:

1. Build the computation call graph (while bodies with their
   ``known_trip_count`` backend configs, fusions, calls, conditionals).
2. Propagate execution weights from ENTRY (a body nested in two 16-trip
   scans gets weight 256).
3. Per computation, count
   * dot FLOPs  = 2 x |result| x |contraction dims|  (MXU work),
   * result bytes of every materializing instruction (x2 for read+write —
     the HBM-traffic proxy),
   * collective result bytes by op kind.

Roofline terms (TPU v5e-like): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

__all__ = [
    "HW",
    "DEFAULT_HW",
    "WeightedCost",
    "analyze_hlo",
    "roofline",
    "RooflineReport",
]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link


DEFAULT_HW = HW()

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# Buffers at or below this size are modelled as VMEM-resident (v5e has
# 128 MB VMEM; 16 MB covers flash tiles and sequential grad accumulators
# while leaving room for double-buffering).
_VMEM_RESIDENT = 16 * 1024 * 1024

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction line:  %name = <shape or tuple> opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\((?:[^()]|\([^()]*\))*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")


def _parse_dims(dims: str) -> list[int]:
    return [int(d) for d in dims.split(",") if d]


def _shape_list(shape_str: str) -> list[tuple[str, list[int]]]:
    """All (dtype, dims) found in a shape string (handles tuples)."""
    return [
        (m.group(1), _parse_dims(m.group(2)))
        for m in _SHAPE_RE.finditer(shape_str)
        if m.group(1) in _DTYPE_BYTES
    ]


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_list(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


# Ops that represent real HBM traffic on TPU.  Un-fused elementwise ops in
# CPU HLO are skipped: the TPU pipeline fuses them into neighbours, so
# counting them would systematically overstate the memory term.
_MAJOR_BYTES_OPS = {
    "dot", "fusion", "reduce", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "convolution",
    "sort", "select-and-scatter", "pad", "cholesky", "triangular-solve",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "custom-call", "rng", "rng-bit-generator",
}


@dataclasses.dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes_written: float = 0.0
    coll_bytes: dict | None = None
    coll_counts: dict | None = None
    # edges: (callee_name, multiplier)
    edges: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class WeightedCost:
    flops: float
    hbm_bytes: float
    coll_bytes_by_op: dict[str, float]
    coll_counts_by_op: dict[str, float]

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll_bytes_by_op.values())

    @property
    def wire_bytes(self) -> float:
        return wire_bytes(self.coll_bytes_by_op)


def wire_bytes(bytes_by_op: dict[str, float], group: int = 16) -> float:
    """Per-device ICI wire traffic from result-shape bytes.

    Ring-algorithm cost model per device (g = group size):
      all-gather:        result x (g-1)/g      (result is the gathered buf)
      all-reduce:        2 x result x (g-1)/g  (reduce-scatter + all-gather)
      reduce-scatter:    result x (g-1)        (result is the 1/g shard)
      all-to-all:        result x (g-1)/g
      collective-permute: result               (one hop)
    """
    f = (group - 1) / group
    w = 0.0
    w += bytes_by_op.get("all-gather", 0.0) * f
    w += bytes_by_op.get("all-reduce", 0.0) * 2 * f
    w += bytes_by_op.get("reduce-scatter", 0.0) * (group - 1)
    w += bytes_by_op.get("all-to-all", 0.0) * f
    w += bytes_by_op.get("collective-permute", 0.0)
    return w


def _parse_computations(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry_name = None
    shapes: dict[str, str] = {}  # instr name -> result shape str (per comp)
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr and (line.startswith("ENTRY") or line.startswith("%")):
            cur = _Comp(
                name=hdr.group(1),
                coll_bytes={op: 0.0 for op in COLLECTIVE_OPS},
                coll_counts={op: 0.0 for op in COLLECTIVE_OPS},
            )
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry_name = cur.name
            shapes = {}
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, opcode, rest = m.groups()
        shapes[name] = shape_str
        result_bytes = _shape_bytes(shape_str)
        if opcode == "convert":
            # dtype converts fuse into their consumer on TPU (e.g. int8
            # KV-cache dequant feeding a matmul reads int8, not f32):
            # propagate the SOURCE shape for traffic accounting.
            src = re.findall(r"%([\w.\-]+)", rest)
            if src and src[0] in shapes:
                shapes[name] = shapes[src[0]]
        if opcode in _MAJOR_BYTES_OPS:
            # HBM traffic model: buffers small enough to live in VMEM
            # (<= _VMEM_RESIDENT bytes) are free — the TPU pipeline keeps
            # tiles on-chip.  Slice-type ops touch only the slice, not
            # their operand; dynamic-update-slice/scatter touch only the
            # update region, not the full buffer.
            operand_names = re.findall(
                r"%([\w.\-]+)", rest.split("), ")[0]
            )
            if opcode in ("dynamic-slice", "slice", "gather"):
                traffic = 2 * result_bytes  # read slice + write slice
            elif opcode in ("dynamic-update-slice", "scatter"):
                upd_idx = 1 if opcode == "dynamic-update-slice" else 2
                upd = (
                    _shape_bytes(shapes[operand_names[upd_idx]])
                    if len(operand_names) > upd_idx
                    and operand_names[upd_idx] in shapes
                    else result_bytes
                )
                traffic = 2 * upd
                if traffic > 2 * result_bytes:
                    traffic = 2 * result_bytes
            elif opcode == "fusion" and "dynamic-update-slice" in name:
                # DUS-rooted fusion: XLA updates the buffer in place; the
                # traffic is the update region (operands minus the buffer
                # itself), not the whole buffer.
                ops_b = sorted(
                    (
                        _shape_bytes(shapes[o])
                        for o in operand_names
                        if o in shapes
                    ),
                    reverse=True,
                )
                traffic = 2 * sum(ops_b[1:]) if len(ops_b) > 1 else result_bytes
                traffic = min(traffic, 2 * result_bytes)
            elif opcode == "fusion" and (
                "dynamic-slice" in name or "gather" in name
            ):
                traffic = 2 * result_bytes
            elif opcode == "fusion" and "reduce" not in name:
                # loop/elementwise fusion: each operand contributes at most
                # O(result) traffic (a fused slice reads the slice, a
                # broadcast reads the source once) — cap the per-operand
                # charge; reduce-rooted fusions legitimately read more and
                # are handled below.
                cap = max(4 * result_bytes, _VMEM_RESIDENT)
                traffic = sum(
                    min(_shape_bytes(shapes[o]), cap)
                    for o in operand_names
                    if o in shapes
                    and _shape_bytes(shapes[o]) > _VMEM_RESIDENT
                )
                if result_bytes > _VMEM_RESIDENT:
                    traffic += result_bytes
            else:
                traffic = sum(
                    b
                    for o in operand_names
                    if o in shapes
                    and (b := _shape_bytes(shapes[o])) > _VMEM_RESIDENT
                )
                if result_bytes > _VMEM_RESIDENT:
                    traffic += result_bytes
            if traffic > _VMEM_RESIDENT:
                cur.bytes_written += traffic
        # --- call graph edges
        if opcode == "while":
            bm = re.search(r"body=%?([\w.\-]+)", line)
            trip = 1
            tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
            if tm:
                trip = int(tm.group(1))
            if bm:
                cur.edges.append((bm.group(1), trip))
            cm = re.search(r"condition=%?([\w.\-]+)", line)
            if cm:
                cur.edges.append((cm.group(1), trip))
        elif opcode == "fusion":
            fm = re.search(r"calls=%?([\w.\-]+)", line)
            if fm:
                cur.edges.append((fm.group(1), 1))
        elif opcode == "call":
            fm = re.search(r"to_apply=%?([\w.\-]+)", line)
            if fm:
                cur.edges.append((fm.group(1), 1))
        elif opcode == "conditional":
            for fm in re.finditer(r"(?:true|false)_computation=%?([\w.\-]+)", line):
                cur.edges.append((fm.group(1), 1))
            bm = re.search(r"branch_computations=\{([^}]*)\}", line)
            if bm:
                for nm in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                    cur.edges.append((nm, 1))
        elif opcode in ("reduce", "sort", "scatter", "map", "reduce-window"):
            fm = re.search(r"to_apply=%?([\w.\-]+)", line)
            if fm:
                cur.edges.append((fm.group(1), 1))
        # --- dot flops
        if opcode == "dot":
            flops = _dot_flops(line, shape_str, shapes)
            cur.flops += flops
        # --- collectives
        if opcode in COLLECTIVE_OPS:
            cur.coll_bytes[opcode] += result_bytes
            cur.coll_counts[opcode] += 1
        elif opcode.endswith("-start") and opcode[:-6] in COLLECTIVE_OPS:
            cur.coll_bytes[opcode[:-6]] += result_bytes
            cur.coll_counts[opcode[:-6]] += 1
    comps["__entry__"] = comps.get(entry_name, _Comp(name="__entry__"))
    comps["__entry_name__"] = entry_name  # type: ignore[assignment]
    return comps


def _dot_flops(line: str, result_shape: str, shapes: dict[str, str]) -> float:
    """2 x |result| x |lhs contracting dims|."""
    res = _shape_list(result_shape)
    if not res:
        return 0.0
    result_elems = 1
    for d in res[0][1]:
        result_elems *= d
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    # First operand may be printed bare (``dot(%x,``) or with its type
    # (``dot(f32[64,64]{1,0} %x,`` — older jax HLO text, whose layout
    # braces contain commas): take the first %name after ``dot(``.
    om = re.search(r"dot\([^%)]*%([\w.\-]+)", line)
    if om is None:
        om = re.search(r"dot\(([\w.\-]+),", line)
    contract = 1
    if cm and om:
        lhs_shape = shapes.get(om.group(1))
        if lhs_shape:
            dims = _shape_list(lhs_shape)
            if dims:
                lhs_dims = dims[0][1]
                for idx in _parse_dims(cm.group(1)):
                    if idx < len(lhs_dims):
                        contract *= lhs_dims[idx]
    return 2.0 * result_elems * contract


def analyze_hlo(hlo_text: str) -> WeightedCost:
    comps = _parse_computations(hlo_text)
    entry_name = comps.pop("__entry_name__", None)  # type: ignore[arg-type]
    comps.pop("__entry__", None)
    if entry_name is None or entry_name not in comps:
        # fall back: treat the computation with most flops as entry
        entry_name = max(comps, key=lambda c: comps[c].flops) if comps else None
    weights: dict[str, float] = {c: 0.0 for c in comps}

    def visit(name: str, w: float, depth=0):
        if name not in comps or depth > 50:
            return
        weights[name] += w
        for callee, mult in comps[name].edges:
            visit(callee, w * mult, depth + 1)

    if entry_name:
        visit(entry_name, 1.0)

    flops = 0.0
    bts = 0.0
    coll_b = {op: 0.0 for op in COLLECTIVE_OPS}
    coll_c = {op: 0.0 for op in COLLECTIVE_OPS}
    for name, comp in comps.items():
        w = weights.get(name, 0.0)
        if w == 0.0:
            continue
        flops += w * comp.flops
        bts += w * comp.bytes_written
        for op in COLLECTIVE_OPS:
            coll_b[op] += w * comp.coll_bytes[op]
            coll_c[op] += w * comp.coll_counts[op]
    return WeightedCost(
        flops=flops,
        hbm_bytes=bts,  # operand reads + result writes of major ops
        coll_bytes_by_op=coll_b,
        coll_counts_by_op=coll_c,
    )


@dataclasses.dataclass
class RooflineReport:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    bound_s: float

    def row(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def roofline(
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    chips: int,
    model_flops: float = 0.0,
    hw: HW = DEFAULT_HW,
) -> RooflineReport:
    """Three-term roofline from *per-device* HLO quantities."""
    compute_s = flops / hw.peak_flops
    memory_s = hbm_bytes / hw.hbm_bw
    collective_s = coll_bytes / hw.ici_bw
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    useful = model_flops / (flops * chips) if flops else 0.0
    return RooflineReport(
        flops=flops,
        hbm_bytes=hbm_bytes,
        coll_bytes=coll_bytes,
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=useful,
        bound_s=max(terms.values()),
    )
