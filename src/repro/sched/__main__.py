"""Simulator CLI: schedule a virtual SUMMA and export the trace.

    PYTHONPATH=src python -m repro.sched --grid 4 4 --extent 2048 \
        --blocks 16 --nonuniform --lookahead eq1 \
        --trace sched_trace.json --json sched_sim.json

Runs entirely on the host (numpy): grids of thousands of virtual devices
are fine.  ``--lookahead eq1`` resolves paper Eq. (1); ``--compare``
additionally simulates I=1 and reports the multi-issue speedup (the
paper's imbalance-absorption result).
"""
from __future__ import annotations

import argparse
import json

from repro.core.blocking import nonuniform_tiling, uniform_tiling
from repro.sched.simulator import MachineModel, simulate
from repro.sched.taskgraph import eq1_lookahead, from_tilings


def _tilings(extent: int, blocks: int, nonuniform: bool, seed: int):
    if nonuniform:
        return [
            nonuniform_tiling(extent, blocks, seed=seed + s) for s in range(3)
        ]
    return [uniform_tiling(extent, -(-extent // blocks)) for _ in range(3)]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.sched")
    ap.add_argument("--grid", type=int, nargs=2, default=(4, 4),
                    metavar=("P_ROW", "P_COL"))
    ap.add_argument("--extent", type=int, default=2048,
                    help="square matrix extent N")
    ap.add_argument("--blocks", type=int, default=16,
                    help="logical blocks per dimension (= SUMMA iterations)")
    ap.add_argument("--nonuniform", action="store_true",
                    help="paper §4.1 nonuniform block sizes")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lookahead", default="eq1",
                    help="multiple-issue window I: an int, or 'eq1'")
    ap.add_argument("--itemsize", type=int, default=4)
    ap.add_argument("--flops", type=float, default=MachineModel.flops_per_s)
    ap.add_argument("--bandwidth", type=float, default=MachineModel.bytes_per_s)
    ap.add_argument("--latency", type=float, default=MachineModel.latency_s)
    ap.add_argument("--compare", action="store_true",
                    help="also simulate I=1 and report the speedup")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome/Perfetto trace JSON here")
    ap.add_argument("--json", default=None,
                    help="write the simulation summary JSON here")
    args = ap.parse_args(argv)

    p_row, p_col = args.grid
    row_t, inner_t, col_t = _tilings(
        args.extent, args.blocks, args.nonuniform, args.seed
    )
    if args.lookahead == "eq1":
        la = eq1_lookahead(p_row, p_col, inner_t.num_blocks)
    else:
        la = int(args.lookahead)
    machine = MachineModel(
        flops_per_s=args.flops, bytes_per_s=args.bandwidth,
        latency_s=args.latency, name="cli",
    )
    graph = from_tilings(
        p_row, p_col, row_t, inner_t, col_t,
        lookahead=la, itemsize=args.itemsize,
    )
    sim = simulate(graph, machine, trace=args.trace is not None)
    out = {"sim": sim.summary(), "tasks": graph.counts()}
    if args.compare:
        base = simulate(
            from_tilings(p_row, p_col, row_t, inner_t, col_t,
                         lookahead=1, itemsize=args.itemsize),
            machine,
        )
        out["serial_makespan_s"] = base.makespan_s
        out["multi_issue_speedup"] = (
            base.makespan_s / sim.makespan_s if sim.makespan_s > 0 else 1.0
        )
    print(json.dumps(out, indent=1))
    if args.trace:
        sim.write_chrome_trace(args.trace)
        print(f"# wrote {args.trace}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
