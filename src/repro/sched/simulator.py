"""Discrete-event simulation of an explicit matmul task DAG.

Each device contributes two resources — a compute unit (MXU) and a comm
unit (ICI link) — with their own clocks.  Tasks are processed in the
graph's topological order (list-scheduling DES): a task starts at the
max of its dependencies' finish times and its resources' free times, and
runs for a duration the :class:`MachineModel` derives from its FLOP /
byte cost.  Collective tasks (broadcasts, gathers) occupy the comm unit
of *every* group member, so a straggler delays the whole group — the
load-imbalance propagation that the multiple-issue window (encoded as
dependency edges by ``taskgraph``) exists to absorb.  One-sided
``fetch_a``/``fetch_b`` tasks (pull mode, repro.spgemm) list
``(receiver, owner)`` as their devices, so every fetch serializes on the
*owner's* comm clock as well — many requesters of one hot panel queue
there, which is exactly the pull-vs-broadcast crossover the 16 x 16+
virtual-grid experiments measure.

Outputs: makespan, per-device busy/idle split, imbalance ratio,
pipeline-efficiency, and a Chrome-trace (``chrome://tracing`` /
Perfetto) JSON export of the full schedule.

The comm cost model is intentionally the same one ``core.plan.PlanCost``
uses (broadcast-as-allreduce ~2x panel bytes; sparsity-blind bulk
gathers), so simulated and planned bytes agree — the simulator adds the
*time* dimension the static cost model lacks.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.sched.taskgraph import TaskGraph, from_plan

__all__ = [
    "MachineModel",
    "DEFAULT_MACHINE",
    "SimResult",
    "simulate",
    "simulate_plan",
]


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Per-device rates converting abstract task costs to seconds.

    Defaults sketch a TPU-class device (dense-matmul-sustained FLOP rate,
    one ICI link) — absolute numbers matter less than the compute:comm
    balance for schedule *comparisons*; calibrate ``flops_per_s`` from a
    measured GEMM for wall-time *predictions* (see benchmarks/run.py).
    """

    flops_per_s: float = 1.0e12
    bytes_per_s: float = 5.0e10
    latency_s: float = 1.0e-6  # per collective launch
    name: str = "default"

    def compute_time(self, flops: float) -> float:
        return flops / self.flops_per_s

    def comm_time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return nbytes / self.bytes_per_s + self.latency_s

    def task_time(self, task) -> float:
        if task.resource == "comm":
            return self.comm_time(task.bytes)
        return self.compute_time(task.flops)


DEFAULT_MACHINE = MachineModel()


@dataclasses.dataclass
class SimResult:
    """Outcome of one schedule simulation."""

    makespan_s: float
    busy_compute_s: np.ndarray  # (n_devices,) time the MXU was occupied
    busy_comm_s: np.ndarray  # (n_devices,) time the comm unit was occupied
    graph_meta: dict
    machine: MachineModel
    spans: list | None = None  # (task, start, finish) when traced

    @property
    def n_devices(self) -> int:
        return len(self.busy_compute_s)

    @property
    def idle_s(self) -> np.ndarray:
        """Per-device compute idle time under the makespan."""
        return self.makespan_s - self.busy_compute_s

    @property
    def imbalance_ratio(self) -> float:
        """max/min per-device compute busy time (paper Table 1 style)."""
        busy = self.busy_compute_s
        lo = busy[busy > 0].min() if (busy > 0).any() else 0.0
        return float(busy.max() / lo) if lo > 0 else 1.0

    @property
    def efficiency(self) -> float:
        """Mean compute utilisation: 1.0 = no device ever idle."""
        if self.makespan_s <= 0:
            return 1.0
        return float(self.busy_compute_s.mean() / self.makespan_s)

    def summary(self) -> dict:
        return {
            "makespan_s": self.makespan_s,
            "devices": self.n_devices,
            "busy_compute_mean_s": float(self.busy_compute_s.mean()),
            "busy_compute_max_s": float(self.busy_compute_s.max()),
            "busy_comm_mean_s": float(self.busy_comm_s.mean()),
            "idle_mean_s": float(self.idle_s.mean()),
            "imbalance_ratio": self.imbalance_ratio,
            "efficiency": self.efficiency,
            "machine": self.machine.name,
            **{
                k: self.graph_meta[k]
                for k in ("strategy", "lookahead", "grid", "shape")
                if k in self.graph_meta
            },
        }

    def fingerprint(self) -> str:
        """Stable digest of the simulated schedule: makespan plus every
        recorded span, hashed at full float64 precision.  Two simulations
        of the same graph on the same machine model must be *bitwise*
        identical — the determinism contract the golden-trace test pins
        (the simulator is pure numpy list-scheduling; any nondeterminism
        is a bug)."""
        import hashlib

        h = hashlib.sha1()
        h.update(np.float64(self.makespan_s).tobytes())
        for task, start, finish in self.spans or ():
            h.update(f"{task.tid}:{task.kind}:{task.step}".encode())
            h.update(np.asarray([start, finish], np.float64).tobytes())
        return h.hexdigest()

    # -- Chrome trace --------------------------------------------------------

    def chrome_trace(self) -> dict:
        """``chrome://tracing`` / Perfetto JSON of the simulated schedule.

        One process row per device; compute and comm are separate thread
        rows.  Collective tasks are drawn on every participating device.
        """
        if self.spans is None:
            raise ValueError("simulate(..., trace=True) to record spans")
        events = []
        p_col = self.graph_meta.get("grid", [1, 1])[1]
        for task, start, finish in self.spans:
            tid = 0 if task.resource == "compute" else 1
            for d in task.devices:
                events.append(
                    {
                        "name": f"{task.kind}[{task.step}]",
                        "cat": task.resource,
                        "ph": "X",
                        "ts": start * 1e6,
                        "dur": max((finish - start) * 1e6, 0.01),
                        "pid": int(d),
                        "tid": tid,
                        "args": {
                            "flops": task.flops,
                            "bytes": task.bytes,
                            "device": [d // p_col, d % p_col],
                        },
                    }
                )
        meta = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": int(d),
                "args": {"name": f"dev({d // p_col},{d % p_col})"},
            }
            for d in range(self.n_devices)
        ] + [
            {
                "name": "thread_name",
                "ph": "M",
                "pid": int(d),
                "tid": tid,
                "args": {"name": name},
            }
            for d in range(self.n_devices)
            for tid, name in ((0, "compute"), (1, "comm"))
        ]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


def simulate(
    graph: TaskGraph,
    machine: MachineModel = DEFAULT_MACHINE,
    *,
    trace: bool = False,
) -> SimResult:
    """Run the DAG through the per-device-clock event simulation."""
    ndev = graph.n_devices
    comp_free = np.zeros(ndev)
    comm_free = np.zeros(ndev)
    busy_comp = np.zeros(ndev)
    busy_comm = np.zeros(ndev)
    finish = np.zeros(len(graph.tasks))
    spans = [] if trace else None
    res_free = {"compute": comp_free, "comm": comm_free}
    res_busy = {"compute": busy_comp, "comm": busy_comm}
    for task, deps in zip(graph.tasks, graph.deps):
        free = res_free[task.resource]
        start = max((finish[d] for d in deps), default=0.0)
        for d in task.devices:
            if free[d] > start:
                start = free[d]
        dur = machine.task_time(task)
        end = start + dur
        finish[task.tid] = end
        busy = res_busy[task.resource]
        for d in task.devices:
            free[d] = end
            busy[d] += dur
        if spans is not None:
            spans.append((task, start, end))
    makespan = float(max(comp_free.max(), comm_free.max())) if ndev else 0.0
    return SimResult(
        makespan_s=makespan,
        busy_compute_s=busy_comp,
        busy_comm_s=busy_comm,
        graph_meta=graph.meta,
        machine=machine,
        spans=spans,
    )


def simulate_plan(
    plan,
    machine: MachineModel = DEFAULT_MACHINE,
    *,
    strategy: str | None = None,
    lookahead: int | None = None,
    trace: bool = False,
) -> SimResult:
    """Materialize a ``MatmulPlan`` and simulate its schedule."""
    graph = from_plan(plan, strategy=strategy, lookahead=lookahead)
    return simulate(graph, machine, trace=trace)
