"""Materialize a matmul schedule as an explicit fine-grained task DAG.

The paper's task formulation (§3.2) expresses one SUMMA iteration as a
small family of tasks — broadcast the A column-panel, broadcast the B
row-panel, run the rank-k GEMM on every device, accumulate into C — with
real dependency edges between them.  ``core.summa`` realises that
formulation *implicitly* through XLA's scheduler; this module realises it
*explicitly*, so the schedule can be simulated, visualised, and tuned
without ever touching a device.

Two builders:

* :func:`from_plan` — materializes a ``core.plan.MatmulPlan``: one task
  group per live K panel, per-task FLOPs from the plan's per-device
  liveness / BlockCSR column maps (``local_impl="bsmm"``), per-task bytes
  from the same broadcast-as-allreduce model ``plan.PlanCost`` uses.
* :func:`from_tilings` — the paper's nonuniform-block experiment: logical
  blocks are cyclically embedded on a ``p_row x p_col`` grid
  (``core.blocking.cyclic_owner``) and per-task costs follow the actual
  block extents, so per-device load imbalance is visible per iteration.

The multiple-issue lookahead window ``I`` (paper Eq. 1) is encoded as
*dependency edges*: the broadcasts of iteration ``t`` depend on the
accumulate of iteration ``t - I`` on every device of their broadcast
group — at most ``I`` iterations are in flight per device, exactly the
in-flight-iteration cap of the paper's task scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

__all__ = [
    "Task",
    "TaskGraph",
    "from_plan",
    "from_tilings",
    "chain_graphs",
    "abstract_summa_config",
    "eq1_lookahead",
]

#: broadcast-as-allreduce moves ~2x the panel bytes of a tree broadcast
#: (same factor as ``core.plan._comm_model``).
BCAST_FACTOR = 2.0


@dataclasses.dataclass(frozen=True)
class Task:
    """One schedulable unit.  Costs are abstract (FLOPs / bytes); the
    simulator converts them to time through a ``MachineModel``."""

    tid: int
    # "bcast_a" | "bcast_b" | "gather_a" | "gather_b" | "fetch_a" |
    # "fetch_b" | "gemm" | "accum"; fetch tasks (one-sided pull) occupy
    # (receiver, owner) so requesters contend on the owner's comm clock
    kind: str
    step: int  # schedule position of the iteration (-1: not per-iteration)
    devices: tuple[int, ...]  # flat device ids whose resource this occupies
    resource: str  # "comm" | "compute"
    flops: float = 0.0
    bytes: float = 0.0


@dataclasses.dataclass
class TaskGraph:
    """An explicit task DAG over a ``p_row x p_col`` device grid.

    ``deps[tid]`` lists the task ids that must finish before ``tid``
    starts.  Tasks are stored in a topological order (builders emit them
    iteration by iteration), which the simulator relies on.
    """

    p_row: int
    p_col: int
    n_steps: int
    lookahead: int
    tasks: list[Task]
    deps: list[tuple[int, ...]]
    meta: dict

    @property
    def n_devices(self) -> int:
        return self.p_row * self.p_col

    def device(self, i: int, j: int) -> int:
        return i * self.p_col + j

    def counts(self) -> dict:
        out: dict[str, int] = {}
        for t in self.tasks:
            out[t.kind] = out.get(t.kind, 0) + 1
        return out

    def total_flops(self) -> float:
        return float(sum(t.flops for t in self.tasks))

    def total_bytes(self) -> float:
        return float(sum(t.bytes for t in self.tasks))

    def validate(self) -> None:
        """Cheap structural invariants (used by tests)."""
        for t, ds in zip(self.tasks, self.deps):
            for d in ds:
                if not 0 <= d < t.tid:
                    raise ValueError(
                        f"task {t.tid} depends on {d}: not topological"
                    )


class _AbstractMesh:
    """Duck-typed stand-in for ``jax.sharding.Mesh`` carrying only the
    axis-size table — enough for planning and simulation (``SummaConfig``
    touches nothing else until execution)."""

    def __init__(self, shape: dict):
        self.shape = dict(shape)

    @property
    def empty(self) -> bool:
        return False

    def __repr__(self) -> str:  # keep plan reprs readable
        return f"AbstractMesh({self.shape})"


def abstract_summa_config(p_row: int, p_col: int, **kwargs):
    """A ``SummaConfig`` over a virtual ``p_row x p_col`` grid.

    Lets the planner + simulator study grids far larger than the local
    device count (the paper's thousands-of-processes experiments) —
    such configs must never reach ``execute_plan``.
    """
    from repro.core.summa import SummaConfig

    mesh = _AbstractMesh({"data": p_row, "model": p_col})
    kwargs.setdefault("row_axis", "data")
    kwargs.setdefault("col_axis", "model")
    return SummaConfig(mesh=mesh, **kwargs)  # type: ignore[arg-type]


# ---------------------------------------------------------------------------
# shared emission machinery
# ---------------------------------------------------------------------------


class _Builder:
    def __init__(self, p_row: int, p_col: int):
        self.p_row = p_row
        self.p_col = p_col
        self.tasks: list[Task] = []
        self.deps: list[tuple[int, ...]] = []

    def dev(self, i: int, j: int) -> int:
        return i * self.p_col + j

    def add(
        self,
        kind: str,
        step: int,
        devices: Iterable[int],
        resource: str,
        deps: Iterable[int] = (),
        flops: float = 0.0,
        bytes: float = 0.0,
    ) -> int:
        tid = len(self.tasks)
        self.tasks.append(
            Task(
                tid=tid, kind=kind, step=step, devices=tuple(devices),
                resource=resource, flops=float(flops), bytes=float(bytes),
            )
        )
        self.deps.append(tuple(deps))
        return tid

    def graph(self, n_steps: int, lookahead: int, meta: dict) -> TaskGraph:
        return TaskGraph(
            p_row=self.p_row, p_col=self.p_col, n_steps=n_steps,
            lookahead=lookahead, tasks=self.tasks, deps=self.deps, meta=meta,
        )


def _emit_pipeline(
    b: _Builder,
    *,
    n_steps: int,
    lookahead: int,
    a_bytes,  # (step, grid_row) -> bytes of the A-panel broadcast (0: skip)
    b_bytes,  # (step, grid_col) -> bytes of the B-panel broadcast (0: skip)
    gemm_flops,  # (step, i, j) -> rank-k update FLOPs (0: dead, no task)
    accum_flops,  # (i, j) -> accumulate FLOPs per iteration
) -> None:
    """Emit the multiple-issue broadcast/gemm/accumulate pipeline.

    Window semantics: iteration ``t``'s broadcasts depend on the
    accumulates of iteration ``t - lookahead`` of every device in the
    broadcast group, capping in-flight iterations per device at
    ``lookahead`` (paper Eq. 1).
    """
    p_row, p_col = b.p_row, b.p_col
    # last accumulate (or gemm) tid per device, per past step
    accum_hist: list[dict[int, int]] = []
    prev_accum: dict[int, int] = {}
    for t in range(n_steps):
        window: dict[int, int] = (
            accum_hist[t - lookahead] if t >= lookahead else {}
        )
        a_tids: dict[int, int] = {}
        for i in range(p_row):
            bytes_ = a_bytes(t, i)
            if bytes_ <= 0:
                continue
            group = [b.dev(i, j) for j in range(p_col)]
            deps = [window[d] for d in group if d in window]
            a_tids[i] = b.add(
                "bcast_a", t, group, "comm", deps=deps, bytes=bytes_
            )
        b_tids: dict[int, int] = {}
        for j in range(p_col):
            bytes_ = b_bytes(t, j)
            if bytes_ <= 0:
                continue
            group = [b.dev(i, j) for i in range(p_row)]
            deps = [window[d] for d in group if d in window]
            b_tids[j] = b.add(
                "bcast_b", t, group, "comm", deps=deps, bytes=bytes_
            )
        step_accum: dict[int, int] = {}
        for i in range(p_row):
            for j in range(p_col):
                d = b.dev(i, j)
                flops = gemm_flops(t, i, j)
                if flops <= 0:
                    # dead iteration for this device: nothing occupies it,
                    # but the window still advances (carry previous task).
                    if d in prev_accum:
                        step_accum[d] = prev_accum[d]
                    continue
                deps = []
                if i in a_tids:
                    deps.append(a_tids[i])
                if j in b_tids:
                    deps.append(b_tids[j])
                if d in prev_accum:
                    deps.append(prev_accum[d])  # C-tile RAW dependency
                g = b.add("gemm", t, (d,), "compute", deps=deps, flops=flops)
                step_accum[d] = b.add(
                    "accum", t, (d,), "compute", deps=(g,),
                    flops=accum_flops(i, j),
                )
        prev_accum = {**prev_accum, **step_accum}
        accum_hist.append(dict(prev_accum))


def _emit_pull_pipeline(
    b: _Builder,
    *,
    n_steps: int,
    lookahead: int,
    owner_col,  # (step,) -> grid column owning the A panel
    owner_row,  # (step,) -> grid row owning the B panel
    a_fetch_bytes,  # (step, grid_row) -> bytes of one A-panel fetch
    b_fetch_bytes,  # (step, grid_col) -> bytes of one B-panel fetch
    gemm_flops,  # (step, i, j) -> rank-k update FLOPs (0: dead, no task)
    accum_flops,  # (i, j) -> accumulate FLOPs per iteration
) -> None:
    """The one-sided variant of :func:`_emit_pipeline` (RDMA-SpGEMM).

    No broadcast trees: each *surviving* gemm pulls exactly the panels it
    reads straight from their owners, at factor-1.0 bytes (a get moves
    the payload once).  A fetch occupies both endpoints — receiver and
    owner — on the comm resource, so many requesters of one hot panel
    serialize on the owner's clock; that contention, against broadcast's
    2x-bytes-but-parallel trees, is the crossover the simulator resolves.
    Dead gemms fetch nothing, which is where pull wins as fill drops.
    Window semantics match :func:`_emit_pipeline` (paper Eq. 1).
    """
    p_row, p_col = b.p_row, b.p_col
    accum_hist: list[dict[int, int]] = []
    prev_accum: dict[int, int] = {}
    for t in range(n_steps):
        window: dict[int, int] = (
            accum_hist[t - lookahead] if t >= lookahead else {}
        )
        oc, orow = owner_col(t), owner_row(t)
        step_accum: dict[int, int] = {}
        for i in range(p_row):
            for j in range(p_col):
                d = b.dev(i, j)
                flops = gemm_flops(t, i, j)
                if flops <= 0:
                    # dead iteration: no fetch, no gemm; the window still
                    # advances (carry previous task).
                    if d in prev_accum:
                        step_accum[d] = prev_accum[d]
                    continue
                deps = []
                if p_col > 1 and j != oc:
                    owner = b.dev(i, oc)
                    bytes_ = a_fetch_bytes(t, i)
                    if bytes_ > 0:
                        fdeps = [
                            window[x] for x in sorted({d, owner})
                            if x in window
                        ]
                        deps.append(b.add(
                            "fetch_a", t, (d, owner), "comm", deps=fdeps,
                            bytes=bytes_,
                        ))
                if p_row > 1 and i != orow:
                    owner = b.dev(orow, j)
                    bytes_ = b_fetch_bytes(t, j)
                    if bytes_ > 0:
                        fdeps = [
                            window[x] for x in sorted({d, owner})
                            if x in window
                        ]
                        deps.append(b.add(
                            "fetch_b", t, (d, owner), "comm", deps=fdeps,
                            bytes=bytes_,
                        ))
                if d in prev_accum:
                    deps.append(prev_accum[d])  # C-tile RAW dependency
                g = b.add("gemm", t, (d,), "compute", deps=deps, flops=flops)
                step_accum[d] = b.add(
                    "accum", t, (d,), "compute", deps=(g,),
                    flops=accum_flops(i, j),
                )
        prev_accum = {**prev_accum, **step_accum}
        accum_hist.append(dict(prev_accum))


def _emit_stationary(b: _Builder, plan) -> None:
    """The A-/B-stationary schedule as an explicit DAG (repro.spgemm).

    Mirrors ``summa._execute_plan_eager``'s transposed executors exactly:
    one re-layout of the *moving* operand (modeled broadcast-as-allreduce
    along the grid axis the stationarity chooser charges), one dense
    local dot per device — the executors prune structure at the value
    level only, so the honest FLOP charge is the full local product —
    and one bandwidth-optimal reduce-scatter of the partial C tiles per
    scatter group, factor ``(g-1)/g``.  No K pipeline, so no
    multiple-issue window.
    """
    p_row, p_col = b.p_row, b.p_col
    itemsize = plan.itemsize
    m_loc = plan.m_pad // p_row
    n_loc = plan.n_pad // p_col
    accum = float(m_loc * n_loc)

    def _kshard_elems(density: np.ndarray, n_groups: int) -> np.ndarray:
        """Split a per-K-element live-element density into the ``n_groups``
        contiguous K shards the re-layout distributes (total preserved even
        when shards straddle block boundaries)."""
        if density.size % n_groups == 0:
            return density.reshape(n_groups, -1).sum(axis=1)
        return np.full(n_groups, density.sum() / n_groups)

    if plan.stationarity == "A":
        # B re-lays out to P(col_axis, None): the grid-column group j
        # receives B's K-shard j (all N columns), then partial C tiles
        # reduce-scatter along the columns of each grid row.
        b_mask = getattr(plan, "b_mask", None)
        if b_mask is not None:
            kb_sz = plan.k_pad // b_mask.shape[0]
            bn_sz = plan.n_pad // b_mask.shape[1]
            dens = np.repeat(
                b_mask.sum(axis=1).astype(np.float64) * bn_sz, kb_sz
            )
        else:
            dens = np.full(plan.k_pad, float(plan.n_pad))
        shard_elems = _kshard_elems(dens, p_col)
        relay: dict[int, int] = {}
        if p_row > 1:  # same gate as the chooser's BCAST·vol_b·row term
            for j in range(p_col):
                bytes_ = BCAST_FACTOR * float(shard_elems[j]) * itemsize
                if bytes_ <= 0:
                    continue
                group = [b.dev(i, j) for i in range(p_row)]
                relay[j] = b.add(
                    "bcast_b", 0, group, "comm", bytes=bytes_
                )
        gemm_flops = 2.0 * m_loc * (plan.k_pad // max(p_col, 1)) * plan.n_pad
        scatter_bytes = (
            (p_col - 1) / p_col * m_loc * plan.n_pad * itemsize
            if p_col > 1 else 0.0
        )
        gemms: dict[tuple[int, int], int] = {}
        for i in range(p_row):
            for j in range(p_col):
                deps = [relay[j]] if j in relay else []
                gemms[i, j] = b.add(
                    "gemm", 0, (b.dev(i, j),), "compute", deps=deps,
                    flops=gemm_flops,
                )
        for i in range(p_row):
            group = [b.dev(i, j) for j in range(p_col)]
            deps = [gemms[i, j] for j in range(p_col)]
            rid = (
                b.add("reduce", 0, group, "comm", deps=deps,
                      bytes=scatter_bytes)
                if scatter_bytes > 0 else None
            )
            for j in range(p_col):
                b.add(
                    "accum", 0, (b.dev(i, j),), "compute",
                    deps=(rid,) if rid is not None else (gemms[i, j],),
                    flops=accum,
                )
    else:  # "B": A re-lays out to P(None, row_axis), scatter along rows
        a_mask = getattr(plan, "a_mask", None)
        if a_mask is not None:
            bm_sz = plan.m_pad // a_mask.shape[0]
            ka_sz = plan.k_pad // a_mask.shape[1]
            dens = np.repeat(
                a_mask.sum(axis=0).astype(np.float64) * bm_sz, ka_sz
            )
        else:
            dens = np.full(plan.k_pad, float(plan.m_pad))
        shard_elems = _kshard_elems(dens, p_row)
        relay = {}
        if p_col > 1:  # same gate as the chooser's BCAST·vol_a·col term
            for i in range(p_row):
                bytes_ = BCAST_FACTOR * float(shard_elems[i]) * itemsize
                if bytes_ <= 0:
                    continue
                group = [b.dev(i, j) for j in range(p_col)]
                relay[i] = b.add(
                    "bcast_a", 0, group, "comm", bytes=bytes_
                )
        gemm_flops = 2.0 * plan.m_pad * (plan.k_pad // max(p_row, 1)) * n_loc
        scatter_bytes = (
            (p_row - 1) / p_row * plan.m_pad * n_loc * itemsize
            if p_row > 1 else 0.0
        )
        gemms = {}
        for i in range(p_row):
            for j in range(p_col):
                deps = [relay[i]] if i in relay else []
                gemms[i, j] = b.add(
                    "gemm", 0, (b.dev(i, j),), "compute", deps=deps,
                    flops=gemm_flops,
                )
        for j in range(p_col):
            group = [b.dev(i, j) for i in range(p_row)]
            deps = [gemms[i, j] for i in range(p_row)]
            rid = (
                b.add("reduce", 0, group, "comm", deps=deps,
                      bytes=scatter_bytes)
                if scatter_bytes > 0 else None
            )
            for i in range(p_row):
                b.add(
                    "accum", 0, (b.dev(i, j),), "compute",
                    deps=(rid,) if rid is not None else (gemms[i, j],),
                    flops=accum,
                )


# ---------------------------------------------------------------------------
# builder 1: from a MatmulPlan
# ---------------------------------------------------------------------------


def _bsmm_step_flops(plan) -> np.ndarray:
    """(p_row, p_col, L) executed FLOPs per live-panel position from the
    plan's per-device BlockCSR column maps (``local_impl="bsmm"``)."""
    cols = plan.local_cols  # (p_row, p_col, mb_loc, S), -1 pad
    live = len(plan.live_panels)
    bm, bk, _ = plan.local_block
    n_loc = plan.n_pad // plan.p_col
    # count of local row blocks touching each gathered panel position
    cnt = (cols[..., None] == np.arange(live)).any(axis=3).sum(axis=2)
    return cnt.astype(np.float64) * (2.0 * bm * bk * n_loc)


def _rank_step_flops(plan) -> np.ndarray:
    """(p_row, p_col, L) executed FLOPs per live-panel position from the
    plan's per-block ranks (``local_impl="ranksparse"``).

    Device (i, j) charges, for each of its local block rows, the factored
    block cost of that row's rank in the panel (``block_rank_flops`` — the
    same per-block ordering-by-flop-count the executor applies), gated on
    the panel being live for the device at all.  This is where rank
    *nonuniformity* becomes per-device load imbalance the simulator and
    tuner can see.
    """
    from repro.core.sparsity import block_rank_flops

    p_row, p_col = plan.p_row, plan.p_col
    ranks = plan.a_ranks  # (M_blk, K_blk) padded
    m_blk = ranks.shape[0]
    mb_loc = m_blk // p_row
    bm = plan.m_pad // m_blk
    bk = plan.kb_width
    n_loc = plan.n_pad // p_col
    live = list(plan.live_panels)
    out = np.zeros((p_row, p_col, len(live)))
    for i in range(p_row):
        rows = ranks[i * mb_loc : (i + 1) * mb_loc, :]
        for t, kk in enumerate(live):
            flops = sum(
                block_rank_flops(int(r), bm, bk, n_loc) for r in rows[:, kk]
            )
            for j in range(p_col):
                if plan.device_live is None or plan.device_live[i, j, kk]:
                    out[i, j, t] = flops
    return out


def from_plan(
    plan,
    *,
    strategy: str | None = None,
    lookahead: int | None = None,
) -> TaskGraph:
    """Materialize a ``MatmulPlan`` into the explicit task DAG it implies.

    ``strategy`` defaults to the plan's own: the broadcast pipeline for
    ``procedural`` (window forced to 1) / ``taskbased`` (window = the
    plan's resolved lookahead), or the bulk-gather graph for
    ``allgather``.  Masked plans always build the pipeline over their
    *live* panels, with per-device FLOPs from the BlockCSR maps when the
    plan runs the BSMM kernel.
    """
    p_row, p_col = plan.p_row, plan.p_col
    itemsize = plan.itemsize
    m_loc = plan.m_pad // p_row
    n_loc = plan.n_pad // p_col
    kb = plan.kb_width
    steps = list(plan.live_panels)
    n_steps = len(steps)
    strategy = strategy or (
        plan.cfg.strategy if plan.local_impl == "dense" else "taskbased"
    )
    b = _Builder(p_row, p_col)
    # Grid column owning each emitted iteration's A panel (contiguous
    # panel schedule, same arithmetic as summa._panel_slices) — the chain
    # builder uses this to wire C(step i) -> bcast_a(step i+1) edges.
    t_a = max(plan.k_steps // p_col, 1)
    meta = {
        "source": "plan",
        "strategy": strategy,
        "shape": [plan.m, plan.k, plan.n],
        "grid": [p_row, p_col],
        "local_impl": plan.local_impl,
        "comm_mode": getattr(plan, "comm_mode", "broadcast"),
        "a_owner": [int(kk // t_a) for kk in steps],
    }

    if getattr(plan, "stationarity", "C") != "C":
        # A-/B-stationary schedules have no K pipeline: one re-layout of
        # the moving operand, one local dot per device, one reduce-scatter
        # per group (satellite of repro.spgemm — the chooser can pick
        # these, so the DAG layer must materialize them too).
        meta["strategy"] = "stationary"
        meta["stationarity"] = plan.stationarity
        meta["lookahead"] = 1
        _emit_stationary(b, plan)
        graph = b.graph(1, 1, meta)
        graph.validate()
        return graph

    if strategy == "allgather":
        if plan.local_impl != "dense":
            raise ValueError("allgather graph is dense-only (sparsity-blind)")
        ga: dict[int, int] = {}
        gb: dict[int, int] = {}
        if p_col > 1:
            bytes_a = itemsize * m_loc * plan.k_pad * (p_col - 1) / p_col
            for i in range(p_row):
                ga[i] = b.add(
                    "gather_a", -1, [b.dev(i, j) for j in range(p_col)],
                    "comm", bytes=bytes_a,
                )
        if p_row > 1:
            bytes_b = itemsize * plan.k_pad * n_loc * (p_row - 1) / p_row
            for j in range(p_col):
                gb[j] = b.add(
                    "gather_b", -1, [b.dev(i, j) for i in range(p_row)],
                    "comm", bytes=bytes_b,
                )
        flops = 2.0 * m_loc * plan.k_pad * n_loc
        for i in range(p_row):
            for j in range(p_col):
                deps = [t for t in (ga.get(i), gb.get(j)) if t is not None]
                g = b.add(
                    "gemm", 0, (b.dev(i, j),), "compute", deps=deps,
                    flops=flops,
                )
                b.add(
                    "accum", 0, (b.dev(i, j),), "compute", deps=(g,),
                    flops=float(m_loc * n_loc),
                )
        graph = b.graph(1, n_steps or 1, meta)
        graph.meta["lookahead"] = graph.lookahead
        return graph

    from repro.core.summa import resolve_multi_issue

    if strategy == "procedural":
        window = 1
    else:
        window = lookahead if lookahead is not None else plan.resolve_lookahead()
    # re-clamp: masked plans schedule only their live panels
    window = resolve_multi_issue(p_row, p_col, n_steps, window)
    meta["lookahead"] = window

    if plan.local_impl == "bsmm":
        step_flops = _bsmm_step_flops(plan)  # (p_row, p_col, L)

        def gemm_flops(t, i, j):
            return float(step_flops[i, j, t])
    elif plan.local_impl == "ranksparse":
        step_flops = _rank_step_flops(plan)  # (p_row, p_col, L)

        def gemm_flops(t, i, j):
            return float(step_flops[i, j, t])
    elif plan.local_impl == "masked" and plan.device_live is not None:
        # Output-structure-aware pruning (repro.spgemm): a gemm whose C
        # tile is dead for this panel — no surviving (a, b, c) block
        # triple on the device — is never emitted.
        dense_panel = 2.0 * m_loc * kb * n_loc

        def gemm_flops(t, i, j):
            return dense_panel if plan.device_live[i, j, steps[t]] else 0.0
    else:
        # dense: every device executes every panel
        dense_panel = 2.0 * m_loc * kb * n_loc

        def gemm_flops(t, i, j):
            return dense_panel

    # B-panel bytes from *surviving* blocks (mirroring the A side): a
    # mostly-dead panel column broadcasts only its live blocks.
    b_live = None
    if p_row > 1 and getattr(plan, "b_mask", None) is not None:
        from repro.core.plan import b_panel_live_elems

        bn_sz = plan.n_pad // plan.b_mask.shape[1]
        b_live = b_panel_live_elems(
            plan.b_mask, getattr(plan, "b_ranks", None),
            bk_sz=kb, bn_sz=bn_sz, p_col=p_col,
        )

    if getattr(plan, "comm_mode", "broadcast") == "pull":
        if plan.local_impl == "masked":
            if plan.device_live is None:
                raise ValueError("pull graphs need per-device liveness")
        elif plan.local_impl != "ranksparse":
            raise ValueError("pull graphs need a masked or rank-sparse plan")
        if plan.local_impl == "ranksparse":
            # A fetches move factor panels while they beat the dense
            # panel: m_loc·r_k U rows plus mb_loc·r_k·kb V rows, the same
            # per-panel crossover ``core.plan._pull_comm_bytes`` charges
            # and ``summa._exec_ranksparse_pull`` slices.
            from repro.core.sparsity import rank_panel_factored_comm

            mb_loc_r = plan.a_ranks.shape[0] // p_row
            bm_sz_r = plan.m_pad // plan.a_ranks.shape[0]
            r_live = plan.a_ranks.max(axis=0)

            def a_fetch_bytes(t, i):
                r_k = max(int(r_live[steps[t]]), 1)
                elems = (
                    m_loc * r_k + mb_loc_r * r_k * kb
                    if rank_panel_factored_comm(r_k, bm_sz_r, kb)
                    else m_loc * kb
                )
                return float(elems) * itemsize
        else:

            def a_fetch_bytes(t, i):
                return float(m_loc * kb * itemsize)

        t_b = max(plan.k_steps // p_row, 1)
        meta["b_owner"] = [int(kk // t_b) for kk in steps]
        _emit_pull_pipeline(
            b,
            n_steps=n_steps,
            lookahead=window,
            owner_col=lambda t: int(steps[t] // t_a),
            owner_row=lambda t: int(steps[t] // t_b),
            a_fetch_bytes=a_fetch_bytes,
            b_fetch_bytes=lambda t, j: (
                float(b_live[steps[t], j]) * itemsize
                if b_live is not None
                else float(kb * n_loc * itemsize)
            ),
            gemm_flops=gemm_flops,
            accum_flops=lambda i, j: float(m_loc * n_loc),
        )
        return b.graph(n_steps, window, meta)

    a_panel_bytes = BCAST_FACTOR * m_loc * kb * itemsize if p_col > 1 else 0.0
    b_panel_bytes = BCAST_FACTOR * kb * n_loc * itemsize if p_row > 1 else 0.0
    if plan.local_impl == "ranksparse" and p_col > 1:
        # Factor panels travel instead of dense A panels: a (m_loc, r_k)
        # U panel plus (mb_loc, r_k, bk) V rows, r_k the panel max rank —
        # unless the panel is past the comm crossover r* = bm·bk/(bm+bk),
        # where it is reconstructed owner-side and dense bytes travel.
        # Same per-panel decision as core.plan / the executor.
        from repro.core.sparsity import rank_panel_factored_comm

        mb_loc = plan.a_ranks.shape[0] // p_row
        bm_sz = plan.m_pad // plan.a_ranks.shape[0]
        r_live = plan.a_ranks.max(axis=0)

        def a_bytes(t, i):
            r_k = max(int(r_live[steps[t]]), 1)
            elems = (
                m_loc * r_k + mb_loc * r_k * kb
                if rank_panel_factored_comm(r_k, bm_sz, kb)
                else m_loc * kb
            )
            return BCAST_FACTOR * elems * itemsize
    else:

        def a_bytes(t, i):
            return a_panel_bytes

    if b_live is not None:

        def b_bytes(t, j):
            return BCAST_FACTOR * float(b_live[steps[t], j]) * itemsize
    else:

        def b_bytes(t, j):
            return b_panel_bytes

    _emit_pipeline(
        b,
        n_steps=n_steps,
        lookahead=window,
        a_bytes=a_bytes,
        b_bytes=b_bytes,
        gemm_flops=gemm_flops,
        accum_flops=lambda i, j: float(m_loc * n_loc),
    )
    return b.graph(n_steps, window, meta)


# ---------------------------------------------------------------------------
# builder 2: from nonuniform tilings (the paper's §4 experiment)
# ---------------------------------------------------------------------------


def from_tilings(
    p_row: int,
    p_col: int,
    row_tiling,
    inner_tiling,
    col_tiling,
    *,
    lookahead: int | None = None,
    itemsize: int = 4,
) -> TaskGraph:
    """Fine-grained task DAG for a (possibly nonuniform) blocked matmul.

    One SUMMA iteration per inner (K) logical block; its panel width is
    that block's extent, so per-iteration costs are nonuniform exactly as
    in the paper.  Row / column blocks embed cyclically on the grid
    (``cyclic_owner``), giving each device its own M x N footprint — the
    per-device load imbalance that multiple-issue must absorb.

    ``lookahead=None`` resolves paper Eq. (1).
    """
    from repro.core.summa import resolve_multi_issue

    rows = np.asarray(row_tiling.sizes, dtype=np.int64)
    inner = np.asarray(inner_tiling.sizes, dtype=np.int64)
    cols = np.asarray(col_tiling.sizes, dtype=np.int64)
    n_steps = len(inner)
    # cyclic embedding: block b of the row blocking lives on grid row b%p
    rows_per = np.zeros(p_row, dtype=np.int64)
    np.add.at(rows_per, np.arange(len(rows)) % p_row, rows)
    cols_per = np.zeros(p_col, dtype=np.int64)
    np.add.at(cols_per, np.arange(len(cols)) % p_col, cols)
    window = resolve_multi_issue(p_row, p_col, n_steps, lookahead)

    b = _Builder(p_row, p_col)
    _emit_pipeline(
        b,
        n_steps=n_steps,
        lookahead=window,
        a_bytes=lambda t, i: (
            BCAST_FACTOR * float(rows_per[i] * inner[t]) * itemsize
            if p_col > 1 else 0.0
        ),
        b_bytes=lambda t, j: (
            BCAST_FACTOR * float(inner[t] * cols_per[j]) * itemsize
            if p_row > 1 else 0.0
        ),
        gemm_flops=lambda t, i, j: 2.0 * float(
            rows_per[i] * inner[t] * cols_per[j]
        ),
        accum_flops=lambda i, j: float(rows_per[i] * cols_per[j]),
    )
    imbalance = float(
        (rows_per.max() * cols_per.max()) / max(rows_per.min() * cols_per.min(), 1)
    )
    return b.graph(
        n_steps,
        window,
        {
            "source": "tilings",
            "strategy": "taskbased" if window > 1 else "procedural",
            "shape": [int(rows.sum()), int(inner.sum()), int(cols.sum())],
            "grid": [p_row, p_col],
            "lookahead": window,
            # cyclic embedding: inner block t's A panel lives on column t%p
            "a_owner": [t % p_col for t in range(n_steps)],
            "static_imbalance": imbalance,
            "uniform": bool(
                row_tiling.is_uniform
                and inner_tiling.is_uniform
                and col_tiling.is_uniform
            ),
        },
    )


# ---------------------------------------------------------------------------
# builder 3: the union graph of chained multiplications
# ---------------------------------------------------------------------------


def chain_graphs(graphs: list[TaskGraph]) -> TaskGraph:
    """Union task DAG of consecutive multiplications ``C_i = C_{i-1} @ B_i``.

    The paper's observation that "no explicit internodal synchronization
    lets multiple MMs overlap" realised as edges: instead of a global
    barrier between steps, the C tile each A-panel broadcast of step
    ``i+1`` *reads* gates only that broadcast — the dependency is the
    final ``accum`` of the owning device (grid row of the broadcast
    group x the panel's owner column, ``meta["a_owner"]``).  B-side
    broadcasts of step ``i+1`` touch fresh operands and carry no
    cross-step edges at all, so they (and early A panels) overlap the
    tail of step ``i``.

    On a single-column grid A panels need no broadcast (the local C rows
    *are* the next operand): the first ``gemm`` per device takes the
    cross edge instead.  ``gather_a`` tasks (allgather strategy) read the
    whole row of C shards and depend on every accum in their group.

    The simulated makespan of the union graph is never worse than the
    sum of the per-step makespans: resource-free times and cross-step
    dependency finishes after step ``i`` are bounded by step ``i``'s
    barrier-synchronized finish, inductively.
    """
    if not graphs:
        raise ValueError("chain_graphs needs at least one graph")
    p_row, p_col = graphs[0].p_row, graphs[0].p_col
    for g in graphs[1:]:
        if (g.p_row, g.p_col) != (p_row, p_col):
            raise ValueError(
                "all chained graphs must share one device grid; got "
                f"{(p_row, p_col)} and {(g.p_row, g.p_col)}"
            )
    b = _Builder(p_row, p_col)
    last_accum: dict[int, int] = {}  # device -> last accum tid so far
    for s, g in enumerate(graphs):
        offset = len(b.tasks)
        a_owner = g.meta.get("a_owner")
        cur_accum: dict[int, int] = {}
        linked_gemm: set[int] = set()
        for task, deps in zip(g.tasks, g.deps):
            new_deps = [d + offset for d in deps]
            if s > 0:
                if task.kind in ("bcast_a", "fetch_a"):
                    # fetch_a: the receiver is devices[0]; its pulled A
                    # panel reads the prior step's C exactly like a
                    # broadcast root would.
                    if a_owner is None:
                        raise ValueError(
                            "chained graph lacks meta['a_owner'] for its "
                            "A-panel broadcasts"
                        )
                    row = task.devices[0] // p_col
                    owner_dev = row * p_col + int(a_owner[task.step])
                    if owner_dev in last_accum:
                        new_deps.append(last_accum[owner_dev])
                elif task.kind == "gather_a":
                    new_deps.extend(
                        last_accum[d] for d in task.devices
                        if d in last_accum
                    )
                elif task.kind == "gemm" and p_col == 1:
                    d = task.devices[0]
                    if d not in linked_gemm and d in last_accum:
                        new_deps.append(last_accum[d])
                        linked_gemm.add(d)
            tid = b.add(
                task.kind, task.step, task.devices, task.resource,
                deps=new_deps, flops=task.flops, bytes=task.bytes,
            )
            if task.kind == "accum":
                for d in task.devices:
                    cur_accum[d] = tid
        last_accum = {**last_accum, **cur_accum}
    graph = b.graph(
        sum(g.n_steps for g in graphs),
        max(g.lookahead for g in graphs),
        {
            "source": "chain",
            "strategy": "taskbased",
            "grid": [p_row, p_col],
            "n_chain_steps": len(graphs),
            "lookahead": [int(g.lookahead) for g in graphs],
            "per_step": [dict(g.meta) for g in graphs],
            "shape": [list(g.meta.get("shape", [])) for g in graphs],
        },
    )
    graph.validate()
    return graph


def eq1_lookahead(p_row: int, p_col: int, k_steps: int) -> int:
    """Paper Eq. (1) clamped to the schedule length (convenience)."""
    from repro.core.summa import resolve_multi_issue

    return resolve_multi_issue(p_row, p_col, k_steps)
