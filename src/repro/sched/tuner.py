"""Schedule autotuner: search lookahead x k_blocks x strategy by simulation.

``core.plan.PlanCost`` ranks strategies by modeled *bytes* — a static
tie-break that knows nothing about overlap, pipelining, or imbalance.
The tuner replaces it: every candidate schedule is materialized as an
explicit task DAG (``taskgraph``) and run through the discrete-event
simulator; the winner is the schedule with the smallest simulated
makespan.  Because the static cost-model choice is always one of the
candidates, the tuned schedule is **never worse** (in simulated
makespan) than the static pick.

Entry points:

* :func:`tune_plan` — returns a new ``MatmulPlan`` whose config carries
  the winning strategy / ``k_blocks`` and whose ``lookahead`` field holds
  the winning window (``core.summa._exec_taskbased`` honors it).  The
  search record is attached as ``plan.tuned``.
* :func:`ring_makespan` — closed-form pipeline estimate for the ring
  collective matmul (``dist.collective_matmul.allgather_matmul``), so
  ``project(strategy="auto")`` can route between the ring and the tuned
  SUMMA schedule on simulated time instead of bytes.
"""
from __future__ import annotations

import dataclasses
import math

from repro.sched.simulator import (
    DEFAULT_MACHINE,
    MachineModel,
    simulate,
)
from repro.sched.taskgraph import eq1_lookahead, from_plan

__all__ = [
    "tune_plan",
    "tune_chain",
    "ring_makespan",
    "lookahead_candidates",
]

#: strategies the tuner may select for plan execution
TUNABLE_STRATEGIES = ("procedural", "taskbased", "allgather")


def lookahead_candidates(p_row: int, p_col: int, k_steps: int) -> list[int]:
    """Candidate multiple-issue windows: serial, minimal overlap, Eq. (1)
    and its half, and the fully-unrolled I = K endpoint."""
    eq1 = eq1_lookahead(p_row, p_col, k_steps)
    cap = max(k_steps, 1)
    cands = {1, 2, max(1, eq1 // 2), eq1, cap}
    return sorted(c for c in cands if 1 <= c <= cap)


def _k_block_candidates(cfg, k_steps: int) -> list[int | None]:
    """``k_blocks`` (over-decomposition) candidates: the plan's own value
    plus the classic grid counts and 2x / 4x over-decompositions."""
    lcm = math.lcm(cfg.p_row, cfg.p_col)
    cands: list[int | None] = [cfg.k_blocks]
    for kb in (max(cfg.p_row, cfg.p_col), lcm, 2 * lcm, 4 * lcm):
        if kb not in cands:
            cands.append(kb)
    return cands


def _sim_summary(sim) -> dict:
    return {
        "makespan_s": sim.makespan_s,
        "imbalance_ratio": sim.imbalance_ratio,
        "efficiency": sim.efficiency,
    }


def tune_plan(
    plan,
    *,
    machine: MachineModel = DEFAULT_MACHINE,
    strategies: tuple[str, ...] = TUNABLE_STRATEGIES,
):
    """Return a tuned copy of ``plan`` (same logical product, best
    simulated schedule).

    Dense plans search strategy x k_blocks x lookahead (re-planning per
    ``k_blocks`` so padding effects are priced in).  Masked plans always
    execute the planned broadcast schedule, so only the window is tuned.
    The returned plan's ``tuned`` dict records the winner and the static
    cost-model baseline; callers must re-pad operands to the tuned plan's
    ``padded_shapes`` (``core.api.DistributedMatmul`` does).
    """
    from repro.core.plan import plan_matmul

    base_cfg = plan.cfg
    if plan.local_impl == "dense":
        static_strategy = plan.cost.best_strategy(("taskbased", "allgather"))
    else:
        # masked plans always execute the planned broadcast schedule; the
        # static baseline is that schedule at the Eq.-(1) window.
        static_strategy = "taskbased"
    static_sim = simulate(from_plan(plan, strategy=static_strategy), machine)

    best = None  # (makespan, order, plan_variant, lookahead, sim)
    n_cands = 0

    def consider(cand_plan, strategy, lookahead):
        nonlocal best, n_cands
        graph = from_plan(cand_plan, strategy=strategy, lookahead=lookahead)
        sim = simulate(graph, machine)
        n_cands += 1
        key = (sim.makespan_s, n_cands)
        if best is None or key < (best[0], best[1]):
            best = (sim.makespan_s, n_cands, cand_plan, strategy,
                    graph.lookahead, sim)

    if plan.local_impl != "dense":
        # Masked (dense-stored) plans may also flip the comm mode: the
        # one-sided pull schedule wins when fill is low enough that
        # per-gemm fetches beat panel broadcasts (repro.spgemm), and the
        # fetch graph's owner-clock contention is exactly what the
        # simulator prices.  Rank-sparse plans pull factor panels
        # (``summa._exec_ranksparse_pull``); bsmm plans keep their
        # broadcast pipeline (their executor is broadcast-only).
        # Masked plans additionally search the stationarity axis: the
        # A-/B-stationary schedules execute the same product through
        # summa's transposed executors, so the tuner may pick them on
        # *simulated* makespan rather than the chooser's modeled bytes.
        base_st = getattr(plan, "stationarity", "C")
        stats = [base_st]
        if plan.local_impl == "masked" and base_st == "C":
            stats = ["C", "A", "B"]
        for st in stats:
            st_plan = (
                plan if st == base_st
                else dataclasses.replace(plan, stationarity=st)
            )
            if st != "C":
                # stationary schedules have no K pipeline — one candidate,
                # no multiple-issue window to sweep
                consider(st_plan, "taskbased", 1)
                continue
            modes = ["broadcast"]
            if (
                plan.local_impl == "masked" and plan.a_ranks is None
            ) or plan.local_impl == "ranksparse":
                modes = ["broadcast", "pull"]
            for mode in modes:
                if mode == getattr(st_plan, "comm_mode", "broadcast"):
                    cand = st_plan
                else:
                    cand = dataclasses.replace(st_plan, comm_mode=mode)
                for la in lookahead_candidates(plan.p_row, plan.p_col,
                                               len(plan.live_panels)):
                    consider(cand, "taskbased", la)
    else:
        for kb in _k_block_candidates(base_cfg, plan.k_steps):
            if kb == base_cfg.k_blocks:
                variant = plan
            else:
                try:
                    variant = plan_matmul(
                        plan.m, plan.k, plan.n,
                        dataclasses.replace(base_cfg, k_blocks=kb),
                        itemsize=plan.itemsize,
                    )
                except ValueError:
                    continue  # k_blocks incompatible with this K / grid
            las = lookahead_candidates(
                variant.p_row, variant.p_col, variant.k_steps
            )
            for strategy in strategies:
                if strategy == "procedural":
                    consider(variant, strategy, 1)
                elif strategy == "allgather":
                    consider(variant, strategy, None)
                else:
                    for la in las:
                        consider(variant, strategy, la)

    _, _, win_plan, win_strategy, win_la, win_sim = best
    tuned_cfg = dataclasses.replace(win_plan.cfg, strategy=win_strategy)
    info = {
        "strategy": win_strategy,
        "k_blocks": win_plan.k_steps,
        "lookahead": int(win_la),
        "stationarity": getattr(win_plan, "stationarity", "C"),
        "comm_mode": getattr(win_plan, "comm_mode", "broadcast"),
        **_sim_summary(win_sim),
        "static_strategy": static_strategy,
        "static_makespan_s": static_sim.makespan_s,
        "speedup_vs_static": (
            static_sim.makespan_s / win_sim.makespan_s
            if win_sim.makespan_s > 0 else 1.0
        ),
        "n_candidates": n_cands,
        "machine": machine.name,
    }
    return dataclasses.replace(
        win_plan, cfg=tuned_cfg, lookahead=int(win_la), tuned=info
    )


def tune_chain(
    builders,
    *,
    machine: MachineModel = DEFAULT_MACHINE,
    max_evals: int = 256,
    default_graphs=None,
):
    """Pick the per-step multiple-issue windows of a chained
    multiplication *jointly* by simulated makespan of the union graph.

    ``builders`` is one callable per chain step, ``lookahead ->
    TaskGraph`` (``None`` = the step's Eq.-(1) default); the union is
    assembled by ``taskgraph.chain_graphs``, so cross-step overlap is
    part of what the search sees — a window that is optimal for a step
    in isolation can lose to one that drains its tail earlier and
    unblocks the next step's A-panel broadcasts.

    The full candidate product is searched when it fits in
    ``max_evals`` simulations; beyond that each step keeps its
    isolated-best window (greedy fallback).  The default (Eq.-1) windows
    are always a candidate, so the tuned chain is never worse than the
    untuned one in simulated makespan.

    ``default_graphs`` accepts the per-step default (Eq.-1) graphs if the
    caller already built them, avoiding a duplicate materialization.
    Returns ``(lookaheads, sim, record)``.
    """
    import itertools

    from repro.sched.taskgraph import chain_graphs

    defaults = (
        default_graphs if default_graphs is not None
        else [b(None) for b in builders]
    )
    default_las = [g.lookahead for g in defaults]
    cand_lists = [
        lookahead_candidates(g.p_row, g.p_col, g.n_steps) for g in defaults
    ]
    for las, g in zip(cand_lists, defaults):
        if g.lookahead not in las:
            las.append(g.lookahead)
    total = math.prod(len(c) for c in cand_lists)
    if total <= max_evals:
        combos = itertools.product(*cand_lists)
    else:
        # greedy fallback: each step keeps its isolated-best window, and
        # the all-defaults combo rides along — two chain evaluations
        # regardless of chain length (the per-step probe sims are linear
        # in the number of steps, never a product).
        bests = []
        for b, g in zip(builders, defaults):
            las = lookahead_candidates(g.p_row, g.p_col, g.n_steps)
            bests.append(min(
                las, key=lambda la: simulate(b(la), machine).makespan_s
            ))
        combos = [tuple(default_las), tuple(bests)]
    best = None  # (makespan, order, las, sim)
    n_evals = 0
    default_key = tuple(default_las)
    default_sim = None
    for las in combos:
        graph = chain_graphs([b(la) for b, la in zip(builders, las)])
        sim = simulate(graph, machine)
        n_evals += 1
        if tuple(las) == default_key:
            default_sim = sim  # the default combo is always a candidate
        key = (sim.makespan_s, n_evals)
        if best is None or key < (best[0], best[1]):
            best = (sim.makespan_s, n_evals, las, sim)
    _, _, win_las, win_sim = best
    if default_sim is None:  # defensive: candidates lists were customized
        default_sim = simulate(chain_graphs(defaults), machine)
    record = {
        "lookaheads": [int(la) for la in win_las],
        "default_lookaheads": [int(la) for la in default_las],
        **_sim_summary(win_sim),
        "default_makespan_s": default_sim.makespan_s,
        "speedup_vs_default": (
            default_sim.makespan_s / win_sim.makespan_s
            if win_sim.makespan_s > 0 else 1.0
        ),
        "n_candidates": n_evals,
        "machine": machine.name,
    }
    return list(win_las), win_sim, record


def ring_makespan(
    plan,
    machine: MachineModel = DEFAULT_MACHINE,
    *,
    lookahead: int = 2,
) -> float:
    """Pipeline estimate for the ring collective matmul over ``p_col``.

    Each of the ``p`` activation chunks takes one hop per step while the
    chunk in hand multiplies against the local weight columns; with
    ``lookahead`` hops in flight the steady state is bound by the slower
    of the two streams (cf. ``allgather_matmul``'s prefetch pipeline).
    """
    p = plan.p_col
    m_loc = plan.m_pad // plan.p_row
    n_loc = plan.n_pad // plan.p_col
    gemm = machine.compute_time(2.0 * (m_loc / p) * plan.k_pad * n_loc)
    if p <= 1:
        return gemm
    hop = machine.comm_time((m_loc / p) * plan.k_pad * plan.itemsize)
    fill = hop * max(1, min(lookahead, p) - 1)
    return fill + max((p - 1) * hop, (p - 1) * gemm) + gemm
