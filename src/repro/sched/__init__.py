"""repro.sched: explicit task graphs, schedule simulation, and autotuning.

The paper's contribution is a *scheduler* — fine-grained tasks with real
dependency edges, multiple-issue lookahead (Eq. 1), imbalance absorbed
by overlap.  ``core.summa`` executes that schedule; this package reasons
about it:

* ``taskgraph``  — materialize a ``MatmulPlan`` (or nonuniform tilings)
  into broadcast/gemm/accumulate tasks with FLOP/byte costs.
* ``simulator``  — discrete-event simulation: per-device clocks, comm
  model shared with ``plan.PlanCost``, makespan / busy / imbalance /
  Chrome-trace outputs; scales to thousands of virtual devices.
* ``tuner``      — search lookahead x k_blocks x strategy over the
  simulator; feeds the winner back into ``plan_matmul`` /
  ``matmul_strategy="auto"`` / ``serve.warm_matmul_plans``.

CLI: ``python -m repro.sched --grid 4 4 --extent 2048 --nonuniform``.
"""
from repro.sched.simulator import (
    DEFAULT_MACHINE,
    MachineModel,
    SimResult,
    simulate,
    simulate_plan,
)
from repro.sched.taskgraph import (
    Task,
    TaskGraph,
    abstract_summa_config,
    chain_graphs,
    eq1_lookahead,
    from_plan,
    from_tilings,
)
from repro.sched.tuner import (
    lookahead_candidates,
    ring_makespan,
    tune_chain,
    tune_plan,
)

__all__ = [
    "DEFAULT_MACHINE",
    "MachineModel",
    "SimResult",
    "simulate",
    "simulate_plan",
    "Task",
    "TaskGraph",
    "abstract_summa_config",
    "chain_graphs",
    "eq1_lookahead",
    "from_plan",
    "from_tilings",
    "lookahead_candidates",
    "ring_makespan",
    "tune_chain",
    "tune_plan",
]
