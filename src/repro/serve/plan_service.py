"""Persistent plan service: tuned-schedule winners as a first-class cache.

``warm_matmul_plans`` moves the simulator search (lookahead x k_blocks x
strategy x stationarity x comm_mode, repro.sched.tuner) out of the
serving traces — but until now every *process* re-ran it.  DBCSR ships
its per-shape tuning results as a persistent library, and PR 9's
``kernels.autotune.KernelAutotuner`` already proved the pattern for
kernel winners; this module gives the schedule layer the same treatment:

* winners are keyed by **(shape, structure digest, mesh fingerprint)** —
  ``m x k x n x itemsize``, the sha1 of the weight block mask (or
  ``"dense"``), and the mesh's axis names x sizes — so a cache tuned on
  one mesh never steers another;
* :meth:`PlanService.plan_projection` is the consult point used by
  ``serve.engine.warm_matmul_plans``: a hit re-applies the stored
  (strategy, k_blocks, lookahead, stationarity, comm_mode) through
  ``ParallelCtx.plan_projection``'s explicit pins — **zero tuner runs**
  — while a miss tunes once and records;
* the observed traffic distribution (``(batch, prompt_len)`` counts) is
  recorded alongside, so a fresh process can :meth:`prewarm` the plan
  *and executable* caches for the shapes production traffic actually
  hits before the first request lands;
* JSON persistence mirrors ``KernelAutotuner.save/load`` exactly —
  stable fingerprint, process singleton seeded from the
  ``REPRO_PLAN_CACHE`` env var, ``REPRO_PLAN_SERVICE=0`` kill switch.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import numpy as np

__all__ = [
    "PlanService",
    "plan_service",
    "set_plan_service",
    "mesh_fingerprint",
    "structure_digest",
    "plan_service_enabled",
]

#: the tuned fields a winner record persists and re-applies.
WINNER_FIELDS = ("strategy", "k_blocks", "lookahead", "stationarity",
                 "comm_mode")


def plan_service_enabled() -> bool:
    """``REPRO_PLAN_SERVICE=0`` disables consults (tune-every-time)."""
    return os.environ.get("REPRO_PLAN_SERVICE", "1") != "0"


def mesh_fingerprint(ctx) -> str:
    """Stable id of the mesh geometry a plan was tuned on: axis names x
    sizes plus the (dp, tp) role assignment."""
    if not ctx.has_mesh:
        return "nomesh"
    axes = ",".join(f"{a}={ctx.mesh.shape[a]}" for a in ctx.mesh.axis_names)
    return f"{axes};dp={'+'.join(ctx.dp_axes)};tp={ctx.tp_axis}"


def structure_digest(mask) -> str:
    """sha1 of the weight block mask bytes; ``"dense"`` for mask-free."""
    if mask is None:
        return "dense"
    m = np.asarray(mask)
    h = hashlib.sha1(str(m.shape).encode())
    h.update(np.ascontiguousarray(m).tobytes())
    return h.hexdigest()[:16]


def _key_str(m: int, k: int, n: int, itemsize: int, structure: str,
             mesh_fp: str) -> str:
    return f"{m}x{k}x{n}xi{itemsize}|{structure}|{mesh_fp}"


def _winner_from_plan(plan) -> dict:
    """Extract the persisted fields from a (tuned or static) plan."""
    tuned = plan.tuned or {}
    return {
        "strategy": tuned.get("strategy", plan.cfg.strategy),
        "k_blocks": int(tuned.get("k_blocks", plan.k_steps)),
        "lookahead": int(tuned.get("lookahead", plan.resolve_lookahead())),
        "stationarity": tuned.get(
            "stationarity", getattr(plan, "stationarity", "C")
        ),
        "comm_mode": tuned.get(
            "comm_mode", getattr(plan, "comm_mode", "broadcast")
        ),
    }


@dataclasses.dataclass
class PlanService:
    """Persistent (shape, structure, mesh) -> tuned-schedule winners plus
    the recorded traffic distribution.  See the module docstring."""

    table: dict = dataclasses.field(default_factory=dict)
    traffic: dict = dataclasses.field(default_factory=dict)
    stats: dict = dataclasses.field(
        default_factory=lambda: {"tunes": 0, "hits": 0, "untuned": 0}
    )

    # -- consult -------------------------------------------------------------

    def lookup(self, m: int, k: int, n: int, *, itemsize: int,
               structure: str, mesh_fp: str) -> dict | None:
        """The stored winner, or ``None`` (miss / disabled).  Never tunes."""
        if not plan_service_enabled():
            return None
        return self.table.get(_key_str(m, k, n, itemsize, structure, mesh_fp))

    def record(self, m: int, k: int, n: int, *, itemsize: int,
               structure: str, mesh_fp: str, winner: dict) -> None:
        key = _key_str(m, k, n, itemsize, structure, mesh_fp)
        self.table[key] = {f: winner[f] for f in WINNER_FIELDS}

    def plan_projection(self, ctx, m: int, k: int, n: int, *, itemsize: int,
                        tune: bool, stationarity: str = "C"):
        """``ctx.plan_projection`` with the service in the loop.

        Hit: re-apply the stored winner through the explicit schedule
        pins (no tuner).  Miss with ``tune=True``: run the tuner once and
        record the winner.  Miss without ``tune``: plan statically (there
        is no search to persist).  Returns the plan (``None`` on the
        xla / pure-DP path, like ``ctx.plan_projection``).
        """
        if (
            not ctx.has_mesh
            or ctx.matmul_strategy == "xla"
            or ctx.pure_dp
        ):
            return None
        structure = structure_digest(ctx.weight_mask((k, n)))
        mesh_fp = mesh_fingerprint(ctx)
        win = self.lookup(m, k, n, itemsize=itemsize, structure=structure,
                          mesh_fp=mesh_fp)
        if win is not None:
            self.stats["hits"] += 1
            return ctx.plan_projection(
                m, k, n, itemsize=itemsize, tune=False,
                strategy=win["strategy"], lookahead=win["lookahead"],
                stationarity=win["stationarity"],
                comm_mode=win["comm_mode"], k_blocks=win["k_blocks"],
            )
        plan = ctx.plan_projection(
            m, k, n, itemsize=itemsize, tune=tune, stationarity=stationarity
        )
        if plan is None:
            return None
        if tune:
            self.stats["tunes"] += 1
            if plan_service_enabled():
                self.record(
                    m, k, n, itemsize=itemsize, structure=structure,
                    mesh_fp=mesh_fp, winner=_winner_from_plan(plan),
                )
        else:
            self.stats["untuned"] += 1
        return plan

    # -- traffic-keyed pre-warming -------------------------------------------

    def record_traffic(self, batch: int, prompt_len: int) -> None:
        """Count one occurrence of a serving shape (the warm list)."""
        key = f"{batch}x{prompt_len}"
        self.traffic[key] = self.traffic.get(key, 0) + 1

    def top_traffic(self, top: int | None = None) -> list[tuple[int, int]]:
        """Most frequent ``(batch, prompt_len)`` shapes, by count."""
        items = sorted(self.traffic.items(), key=lambda kv: (-kv[1], kv[0]))
        if top is not None:
            items = items[:top]
        return [tuple(int(x) for x in k.split("x")) for k, _ in items]

    def prewarm(self, cfg, ctx, *, top: int | None = 4,
                warm_executables: bool = True) -> int:
        """Warm plans (+ executables) for the recorded traffic shapes —
        call at process start so the first request of every common shape
        dispatches a pre-compiled program.  Returns shapes warmed."""
        from repro.serve import engine

        shapes = self.top_traffic(top)
        for batch, prompt_len in shapes:
            engine.warm_matmul_plans(
                cfg, ctx, batch, prompt_len,
                warm_executables=warm_executables, service=self,
            )
        return len(shapes)

    # -- persistence (mirrors KernelAutotuner.save/load) ---------------------

    def fingerprint(self) -> str:
        """Content digest of the winner table; ``""`` when empty/disabled."""
        if not plan_service_enabled() or not self.table:
            return ""
        h = hashlib.sha1()
        for k in sorted(self.table):
            h.update(k.encode())
            e = self.table[k]
            for f in WINNER_FIELDS:
                h.update(str(e.get(f)).encode())
        return h.hexdigest()[:16]

    def save(self, path: str) -> None:
        data = {
            "version": 1,
            "entries": self.table,
            "traffic": self.traffic,
        }
        with open(path, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)

    def load(self, path: str, *, merge: bool = True) -> int:
        """Install entries from ``path``; returns how many winners loaded.

        ``merge=True`` (default): the file is the persisted truth on key
        collisions, exactly like ``KernelAutotuner.load``."""
        with open(path) as f:
            data = json.load(f)
        if not merge:
            self.table.clear()
            self.traffic.clear()
        self.table.update(data.get("entries", {}))
        for k, v in data.get("traffic", {}).items():
            self.traffic[k] = self.traffic.get(k, 0) + int(v)
        return len(data.get("entries", {}))


_SERVICE: PlanService | None = None


def plan_service() -> PlanService:
    """The process singleton; seeded from ``REPRO_PLAN_CACHE`` if the env
    var names an existing JSON file (the fresh-process warm restore)."""
    global _SERVICE
    if _SERVICE is None:
        _SERVICE = PlanService()
        path = os.environ.get("REPRO_PLAN_CACHE", "")
        if path and os.path.exists(path):
            _SERVICE.load(path)
    return _SERVICE


def set_plan_service(service: PlanService | None) -> None:
    """Swap the process singleton (tests; ``None`` resets to empty-lazy)."""
    global _SERVICE
    _SERVICE = service
