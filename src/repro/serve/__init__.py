"""Serving: KV/recurrent caches, prefill, decode, and the front-end.

* ``engine``       — batched prefill + single-token decode over ring
  caches (per-slot position vectors, seq-sharded + int8 KV paths).
* ``scheduler``    — continuous-batching request loop (admit/evict per
  decode step; the sched simulator's machine model as admission control).
* ``pages``        — paged KV cache: pools + page table, block-managed
  cache liveness.
* ``plan_service`` — persistent (shape, structure, mesh) -> tuned
  schedule winners + traffic-keyed warm lists.
"""
