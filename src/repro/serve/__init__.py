"""Serving: KV/recurrent caches, prefill, decode."""
