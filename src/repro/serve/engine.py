"""Serving engine: batched prefill + single-token decode with caches.

Cache kinds per block:

* ``attn``  — KV cache (B, Hkv, S_cache, Dh); rolling ring buffer of size
  ``window`` for sliding/local-attention archs, so the ``long_500k`` cell
  holds only O(window) state.  Decode attention shards the cache's S
  dimension over the TP axis and combines partial softmaxes with the
  log-sum-exp trick (flash-decoding on the mesh).
* ``rglru`` / ``mlstm`` / ``slstm`` — O(1) recurrent state; prefill
  derives the closed-form final state (no sequential pass where the math
  allows it).

Layout mirrors the model: stacked caches per scan unit + unrolled tail.
``pos`` is a per-slot ``(B,)`` vector counting tokens written so far in
each batch row — rows decode at independent positions, which is what the
continuous-batching scheduler (``serve.scheduler``) relies on to admit
and evict requests per step without reshaping live state.  A scalar
``pos`` (legacy fixed-shape caches) is still accepted and broadcast.

Capacity contract (non-windowed archs): decoding a token at position
``>= S_cache`` never corrupts the cache — the ring write is dropped — but
the returned logits for that row attend only to the first ``S_cache``
tokens, so they are not the true model output.  Drivers must not decode
past capacity: the serving loops raise :class:`CacheCapacityError`
instead (windowed archs wrap by design and have no capacity limit).
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.dist.context import ParallelCtx
from repro.models import layers as L
from repro.models.attention import _project_qkv, attention
from repro.models.config import ModelConfig
from repro.models.ffn import ffn
from repro.models.model import embed_inputs
from repro.models.moe import moe_ffn
from repro.models.recurrent import (
    mlstm_block,
    mlstm_step,
    rglru_block,
    rglru_step,
    slstm_block,
    slstm_step,
)

__all__ = [
    "CacheCapacityError",
    "init_cache",
    "cache_shardings",
    "prefill",
    "decode_step",
    "cache_len",
    "warm_matmul_plans",
    "warm_kernel_cache",
]


class CacheCapacityError(RuntimeError):
    """Decoding would write past the KV cache capacity of a non-windowed
    arch.  Raised by the serving drivers (``launch.serve``,
    ``serve.scheduler``) *before* the overflowing decode step — the
    engine itself drops out-of-capacity writes (never corrupts state) but
    cannot produce correct logits for tokens beyond ``S_cache``."""


def warm_matmul_plans(cfg: ModelConfig, ctx: ParallelCtx, batch: int,
                      prompt_len: int, *, warm_executables: bool = True,
                      service=None):
    """Pre-derive the SUMMA ``MatmulPlan``s for every projection shape the
    serving traces will request — prefill flattens (B, S, D) activations
    to M = B*S rows, decode to M = B — so the jitted prefill/decode paths
    hit ``DistributedMatmul``'s plan cache instead of re-deriving the
    schedule (numpy panel liveness, CSR maps, cost model) inside tracing.
    With ``matmul_strategy="auto"`` each plan is additionally *tuned*
    (repro.sched.tuner): the simulator search over lookahead x k_blocks x
    strategy runs here, once per shape, instead of inside the trace.
    With ``warm_executables`` (default) each warmed plan is also driven
    through ``core.summa``'s plan-digest-keyed executable cache at the
    serving dtype, so the first production matmul per shape dispatches a
    pre-compiled program instead of paying the trace+compile there.

    Tuned winners go through the **persistent plan service**
    (``serve.plan_service``; pass ``service=`` to override the process
    singleton): shapes whose (shape, structure digest, mesh fingerprint)
    key is already recorded re-apply the stored (strategy, k_blocks,
    lookahead, stationarity, comm_mode) without re-running the simulator
    search — the schedule analogue of ``KernelAutotuner``'s warm restore
    (seed it across processes via ``REPRO_PLAN_CACHE``).  The traffic
    shape ``(batch, prompt_len)`` is recorded so the service can pre-warm
    future processes from the observed distribution.
    Returns the warmed plans; no-op (empty) on the plain-einsum path.
    """
    from repro.core import summa as sm
    from repro.serve.plan_service import plan_service

    if not ctx.has_mesh or ctx.matmul_strategy == "xla" or ctx.pure_dp:
        return []
    svc = plan_service() if service is None else service
    svc.record_traffic(batch, prompt_len)
    d = cfg.d_model
    ffs = [cfg.d_ff] if cfg.d_ff else []
    if cfg.moe is not None and cfg.moe.num_shared_experts:
        ffs.append(cfg.moe.d_ff * cfg.moe.num_shared_experts)
    itemsize = jnp.dtype(cfg.dtype).itemsize
    tune = ctx.matmul_strategy == "auto"
    # "auto" also lets the comm-volume model pick the stationarity: tall
    # prefill activations keep C-stationary, skinny decode shapes can win
    # with the weight-stationary variants (repro.spgemm chooser).
    stationarity = "auto" if tune else "C"
    plans = []
    for m in (batch * prompt_len, batch):
        for f in ffs:
            for k_in, n_out in ((d, f), (f, d)):
                plans.append(
                    svc.plan_projection(
                        ctx, m, k_in, n_out, itemsize=itemsize, tune=tune,
                        stationarity=stationarity,
                    )
                )
    plans = [p for p in plans if p is not None]
    if warm_executables:
        for p in {id(p): p for p in plans}.values():
            sm.warm_plan_executable(p, jnp.dtype(cfg.dtype))
    return plans


def warm_kernel_cache(cfg: ModelConfig, ctx: ParallelCtx, batch: int,
                      prompt_len: int, *, path: str | None = None,
                      routes: tuple[str, ...] | None = None,
                      repeats: int = 3):
    """Tune the kernel-autotune buckets for every *local* gemm shape the
    serving projections produce, and persist the winners.

    The per-plan local panel product is ``(m_loc, kb_width) @ (kb_width,
    n_loc)`` — that shape (bucketed) is what ``summa._local_dot`` will
    look up at trace time, so tuning here moves the benchmarking out of
    the serving path exactly like :func:`warm_matmul_plans` moves the
    simulator search out of it.  ``path`` writes the JSON cache file
    (restore it in a later process via the ``REPRO_AUTOTUNE_CACHE`` env
    var or ``KernelAutotuner.load``); ``routes`` restricts the benchmark
    sweep (interpret-mode structured kernels are slow off-TPU).  Warm the
    kernel cache **before** :func:`warm_matmul_plans`: executable cache
    keys carry the autotune fingerprint, so executables warmed against a
    cold kernel cache are re-traced once it fills.  Returns the tuned
    bucket keys.
    """
    from repro.kernels.autotune import autotune_cache, bucket_key

    plans = warm_matmul_plans(cfg, ctx, batch, prompt_len,
                              warm_executables=False)
    cache = autotune_cache()
    tuned = []
    for p in plans:
        m_loc = p.m_pad // p.p_row
        n_loc = p.n_pad // p.p_col
        key = bucket_key(m_loc, p.kb_width, n_loc, dtype=cfg.dtype)
        if key in tuned:
            continue
        cache.tune(m_loc, p.kb_width, n_loc, dtype=cfg.dtype,
                   repeats=repeats, routes=routes)
        tuned.append(key)
    if path is not None:
        cache.save(path)
    return tuned


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.window is not None:
        return min(cfg.window, max_len)
    return max_len


# ---------------------------------------------------------------------------
# cache init (abstract-friendly: pure shapes)
# ---------------------------------------------------------------------------


def _quantize_kv(x: jax.Array):
    """(.., S, Dh) -> int8 values + per-(token, head) fp32 absmax scales."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _block_cache(
    kind: str, cfg: ModelConfig, batch: int, max_len: int, kv_quant: bool = False
):
    dh = cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.dtype)
    if kind == "attn":
        s_c = cache_len(cfg, max_len)
        shape = (batch, cfg.num_kv_heads, s_c, dh)
        if kv_quant:
            sshape = (batch, cfg.num_kv_heads, s_c, 1)
            return {
                "k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(sshape, jnp.float32),
                "v_s": jnp.zeros(sshape, jnp.float32),
            }
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    d = cfg.d_model
    if kind == "rglru":
        return {
            "h": jnp.zeros((batch, d), jnp.float32),
            "conv": jnp.zeros((batch, 3, d), jnp.float32),
        }
    if kind == "mlstm":
        di = 2 * d
        nh = cfg.num_heads
        dh_i = di // nh
        return {
            "c": jnp.zeros((batch, nh, dh_i, dh_i), jnp.float32),
            "n": jnp.zeros((batch, nh, dh_i), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, 3, di), jnp.float32),
        }
    if kind == "slstm":
        return {
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.ones((batch, d), jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32),
        }
    raise ValueError(kind)


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, *, kv_quant: bool = False
):
    def unit_cache(_):
        return {
            f"b{j}": _block_cache(kind, cfg, batch, max_len, kv_quant)
            for j, kind in enumerate(cfg.block_pattern)
        }

    units = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.units,) + x.shape).copy()
        if cfg.units
        else x[None][:0],
        unit_cache(None),
    )
    tail = [
        _block_cache(kind, cfg, batch, max_len, kv_quant) for kind in cfg.tail
    ]
    return {"units": units, "tail": tail, "pos": jnp.zeros((batch,), jnp.int32)}


#: attn-cache leaf names — KV values plus their int8 quantization scales;
#: everything else in a block cache is recurrent/conv state.
_KV_LEAF_KEYS = frozenset({"k", "v", "k_s", "v_s"})


def _leaf_key(entry) -> str | int | None:
    """Dict key / sequence index of one ``KeyPath`` entry."""
    return getattr(entry, "key", getattr(entry, "idx", None))


def cache_batch_axis(path) -> int:
    """Batch axis of a cache leaf from its tree path: stacked unit caches
    carry a leading scan dimension, tail caches and ``pos`` do not."""
    return 1 if _leaf_key(path[0]) == "units" else 0


def cache_shardings(cache, ctx: ParallelCtx, batch: int):
    """Shardings for a serving cache (the one cache-sharding function —
    ``launch.dryrun`` delegates here).

    * KV values **and their int8 scales** (``k``/``v``/``k_s``/``v_s``,
      ``(units?, B, Hkv, S, Dh|1)``): batch over DP, S over TP — the
      seq-sharded decode-attention layout.
    * recurrent / conv states (``h``/``c``/``n``/``m``/``conv``) and the
      per-slot ``pos`` vector: batch over DP only.  Classification is by
      leaf *name and tree path*, never by shape sniffing — stacked conv
      caches ``(U, B, 3, d)`` and mlstm ``(U, B, nh, dh, dh)`` states must
      never land an axis on TP.
    * batch not divisible by the DP degree: the batch axis is replicated
      (the same explicit fallback ``_decode_attention`` warns about).
    """
    bs = ctx.dp if batch % max(ctx.dp_size, 1) == 0 else None

    def spec(path, leaf):
        base = [None] * leaf.ndim
        if _leaf_key(path[-1]) in _KV_LEAF_KEYS:
            base[-4] = bs  # B
            base[-2] = ctx.tp_axis  # S
            return ctx.named(*base)
        if leaf.ndim > 0:  # recurrent state or pos: batch over DP
            base[cache_batch_axis(path)] = bs
        return ctx.named(*base)

    return jax.tree_util.tree_map_with_path(spec, cache)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def _prefill_block(kind, p, x, positions, cfg, ctx, batch, max_len):
    if kind == "attn":
        o, (k, v) = attention(
            p["attn"], x, positions, cfg, ctx, window=cfg.window,
            use_kernel=False, return_kv=True,
        )
        x = x + o
        if "moe" in p:
            y, _ = moe_ffn(p["moe"], x, cfg, ctx)
            x = x + y
        elif "ffn" in p:
            x = x + ffn(p["ffn"], x, cfg, ctx)
        s = k.shape[2]
        s_c = cache_len(cfg, max_len)
        if s >= s_c:
            # keep the last s_c keys, packed in ring order slot = t % s_c
            t0 = s - s_c
            idx = t0 + jnp.arange(s_c)  # tokens kept: [s-s_c, s)
            ring_slot = idx % s_c
            k_keep = jnp.take(k, idx, axis=2)
            v_keep = jnp.take(v, idx, axis=2)
            k_cache = jnp.zeros_like(k_keep)
            v_cache = jnp.zeros_like(v_keep)
            k_cache = k_cache.at[:, :, ring_slot, :].set(k_keep)
            v_cache = v_cache.at[:, :, ring_slot, :].set(v_keep)
        else:
            pad = s_c - s
            k_cache = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v_cache = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if ctx.kv_quant:
            kq, ks = _quantize_kv(k_cache)
            vq, vs = _quantize_kv(v_cache)
            return x, {"k": kq, "k_s": ks, "v": vq, "v_s": vs}
        return x, {"k": k_cache, "v": v_cache}
    if kind == "rglru":
        o, st = rglru_block(p["rec"], x, cfg, ctx, return_state=True)
        x = x + o
        x = x + ffn(p["ffn"], x, cfg, ctx)
        return x, st
    if kind == "mlstm":
        o, st = mlstm_block(p["rec"], x, cfg, ctx, return_state=True)
        return x + o, st
    if kind == "slstm":
        o, st = slstm_block(p["rec"], x, cfg, ctx, return_state=True)
        return x + o, st
    raise ValueError(kind)


def prefill(params, inputs: dict, cfg: ModelConfig, ctx: ParallelCtx, max_len: int):
    """Returns (last-token logits (B, V), cache)."""
    x = embed_inputs(params, inputs, cfg)
    b, s = x.shape[:2]
    positions = inputs.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def unit_fn(x, unit_params):
        caches = {}
        for j, kind in enumerate(cfg.block_pattern):
            x, c = _prefill_block(
                kind, unit_params[f"b{j}"], x, positions, cfg, ctx, b, max_len
            )
            caches[f"b{j}"] = c
        return x, caches

    if cfg.units > 0:
        x, unit_caches = jax.lax.scan(unit_fn, x, params["units"])
    else:
        unit_caches = {}
    tail_caches = []
    for j, kind in enumerate(cfg.tail):
        x, c = _prefill_block(
            kind, params["tail"][j], x, positions, cfg, ctx, b, max_len
        )
        tail_caches.append(c)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    last = x[:, -1, :]
    if "head" in params:
        logits = L.dense(params["head"], last).astype(jnp.float32)
    else:
        logits = L.unembed(params["embed"], last)
    cache = {
        "units": unit_caches,
        "tail": tail_caches,
        "pos": jnp.full((b,), s, jnp.int32),
    }
    return logits, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def _local_ring_update(buf, new_val, slot, offset):
    """Update per-row positions ``slot`` (global, ``(B,)``) in a seq-shard
    covering [offset, offset + S_loc): only the owning shard writes — no
    cross-shard traffic, no re-gather of the sharded cache.  Out-of-range
    rows (another shard owns the slot, or the slot is past capacity on a
    non-windowed arch) keep their current value — an overflowing write is
    *dropped*, never clamped onto the final slot."""
    b, _, s_loc, _ = buf.shape
    local = slot - offset  # (B,)
    in_range = (local >= 0) & (local < s_loc)
    lslot = jnp.clip(local, 0, s_loc - 1)
    rows = jnp.arange(b)
    cur = buf[rows, :, lslot, :]  # (B, Hkv, Dh)
    upd = jnp.where(
        in_range[:, None, None], new_val[:, :, 0, :].astype(buf.dtype), cur
    )
    return buf.at[rows, :, lslot, :].set(upd)


def _decode_attention(q, k_new, v_new, k_cache, v_cache, slot, n_valid,
                      ctx: ParallelCtx, k_scale=None, v_scale=None):
    """One fused decode-attention step: write the new token's K/V into the
    seq-sharded ring caches (shard-locally) and attend with LSE combine.

    q (B, H, Dh); k_new/v_new (B, Hkv, 1, Dh); caches (B, Hkv, S_c, Dh).
    ``slot`` / ``n_valid`` are per-row ``(B,)`` vectors (scalars are
    broadcast) — rows may sit at independent positions (continuous
    batching).  With ``k_scale``/``v_scale`` the caches are int8 and
    dequantized in-shard (fused into the matmuls on TPU: reads stay
    1 byte/elem).  Returns (attention output, updated caches...).
    """
    b, h, dh = q.shape
    hkv = k_cache.shape[1]
    g = h // hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    slot = jnp.broadcast_to(jnp.asarray(slot, jnp.int32), (b,))
    n_valid = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (b,))
    quant = k_scale is not None
    if quant:
        kq_new, ks_new = _quantize_kv(k_new)
        vq_new, vs_new = _quantize_kv(v_new)

    def partial_attn(q_l, k_l, v_l, nv_l, offset, ks_l=None, vs_l=None):
        s_loc = k_l.shape[2]
        b_l = q_l.shape[0]  # may be the per-shard batch inside shard_map
        qg = (q_l.astype(jnp.float32) * scale).reshape(b_l, hkv, g, dh)
        kf = k_l.astype(jnp.float32)
        vf = v_l.astype(jnp.float32)
        if quant:
            kf = kf * ks_l
            vf = vf * vs_l
        logits = jnp.einsum("bhgd,bhsd->bhgs", qg, kf)
        live = (
            (offset + jnp.arange(s_loc))[None, None, None, :]
            < nv_l[:, None, None, None]
        )
        logits = jnp.where(live, logits, -1e30)
        m = jnp.max(logits, axis=-1)  # (b,hkv,g)
        p = jnp.exp(logits - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhgs,bhsd->bhgd", p, vf)
        return m, l, o

    if ctx.mesh is None or ctx.mesh.empty or ctx.tp_size == 1:
        if quant:
            k_cache = _local_ring_update(k_cache, kq_new, slot, 0)
            v_cache = _local_ring_update(v_cache, vq_new, slot, 0)
            k_scale = _local_ring_update(k_scale, ks_new, slot, 0)
            v_scale = _local_ring_update(v_scale, vs_new, slot, 0)
        else:
            k_cache = _local_ring_update(k_cache, k_new, slot, 0)
            v_cache = _local_ring_update(v_cache, v_new, slot, 0)
        m, l, o = partial_attn(q, k_cache, v_cache, n_valid, 0,
                               k_scale, v_scale)
        out = o / jnp.maximum(l[..., None], 1e-30)
        out = out.reshape(b, h, dh).astype(q.dtype)
        if quant:
            return out, k_cache, v_cache, k_scale, v_scale
        return out, k_cache, v_cache

    def body(q_l, kn_l, vn_l, slot_l, nv_l, k_l, v_l, *scales):
        s_loc = k_l.shape[2]
        offset = jax.lax.axis_index(ctx.tp_axis) * s_loc
        if quant:
            ks_l, vs_l, ksn_l, vsn_l = scales
            k_l = _local_ring_update(k_l, kn_l, slot_l, offset)
            v_l = _local_ring_update(v_l, vn_l, slot_l, offset)
            ks_l = _local_ring_update(ks_l, ksn_l, slot_l, offset)
            vs_l = _local_ring_update(vs_l, vsn_l, slot_l, offset)
        else:
            ks_l = vs_l = None
            k_l = _local_ring_update(k_l, kn_l, slot_l, offset)
            v_l = _local_ring_update(v_l, vn_l, slot_l, offset)
        m, l, o = partial_attn(q_l, k_l, v_l, nv_l, offset, ks_l, vs_l)
        m_g = jax.lax.pmax(m, ctx.tp_axis)
        corr = jnp.exp(m - m_g)
        denom = jax.lax.psum(l * corr, ctx.tp_axis)
        numer = jax.lax.psum(o * corr[..., None], ctx.tp_axis)
        out = numer / jnp.maximum(denom[..., None], 1e-30)
        out = out.reshape(q_l.shape[0], h, dh).astype(q.dtype)
        if quant:
            return out, k_l, v_l, ks_l, vs_l
        return out, k_l, v_l

    if b % max(ctx.dp_size, 1) == 0:
        bspec = ctx.dp
    else:
        # Explicit fallback: a ragged continuous batch that does not
        # divide the DP degree replicates the *whole cache* on every DP
        # rank for this step.  That is correct but costly — warn once per
        # trace so drivers size their slot pools to a DP multiple
        # (serve.scheduler does) or pad the batch.
        warnings.warn(
            f"decode batch {b} is not divisible by dp={ctx.dp_size}: "
            "KV cache DP sharding is dropped (replicated) for this step; "
            "pad the batch or use a slot count divisible by dp",
            RuntimeWarning,
            stacklevel=2,
        )
        bspec = None
    cache_spec = P(bspec, None, ctx.tp_axis, None)
    new_spec = P(bspec, None, None, None)  # new token K/V: replicated on S
    row_spec = P(bspec)  # per-row slot / n_valid vectors
    in_specs = [P(bspec, None, None), new_spec, new_spec, row_spec, row_spec,
                cache_spec, cache_spec]
    out_specs = [P(bspec, None, None), cache_spec, cache_spec]
    args = [q, kq_new if quant else k_new, vq_new if quant else v_new,
            slot, n_valid, k_cache, v_cache]
    if quant:
        in_specs += [cache_spec, cache_spec, new_spec, new_spec]
        out_specs += [cache_spec, cache_spec]
        args += [k_scale, v_scale, ks_new, vs_new]
    return shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=tuple(in_specs),
        out_specs=tuple(out_specs),
        check_vma=False,
    )(*args)


def _decode_block(kind, p, x_t, positions, cache, pos, cfg, ctx):
    """x_t (B, D) one token at per-row positions ``pos`` (B,); returns
    (x_t, new_cache).  Non-windowed archs write slot = pos *unclamped*:
    past capacity the ring update drops the write (saturating semantics —
    the final KV slot is never silently overwritten forever; see the
    module capacity contract and :class:`CacheCapacityError`)."""
    if kind == "attn":
        h = L.rmsnorm(p["attn"]["norm"], x_t, cfg.norm_eps)
        q, k, v = _project_qkv(
            p["attn"], h[:, None, :], positions, cfg, ctx
        )  # (B, 1, H, dh)
        s_c = cache["k"].shape[2]
        slot = pos % s_c if cfg.window is not None else pos
        k_new = k.transpose(0, 2, 1, 3)  # (B, Hkv, 1, dh)
        v_new = v.transpose(0, 2, 1, 3)
        n_valid = jnp.minimum(pos + 1, s_c)
        q_t = q.reshape(q.shape[0], q.shape[2], q.shape[3])  # (B, H, dh)
        if ctx.kv_quant:
            o, ck, cv, cks, cvs = _decode_attention(
                q_t, k_new, v_new, cache["k"], cache["v"], slot, n_valid,
                ctx, cache["k_s"], cache["v_s"],
            )
            new_cache = {"k": ck, "v": cv, "k_s": cks, "v_s": cvs}
        else:
            o, ck, cv = _decode_attention(
                q_t, k_new, v_new, cache["k"], cache["v"], slot, n_valid, ctx
            )
            new_cache = {"k": ck, "v": cv}
        o = L.dense(p["attn"]["wo"], o.reshape(x_t.shape[0], -1))
        x_t = x_t + o
        if "moe" in p:
            y, _ = moe_ffn(p["moe"], x_t[:, None, :], cfg, ctx)
            x_t = x_t + y[:, 0]
        elif "ffn" in p:
            x_t = x_t + ffn(p["ffn"], x_t[:, None, :], cfg, ctx)[:, 0]
        return x_t, new_cache
    if kind == "rglru":
        o, st = rglru_step(p["rec"], x_t, cache, cfg)
        x_t = x_t + o
        x_t = x_t + ffn(p["ffn"], x_t[:, None, :], cfg, ctx)[:, 0]
        return x_t, st
    if kind == "mlstm":
        o, st = mlstm_step(p["rec"], x_t, cache, cfg)
        return x_t + o, st
    if kind == "slstm":
        o, st = slstm_step(p["rec"], x_t, cache, cfg)
        return x_t + o, st
    raise ValueError(kind)


def decode_step(params, cache, tokens, cfg: ModelConfig, ctx: ParallelCtx,
                *, active=None):
    """One decode step.  tokens (B,) int32 -> (logits (B, V), new cache).

    ``cache["pos"]`` is a per-row ``(B,)`` position vector (a legacy
    scalar is broadcast): rows decode at independent offsets, so a
    continuous-batching scheduler can hold requests at different depths
    in one batch.  ``active`` (optional ``(B,)`` bool/int) advances only
    the marked rows' positions — inactive (free) slots keep ``pos``
    untouched so an admitted request starts from a clean offset; their
    ride-along writes land in slots the next prefill overwrites anyway.
    """
    pos = cache["pos"]
    b = tokens.shape[0]
    if pos.ndim == 0:  # legacy fixed-shape caches: one position per batch
        pos = jnp.broadcast_to(pos, (b,))
    x = L.embed(params["embed"], tokens) if cfg.embed_inputs else tokens
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(
            pos[:, None, None], (b, 1, 3)
        ).astype(jnp.int32)
    else:
        positions = pos[:, None].astype(jnp.int32)

    def unit_fn(x_t, scanned):
        unit_params, unit_cache = scanned
        new_caches = {}
        for j, kind in enumerate(cfg.block_pattern):
            x_t, c = _decode_block(
                kind, unit_params[f"b{j}"], x_t, positions, unit_cache[f"b{j}"],
                pos, cfg, ctx,
            )
            new_caches[f"b{j}"] = c
        return x_t, new_caches

    if cfg.units > 0:
        x, new_unit_caches = jax.lax.scan(
            unit_fn, x, (params["units"], cache["units"])
        )
    else:
        new_unit_caches = cache["units"]
    new_tail = []
    for j, kind in enumerate(cfg.tail):
        x, c = _decode_block(
            kind, params["tail"][j], x, positions, cache["tail"][j], pos, cfg, ctx
        )
        new_tail.append(c)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if "head" in params:
        logits = L.dense(params["head"], x).astype(jnp.float32)
    else:
        logits = L.unembed(params["embed"], x)
    advance = 1 if active is None else jnp.asarray(active, jnp.int32)
    new_cache = {
        "units": new_unit_caches, "tail": new_tail, "pos": pos + advance,
    }
    return logits, new_cache
