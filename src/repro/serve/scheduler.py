"""Continuous-batching scheduler: the serving front-end's request loop.

The paper's thesis is that fine-grained tasks plus a multiple-issue
window absorb irregular load without global synchronization; ragged
serving traffic is the same problem one level up.  This scheduler holds
a fixed pool of ``n_slots`` batch slots and, **per decode step**, admits
queued requests into free slots and evicts finished ones — requests
never wait for the whole batch to drain (that is ``mode="static"``, the
baseline this module exists to beat).  The enabling engine refactor is
the per-slot ``(B,)`` position vector: every slot decodes at its own
depth, and ``decode_step(..., active=...)`` advances only live rows.

Admission control reuses the schedule simulator's machine model
(``repro.sched.simulator.MachineModel``): each admission costs one
batch-1 prefill, estimated as ``compute_time(2 * active_params *
prompt_len)`` seconds, and at most ``admit_budget_s`` of estimated
prefill work is admitted per step — bounding the per-step latency tail
(p99) instead of letting a burst of arrivals stall every live stream.

Backends: ``"dense"`` uses ``engine``'s ring caches; ``"paged"`` uses
``serve.pages`` pools + page table, so eviction returns pages with no
reshaping of live state.  The slot count should be a multiple of the DP
degree — ``engine._decode_attention`` warns and replicates otherwise.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.context import ParallelCtx
from repro.models.config import ModelConfig
from repro.sched.simulator import DEFAULT_MACHINE, MachineModel
from repro.serve import engine, pages

__all__ = ["Request", "Scheduler", "ragged_trace"]


@dataclasses.dataclass
class Request:
    """One serving request: a prompt plus a greedy-decode length."""

    rid: int
    prompt: np.ndarray  # (S,) int32 token ids
    max_new_tokens: int
    arrival_step: int = 0
    # filled by the scheduler
    out_tokens: list = dataclasses.field(default_factory=list)
    admitted_step: int = -1
    finished_step: int = -1

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))


def ragged_trace(n_requests: int, *, prompt_lens=(8, 16),
                 gen_lens=(4, 24), vocab: int = 256, seed: int = 0,
                 arrival_every: int = 0) -> list[Request]:
    """A deterministic ragged arrival trace: prompt/gen lengths cycle
    through the given sets (maximally mixed, so a static batch always
    contains one nearly-finished and one long-running request), tokens
    drawn from ``vocab``.  ``arrival_every > 0`` staggers arrivals."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        # gen length cycles fastest: adjacent requests (which a static
        # batcher pins into one batch) always have different decode depths
        g = int(gen_lens[i % len(gen_lens)])
        s = int(prompt_lens[(i // len(gen_lens)) % len(prompt_lens)])
        reqs.append(
            Request(
                rid=i,
                prompt=rng.integers(0, vocab, size=s).astype(np.int32),
                max_new_tokens=g,
                arrival_step=i * arrival_every if arrival_every else 0,
            )
        )
    return reqs


class Scheduler:
    """Slot-pool scheduler over ``engine``/``pages`` decode.

    ``mode="continuous"`` admits into any free slot every step;
    ``mode="static"`` admits only when *all* slots are free (classic
    batch serving — same code path, so the comparison is fair).
    """

    def __init__(self, params, cfg: ModelConfig, ctx: ParallelCtx, *,
                 n_slots: int, max_len: int, mode: str = "continuous",
                 backend: str = "dense", page_size: int = 8,
                 n_pages: int | None = None,
                 machine: MachineModel = DEFAULT_MACHINE,
                 admit_budget_s: float = float("inf")):
        if mode not in ("continuous", "static"):
            raise ValueError(f"mode={mode!r}")
        if backend not in ("dense", "paged"):
            raise ValueError(f"backend={backend!r}")
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.n_slots = n_slots
        self.max_len = max_len
        self.mode = mode
        self.backend = backend
        self.machine = machine
        self.admit_budget_s = admit_budget_s
        self.s_cache = engine.cache_len(cfg, max_len)

        if backend == "paged":
            max_pages = -(-max_len // page_size)
            if n_pages is None:
                # enough for every slot full, + the trash page
                n_pages = n_slots * max_pages + 1
            self.alloc = pages.PageAllocator(
                n_pages=n_pages, page_size=page_size, n_slots=n_slots,
                max_pages=max_pages,
            )
            self.cache = pages.paged_init_cache(
                cfg, n_slots, n_pages, page_size, ctx
            )
            self._decode = jax.jit(
                lambda p, c, t, tab, a: pages.paged_decode_step(
                    p, c, t, tab, cfg, ctx, active=a
                )
            )
        else:
            self.alloc = None
            self.cache = engine.init_cache(
                cfg, n_slots, max_len, kv_quant=ctx.kv_quant
            )
            self._decode = jax.jit(
                lambda p, c, t, tab, a: engine.decode_step(
                    p, c, t, cfg, ctx, active=a
                )
            )
        # one jitted prefill per prompt-length bucket (batch 1)
        self._prefill = jax.jit(
            lambda p, b: engine.prefill(p, b, cfg, ctx, max_len=max_len)
        )

        self.tokens = jnp.zeros((n_slots,), jnp.int32)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.remaining = np.zeros(n_slots, np.int64)
        self.queue: deque[Request] = deque()
        self.stats = {
            "steps": 0, "prefills": 0, "evictions": 0,
            "decoded_tokens": 0, "budget_deferrals": 0,
        }
        self.step_latencies: list[float] = []

    # -- request intake ------------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue a request; rejects ones that can never fit the cache."""
        total = req.prompt_len + req.max_new_tokens
        cap = (
            self.alloc.capacity if self.backend == "paged" else self.s_cache
        )
        if self.cfg.window is None and total > cap:
            raise engine.CacheCapacityError(
                f"request {req.rid}: {req.prompt_len} prompt + "
                f"{req.max_new_tokens} new = {total} tokens > cache "
                f"capacity {cap}"
            )
        self.queue.append(req)

    # -- slot plumbing -------------------------------------------------------

    def _write_slot(self, sub_cache, slot: int) -> None:
        """Install a batch-1 prefill cache into batch row ``slot``.  KV
        leaves of the paged backend scatter through the page table; every
        other leaf (recurrent state, ``pos``; dense KV) is a row write at
        the leaf's batch axis."""
        if self.backend == "paged":
            req = self.slot_req[slot]
            self.alloc.ensure(slot, req.prompt_len)
            self.cache = pages.paged_prefill_write(
                self.cache, sub_cache, self.alloc, slot, req.prompt_len
            )

        def row(path, leaf, sub):
            if self.backend == "paged" and (
                engine._leaf_key(path[-1]) in engine._KV_LEAF_KEYS
            ):
                return leaf  # already scattered into the pools
            ax = engine.cache_batch_axis(path)
            idx = (slice(None),) * ax + (slot,)
            return leaf.at[idx].set(jnp.take(sub, 0, axis=ax))

        self.cache = jax.tree_util.tree_map_with_path(
            row, self.cache, sub_cache
        )

    def _admit(self, step: int) -> None:
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if self.mode == "static" and len(free) < self.n_slots:
            return  # static batching: wait for the whole batch to drain
        budget = self.admit_budget_s
        admitted = 0
        while self.queue and free:
            req = self.queue[0]
            if req.arrival_step > step:
                break
            cost = self.machine.compute_time(
                2.0 * self.cfg.active_param_count() * req.prompt_len
            )
            # always make progress: the step's first admission is exempt,
            # so one over-budget prompt delays neighbours, never starves.
            if cost > budget and admitted > 0:
                self.stats["budget_deferrals"] += 1
                break
            if self.backend == "paged":
                need = self.alloc.pages_needed(req.prompt_len)
                if need > self.alloc.n_free():
                    break  # wait for an eviction to return pages
            self.queue.popleft()
            slot = free.pop(0)
            self.slot_req[slot] = req
            req.admitted_step = step
            budget -= cost
            logits, sub = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt)[None]}
            )
            tok = int(jnp.argmax(logits[0]))
            req.out_tokens.append(tok)
            self._write_slot(sub, slot)
            self.tokens = self.tokens.at[slot].set(tok)
            self.remaining[slot] = req.max_new_tokens - 1
            self.stats["prefills"] += 1
            admitted += 1
            if self.remaining[slot] <= 0:
                self._evict(slot, step)

    def _evict(self, slot: int, step: int) -> None:
        req = self.slot_req[slot]
        req.finished_step = step
        self.slot_req[slot] = None
        self.remaining[slot] = 0
        if self.backend == "paged":
            self.alloc.release(slot)
        self.stats["evictions"] += 1

    # -- the loop ------------------------------------------------------------

    def _active_mask(self) -> np.ndarray:
        return np.array([r is not None for r in self.slot_req])

    def step(self, step_idx: int) -> None:
        """One scheduler step: admit, decode once, harvest, evict."""
        t0 = time.perf_counter()
        self._admit(step_idx)
        active = self._active_mask()
        if active.any():
            # capacity guard: the engine drops over-capacity writes; the
            # driver must never ask for those logits (module contract).
            if self.cfg.window is None and self.backend == "dense":
                pos = np.asarray(self.cache["pos"])
                if (pos[active] >= self.s_cache).any():
                    raise engine.CacheCapacityError(
                        f"active slot at pos {int(pos[active].max())} >= "
                        f"cache capacity {self.s_cache}"
                    )
            if self.backend == "paged":
                # grow pages on demand: this step writes each active row's
                # KV at ``pos``, which must be page-mapped before decode
                # (an unmapped write lands on the trash page but the
                # position would still be live-masked — garbage reads).
                pos = np.asarray(self.cache["pos"])
                for i in np.flatnonzero(active):
                    self.alloc.ensure(int(i), int(pos[i]) + 1)
                table = self.alloc.table()
            else:
                table = None
            logits, self.cache = self._decode(
                self.params, self.cache, self.tokens, table,
                jnp.asarray(active, jnp.int32),
            )
            toks = np.asarray(jnp.argmax(logits, axis=-1))
            new_tokens = np.array(self.tokens)
            for i in np.flatnonzero(active):
                req = self.slot_req[i]
                req.out_tokens.append(int(toks[i]))
                new_tokens[i] = toks[i]
                self.remaining[i] -= 1
                if self.remaining[i] <= 0:
                    self._evict(int(i), step_idx)
            self.tokens = jnp.asarray(new_tokens)
            self.stats["decoded_tokens"] += int(active.sum())
        self.stats["steps"] += 1
        self.step_latencies.append(time.perf_counter() - t0)

    def run(self, requests, *, max_steps: int = 100_000) -> dict:
        """Serve ``requests`` to completion; returns outputs + metrics.

        ``tokens/s`` counts *generated* tokens (prefill-emitted first
        token + decode tokens) over total wall; p50/p99 are per-step wall
        latencies in ms (admission + decode, the user-visible stall)."""
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        step = 0
        while (self.queue or self._active_mask().any()) and step < max_steps:
            self.step(step)
            step += 1
        wall = time.perf_counter() - t0
        if self.queue:
            raise RuntimeError(f"max_steps hit with {len(self.queue)} queued")
        total_tokens = sum(len(r.out_tokens) for r in requests)
        lat = np.array(self.step_latencies)
        return {
            "mode": self.mode,
            "backend": self.backend,
            "n_slots": self.n_slots,
            "requests": len(requests),
            "outputs": {r.rid: list(r.out_tokens) for r in requests},
            "steps": self.stats["steps"],
            "prefills": self.stats["prefills"],
            "budget_deferrals": self.stats["budget_deferrals"],
            "generated_tokens": int(total_tokens),
            "wall_s": float(wall),
            "tokens_per_s": float(total_tokens / max(wall, 1e-9)),
            "p50_step_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_step_ms": float(np.percentile(lat, 99) * 1e3),
        }
