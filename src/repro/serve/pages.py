"""Paged KV cache: ring KV allocated in fixed-size blocks via a page table.

The block-sparse engine manages matrix panels as fixed-size blocks with
host-side liveness maps (``core.plan``); this module applies the same
treatment to KV-cache liveness.  Instead of one contiguous
``(B, Hkv, S_cache, Dh)`` ring per layer, each layer holds a **page
pool** ``(n_pages, Hkv, page_size, Dh)`` (stacked ``(U, n_pages, ...)``
for scanned units) and every batch slot owns an ordered list of page ids
recorded in a single **page table** shared by all layers — layer ``i``'s
token ``t`` always lives at ``(table[slot, t // page_size],
t % page_size)`` of layer ``i``'s pool.  Admitting a request allocates
pages from the free list as its sequence grows; evicting returns them
with **no reshaping or compaction of live state** — exactly the property
the continuous-batching scheduler needs (the flashinfer serving idiom).

Page ``0`` is reserved as the *trash page*: rows with nothing to write
this step (inactive slots, out-of-capacity positions) are routed there,
so the decode step stays a fixed-shape program with no per-row branching.

Scope: non-windowed archs (a sliding-window ring is already O(window)
and gains nothing from paging), ``tp_size == 1`` and ``kv_quant=False``
— the seq-sharded and int8 decode paths keep the dense ring layout
(``serve.engine``).  DP sharding of the pool's page axis is a follow-up.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.context import ParallelCtx
from repro.models import layers as L
from repro.models.attention import _project_qkv
from repro.models.config import ModelConfig
from repro.serve import engine

__all__ = [
    "OutOfPages",
    "PageAllocator",
    "paged_init_cache",
    "paged_prefill_write",
    "paged_decode_step",
    "gather_pages",
]


class OutOfPages(RuntimeError):
    """The free list is empty — admission must wait for an eviction."""


@dataclasses.dataclass
class PageAllocator:
    """Host-side page-table bookkeeping (numpy; no device state).

    ``n_pages`` counts the pool's physical pages *including* the reserved
    trash page 0, so ``n_pages - 1`` are allocatable.  ``max_pages`` is
    the per-slot table width: slot capacity = ``max_pages * page_size``
    tokens.
    """

    n_pages: int
    page_size: int
    n_slots: int
    max_pages: int

    def __post_init__(self):
        if self.n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        self.free: list[int] = list(range(self.n_pages - 1, 0, -1))
        self.slot_pages: list[list[int]] = [[] for _ in range(self.n_slots)]
        self._table = np.zeros((self.n_slots, self.max_pages), np.int32)

    @property
    def capacity(self) -> int:
        """Max tokens one slot can hold."""
        return self.max_pages * self.page_size

    def n_free(self) -> int:
        return len(self.free)

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)  # ceil

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow ``slot`` to cover ``n_tokens`` tokens, allocating from the
        free list.  Raises :class:`OutOfPages` (allocating nothing) when
        the free list is short, and ``CacheCapacityError`` past the
        per-slot table width."""
        need = self.pages_needed(n_tokens)
        have = len(self.slot_pages[slot])
        if need > self.max_pages:
            raise engine.CacheCapacityError(
                f"request needs {need} pages > max_pages={self.max_pages} "
                f"({n_tokens} tokens, page_size={self.page_size})"
            )
        grow = need - have
        if grow <= 0:
            return
        if grow > len(self.free):
            raise OutOfPages(
                f"slot {slot} needs {grow} pages, {len(self.free)} free"
            )
        for _ in range(grow):
            pid = self.free.pop()
            self.slot_pages[slot].append(pid)
            self._table[slot, len(self.slot_pages[slot]) - 1] = pid

    def release(self, slot: int) -> int:
        """Return ``slot``'s pages to the free list; returns how many."""
        pages = self.slot_pages[slot]
        n = len(pages)
        self.free.extend(reversed(pages))
        self.slot_pages[slot] = []
        self._table[slot, :] = 0
        return n

    def table(self) -> jax.Array:
        """The device page table ``(n_slots, max_pages)`` int32 (trash page
        0 for unallocated entries)."""
        return jnp.asarray(self._table)


# ---------------------------------------------------------------------------
# pool init / prefill scatter / gather
# ---------------------------------------------------------------------------


def _check_paged_supported(cfg: ModelConfig, ctx: ParallelCtx):
    if cfg.window is not None:
        raise NotImplementedError(
            "paged KV targets non-windowed archs (a sliding-window ring is "
            "already O(window))"
        )
    if ctx.kv_quant:
        raise NotImplementedError("paged + kv_quant: keep the dense ring")
    if ctx.has_mesh and ctx.tp_size > 1:
        raise NotImplementedError(
            "paged + TP seq-sharding: keep the dense ring"
        )


def paged_init_cache(cfg: ModelConfig, n_slots: int, n_pages: int,
                     page_size: int, ctx: ParallelCtx | None = None):
    """Like ``engine.init_cache`` but every attn cache is a page pool
    ``(n_pages, Hkv, page_size, Dh)`` (stacked ``(U, n_pages, ...)``) —
    note there is **no batch axis** on KV leaves; the page table owns the
    slot -> page mapping.  Recurrent/conv states and ``pos`` keep their
    dense per-slot layout (they are O(1) per row; nothing to page)."""
    if ctx is not None:
        _check_paged_supported(cfg, ctx)
    dense = engine.init_cache(cfg, n_slots, page_size)

    def pool(path, leaf):
        if engine._leaf_key(path[-1]) not in engine._KV_LEAF_KEYS:
            return leaf
        # dense: (U?, n_slots, Hkv, page_size, Dh) -> (U?, n_pages, ...)
        ax = engine.cache_batch_axis(path)
        shape = leaf.shape[:ax] + (n_pages,) + leaf.shape[ax + 1:]
        return jnp.zeros(shape, leaf.dtype)

    return jax.tree_util.tree_map_with_path(pool, dense)


def _scatter_tokens(pool, kv, pages, n_tokens: int, page_size: int):
    """Write ``kv`` ``(1, Hkv, S, Dh)`` tokens ``[0, n_tokens)`` into
    ``pool`` ``(n_pages, Hkv, page_size, Dh)`` at the slot's ``pages``."""
    t = np.arange(n_tokens)
    page_ids = jnp.asarray(np.asarray(pages, np.int32)[t // page_size])
    within = jnp.asarray(t % page_size)
    vals = kv[0, :, :n_tokens, :].transpose(1, 0, 2)  # (S, Hkv, Dh)
    return pool.at[page_ids, :, within, :].set(vals.astype(pool.dtype))


def paged_prefill_write(pools, dense_cache, alloc: PageAllocator, slot: int,
                        n_tokens: int):
    """Scatter one request's dense prefill KV (``engine.prefill`` with
    batch 1) into the page pools at ``slot``'s pages (allocate first with
    ``alloc.ensure``).  Non-KV leaves are left untouched — the scheduler
    writes those rows directly.  Returns the updated pools tree."""
    pages = alloc.slot_pages[slot]

    def write(path, pool, sub):
        if engine._leaf_key(path[-1]) not in engine._KV_LEAF_KEYS:
            return pool
        if engine.cache_batch_axis(path) == 1:  # stacked units: vmap U
            return jax.vmap(
                lambda p, s: _scatter_tokens(
                    p, s, pages, n_tokens, alloc.page_size
                )
            )(pool, sub)
        return _scatter_tokens(pool, sub, pages, n_tokens, alloc.page_size)

    return jax.tree_util.tree_map_with_path(write, pools, dense_cache)


def gather_pages(pool, table):
    """``(n_pages, Hkv, ps, Dh)`` x ``(B, max_pages)`` ->
    ``(B, Hkv, max_pages * ps, Dh)`` contiguous per-slot KV views."""
    g = pool[table]  # (B, max_pages, Hkv, ps, Dh)
    b, mp, hkv, ps, dh = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, hkv, mp * ps, dh)


# ---------------------------------------------------------------------------
# paged decode
# ---------------------------------------------------------------------------


def _paged_attn_block(p, x_t, positions, cache, pos, table, cfg, ctx):
    """The paged twin of ``engine._decode_block``'s attn branch: scatter
    the new token's K/V through the page table, then attend over the
    gathered per-slot views.  Out-of-capacity / unmapped positions write
    to trash page 0 (dropped — same saturating contract as the ring)."""
    b = x_t.shape[0]
    ps = cache["k"].shape[2]
    max_pages = table.shape[1]
    h = L.rmsnorm(p["attn"]["norm"], x_t, cfg.norm_eps)
    q, k, v = _project_qkv(p["attn"], h[:, None, :], positions, cfg, ctx)
    k_new = k.transpose(0, 2, 1, 3)  # (B, Hkv, 1, dh)
    v_new = v.transpose(0, 2, 1, 3)

    rows = jnp.arange(b)
    page_idx = jnp.clip(pos // ps, 0, max_pages - 1)
    in_range = pos < max_pages * ps
    page = jnp.where(in_range, table[rows, page_idx], 0)  # trash when OOB
    within = pos % ps
    k_pool = cache["k"].at[page, :, within, :].set(
        k_new[:, :, 0, :].astype(cache["k"].dtype)
    )
    v_pool = cache["v"].at[page, :, within, :].set(
        v_new[:, :, 0, :].astype(cache["v"].dtype)
    )

    kf = gather_pages(k_pool, table).astype(jnp.float32)
    vf = gather_pages(v_pool, table).astype(jnp.float32)
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    g = hq // hkv
    n_valid = jnp.minimum(pos + 1, max_pages * ps)
    qg = (
        q.reshape(b, hq, dh).astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))
    ).reshape(b, hkv, g, dh)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg, kf)
    live = (
        jnp.arange(kf.shape[2])[None, None, None, :]
        < n_valid[:, None, None, None]
    )
    logits = jnp.where(live, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", w, vf).reshape(b, hq * dh)
    x_t = x_t + L.dense(p["attn"]["wo"], o.astype(x_t.dtype))
    if "moe" in p:
        from repro.models.moe import moe_ffn

        y, _ = moe_ffn(p["moe"], x_t[:, None, :], cfg, ctx)
        x_t = x_t + y[:, 0]
    elif "ffn" in p:
        from repro.models.ffn import ffn

        x_t = x_t + ffn(p["ffn"], x_t[:, None, :], cfg, ctx)[:, 0]
    return x_t, {"k": k_pool, "v": v_pool}


def paged_decode_step(params, cache, tokens, table, cfg: ModelConfig,
                      ctx: ParallelCtx, *, active=None):
    """``engine.decode_step`` over page pools: same per-row ``pos``
    vector and ``active`` advancement, but attn KV lives behind
    ``table`` ``(B, max_pages)`` int32.  The table is a traced operand —
    admissions/evictions change its *values*, never the program."""
    pos = cache["pos"]
    b = tokens.shape[0]
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    x = L.embed(params["embed"], tokens) if cfg.embed_inputs else tokens
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(
            pos[:, None, None], (b, 1, 3)
        ).astype(jnp.int32)
    else:
        positions = pos[:, None].astype(jnp.int32)

    def block(kind, p, x_t, c):
        if kind == "attn":
            return _paged_attn_block(
                p, x_t, positions, c, pos, table, cfg, ctx
            )
        return engine._decode_block(kind, p, x_t, positions, c, pos, cfg, ctx)

    def unit_fn(x_t, scanned):
        unit_params, unit_cache = scanned
        new_caches = {}
        for j, kind in enumerate(cfg.block_pattern):
            x_t, c = block(kind, unit_params[f"b{j}"], x_t, unit_cache[f"b{j}"])
            new_caches[f"b{j}"] = c
        return x_t, new_caches

    if cfg.units > 0:
        x, new_unit_caches = jax.lax.scan(
            unit_fn, x, (params["units"], cache["units"])
        )
    else:
        new_unit_caches = cache["units"]
    new_tail = []
    for j, kind in enumerate(cfg.tail):
        x, c = block(kind, params["tail"][j], x, cache["tail"][j])
        new_tail.append(c)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if "head" in params:
        logits = L.dense(params["head"], x).astype(jnp.float32)
    else:
        logits = L.unembed(params["embed"], x)
    advance = 1 if active is None else jnp.asarray(active, jnp.int32)
    new_cache = {
        "units": new_unit_caches, "tail": new_tail, "pos": pos + advance,
    }
    return logits, new_cache
