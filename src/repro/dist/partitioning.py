"""Path-based FSDP + tensor-parallel PartitionSpec inference.

Model parameters are plain nested dicts (models/layers.py), so sharding
is attached *by path*, never by module type:

* dense kernels ``{"w": (..., d_in, d_out)}`` — ``(..., "data",
  "model")``: input dim FSDP-sharded, output dim tensor-parallel.  Any
  leading dims (the scan-stacked unit axis) stay replicated.
* MoE expert weights (raw ``(..., E, d_in, d_out)`` arrays under
  ``w_gate`` / ``w_up`` / ``w_down``) — experts over the TP axis (expert
  parallelism, models/moe.py) and ``d_model`` over the FSDP axis.
* embeddings ``(V, D)`` — ``("model", "data")``: vocab over TP (the
  all-reduce after the tied unembed is the same collective as a TP
  head), ``D`` over FSDP.
* biases — output dim over TP; norms / conv / gate vectors replicated.

``param_specs`` proposes specs from these rules; ``_validate_spec``
makes them safe for a concrete mesh (a dim that does not divide its
axis-group size falls back to replicated — nonuniform shapes like
vocab 50304 on a 16-way axis must not crash a launch); ``param_shardings``
composes both into NamedShardings, with ``fsdp=False`` (ZeRO-1 params)
and ``tp=False`` (pure data parallelism) dropping the respective axes.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "param_shardings", "_validate_spec"]

_FSDP_AXIS = "data"
_TP_AXIS = "model"

#: raw-array expert weights in models/moe.py (dense layers wrap their
#: kernel in a {"w": ...} dict, so they never hit these keys directly)
_EXPERT_UP_KEYS = ("w_gate", "w_up")  # (..., E, d_model, d_ff)
_EXPERT_DOWN_KEYS = ("w_down",)  # (..., E, d_ff, d_model)


def _path_keys(path) -> list[Any]:
    out = []
    for entry in path:
        if hasattr(entry, "key"):
            out.append(entry.key)
        elif hasattr(entry, "idx"):
            out.append(entry.idx)
        else:  # pragma: no cover - future jax key types
            out.append(str(entry))
    return out


def _leaf_spec(path, leaf) -> P:
    keys = _path_keys(path)
    last = keys[-1] if keys else None
    nd = getattr(leaf, "ndim", len(leaf.shape))
    lead = [None] * max(nd - 2, 0)

    if last == "embedding" and nd == 2:
        return P(_TP_AXIS, _FSDP_AXIS)
    if last == "w" and nd >= 2:
        return P(*lead, _FSDP_AXIS, _TP_AXIS)
    if last == "b" and nd >= 1:
        return P(*([None] * (nd - 1)), _TP_AXIS)
    if last in _EXPERT_UP_KEYS and nd >= 3:
        return P(*([None] * (nd - 3)), _TP_AXIS, _FSDP_AXIS, None)
    if last in _EXPERT_DOWN_KEYS and nd >= 3:
        return P(*([None] * (nd - 3)), _TP_AXIS, None, _FSDP_AXIS)
    # norms, convs, recurrence gates, router (fp32, small): replicated
    return P(*([None] * nd))


def param_specs(params) -> Any:
    """PartitionSpec tree mirroring ``params`` (one P per leaf)."""
    return jax.tree_util.tree_map_with_path(_leaf_spec, params)


def _filter_spec(spec: P, *, fsdp: bool, tp: bool) -> P:
    """Drop the FSDP and/or TP axis from a spec (ZeRO-1 / pure-DP)."""

    def keep(axis):
        if axis == _FSDP_AXIS and not fsdp:
            return False
        if axis == _TP_AXIS and not tp:
            return False
        return True

    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if keep(a))
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(kept)
    return P(*out)


def _validate_spec(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Make ``spec`` safe for ``shape`` on ``mesh``.

    * a spec longer than the array rank (an over-sharded tree) is a bug in
      the rules — raise;
    * an axis name the mesh does not know is a bug in the caller — raise;
    * a dim that does not divide its axis-group size silently falls back
      to replicated for that dim (nonuniform vocab / head counts must
      degrade, not crash).

    ``mesh`` only needs a ``.shape`` mapping (axis name -> size), so
    abstract stand-ins work for spec checks without devices.
    """
    entries = tuple(spec)
    if len(entries) > len(shape):
        raise ValueError(
            f"spec {spec} has {len(entries)} entries for rank-{len(shape)} "
            f"array of shape {shape} (over-sharded)"
        )
    mesh_shape = dict(mesh.shape)
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            if a not in mesh_shape:
                raise ValueError(
                    f"spec {spec} references unknown mesh axis {a!r}; "
                    f"mesh has {sorted(mesh_shape)}"
                )
        group = math.prod(mesh_shape[a] for a in axes)
        out.append(entry if dim % group == 0 else None)
    # dims beyond the spec's length are implicitly replicated
    return P(*out)


def param_shardings(
    params,
    mesh: Mesh,
    *,
    fsdp: bool = True,
    tp: bool = True,
) -> Any:
    """NamedSharding tree for ``params`` on ``mesh``.

    ``fsdp=False`` replicates over the FSDP axis (ZeRO-1 parameter
    mirrors); ``tp=False`` replicates over the TP axis (pure data
    parallelism).  Indivisible dims degrade to replicated per
    ``_validate_spec``.
    """
    specs = param_specs(params)

    def to_sharding(leaf, spec):
        spec = _filter_spec(spec, fsdp=fsdp, tp=tp)
        spec = _validate_spec(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(to_sharding, params, specs)
