"""Distributed-parallelism layer: mesh context, partitioning, collectives.

This package is the seam between the mesh-agnostic model code
(``repro.models``) and the hardware: ``context.ParallelCtx`` carries the
mesh and the parallelism policy, ``partitioning`` infers FSDP +
tensor-parallel ``PartitionSpec``s over parameter pytrees, and
``collective_matmul`` routes the LM stack's projections through the
paper's task-based SUMMA engine (``repro.core``) when asked to.
"""
from repro.dist.context import ParallelCtx
from repro.dist.partitioning import param_shardings, param_specs
from repro.dist.collective_matmul import allgather_matmul, project

__all__ = [
    "ParallelCtx",
    "param_specs",
    "param_shardings",
    "project",
    "allgather_matmul",
]
