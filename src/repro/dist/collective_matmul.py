"""Collective matmuls: the paper's engine embedded in the LM stack.

``project`` is the single entry point the model code uses for its big
projections (models/ffn.py).  It routes by ``ctx.matmul_strategy``:

* ``"xla"`` — plain einsum; GSPMD picks the collectives.  The default.
* ``"summa"`` — the task-based multiple-issue SUMMA schedule
  (core.summa, paper §3.2) over the (dp x tp) mesh slice, via the
  ``DistributedMatmul`` built by ``ctx.matmul()``.
* ``"allgather"`` — ``allgather_matmul`` below: a ring collective matmul
  over the TP axis that overlaps the activation all-gather with the
  per-chunk GEMMs using the same multiple-issue lookahead idiom as
  ``core.summa._exec_taskbased`` (paper Eq. (1)); it is the ``I = K``
  communication pattern realised as a pipeline instead of one bulk
  gather.  See EXPERIMENTS.md §Perf for the trade-off between the two
  non-XLA strategies.
* ``"auto"`` — per-shape pick by *simulated time*: the schedule
  autotuner (repro.sched.tuner) searches lookahead x k_blocks x strategy
  over the discrete-event simulator and executes the winner (its tuned
  lookahead included); the ring is routed to when its pipeline estimate
  beats the tuned SUMMA-family makespan.  This replaces the old static
  bytes tie-break — the ``MatmulPlan`` cost model remains the byte
  source, the simulator adds overlap and imbalance.

``project`` also accepts an optional block mask over the weight
(``w_mask``, or one registered in ``ctx.weight_block_masks``): the
planned schedule then prunes dead K panels and, with the Pallas local
kernel, runs the per-device block-CSR BSMM — the paper's block-sparse
path embedded in the LM.  The xla path zeroes masked blocks so every
strategy computes the same masked product.

All strategies accumulate in fp32 and return the activation dtype, so
swapping them changes only the schedule, not the arithmetic contract.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

__all__ = ["project", "allgather_matmul"]


def _mask_weight(w: jax.Array, w_mask: np.ndarray) -> jax.Array:
    """Zero masked blocks of a (d_in, d_out) weight (einsum-path parity)."""
    from repro.core.summa import _apply_block_mask

    return _apply_block_mask(w, np.asarray(w_mask, dtype=bool))


def _ring_eligible(ctx, x2: jax.Array, w: jax.Array) -> bool:
    return (
        ctx.tp_size > 1
        and x2.shape[0] % (ctx.dp_size * ctx.tp_size) == 0
        and w.shape[-1] % ctx.tp_size == 0
    )


def project(
    x: jax.Array,
    w: jax.Array,
    ctx,
    *,
    w_mask: np.ndarray | None = None,
) -> jax.Array:
    """``x @ w`` with the context's matmul strategy.

    ``x``: (..., d_in) activations; ``w``: (d_in, d_out) kernel.  Leading
    dims are flattened into SUMMA's M dimension and restored afterwards.
    ``w_mask`` is an optional (Kblk, Nblk) block mask over the weight;
    when omitted, ``ctx.weight_block_masks`` is consulted for the weight
    shape.  Meshless contexts always take the einsum path so smoke tests
    and eval_shape tracing never build collectives.
    """
    if w_mask is None:
        w_mask = ctx.weight_mask(w.shape)
    if ctx.matmul_strategy == "xla" or not ctx.has_mesh or ctx.pure_dp:
        if w_mask is not None:
            w = _mask_weight(w, w_mask)
        return jnp.einsum(
            "...d,df->...f", x, w, preferred_element_type=jnp.float32
        ).astype(x.dtype)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    strategy = ctx.matmul_strategy
    ring_ok = _ring_eligible(ctx, x2, w)
    tune = False
    if strategy == "auto":
        if w_mask is not None:
            # Masked plans always execute the planned broadcast schedule
            # (DAG or BSMM) — the gather-style executors are sparsity-
            # blind; the tuner still picks the lookahead window.
            strategy = "summa"
            tune = True
        else:
            # One cached tuned plan per shape: the simulator-searched
            # schedule (strategy x k_blocks x lookahead), vs. the ring's
            # pipeline estimate when the ring is eligible.
            from repro.sched.tuner import ring_makespan

            plan = ctx.matmul().plan(
                x2.shape[0], x2.shape[1], w.shape[1],
                itemsize=x2.dtype.itemsize, tune=True,
            )
            if ring_ok and ring_makespan(plan) < plan.tuned["makespan_s"]:
                strategy = "ring"
            else:
                strategy = "summa"
                tune = True
    if strategy in ("allgather", "ring") and ring_ok and w_mask is None:
        out = allgather_matmul(
            x2, w, mesh=ctx.mesh, axis=ctx.tp_axis, batch_axes=ctx.dp_axes
        )
    else:
        summa_strategy = {"summa": None, "ring": None}.get(strategy, strategy)
        out = ctx.matmul()(
            x2, w, b_mask=w_mask, strategy=summa_strategy, tune=tune
        )
    return out.reshape(*lead, w.shape[-1])


def allgather_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    mesh: Mesh,
    axis: str,
    batch_axes: tuple[str, ...] = (),
    lookahead: int = 2,
    accum_dtype=jnp.float32,
) -> jax.Array:
    """Ring all-gather matmul with multiple-issue lookahead.

    The sequence-parallel <-> tensor-parallel boundary matmul: ``x``
    (M, K) arrives with M sharded over ``(*batch_axes, axis)`` and ``w``
    (K, N) column-sharded over ``axis`` (P shards).  Instead of one bulk
    all-gather of ``x`` followed by one GEMM, the activation chunks
    travel the ring one hop per step while each device multiplies the
    chunk it already holds against its weight columns — transfer ``g+1``
    is issued before GEMM ``g`` consumes its buffer, so the two overlap
    exactly as the prefetch pipeline in
    ``core.summa._exec_taskbased``.  ``lookahead`` is the pipeline
    depth I of paper Eq. (1): ``I`` ring hops are in flight at any time
    (clamped to the shard count).

    There is no redundant compute: each device produces the
    (M / |batch_axes|, N / P) output tile of its (batch, ring-group)
    coordinate, so global FLOPs are exactly 2·M·K·N.  Under reverse-mode
    AD the transpose of the activation all-gather is a reduce-scatter of
    the cotangent, so the backward pass is the matching overlapped
    reduce-scatter matmul for free.

    Returns (M, N), M sharded over ``batch_axes`` and N over ``axis``,
    in ``x.dtype``.
    """
    (m, k), (k2, n) = x.shape, w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {x.shape} @ {w.shape}")
    p = mesh.shape[axis]
    b_size = math.prod(mesh.shape[a] for a in batch_axes)
    if m % (b_size * p):
        raise ValueError(
            f"M={m} must be divisible by the M sharding "
            f"({b_size} x {p} shards)"
        )
    if n % p:
        raise ValueError(
            f"N={n} must be divisible by the {axis!r} axis size ({p})"
        )
    m_loc = m // (b_size * p)  # ring-chunk rows held per device
    la = max(1, min(lookahead, p))
    perm = [(i, (i + 1) % p) for i in range(p)]

    def fn(x_loc, w_loc):
        me = jax.lax.axis_index(axis)

        # Prologue: put ``la`` ring hops in flight before any GEMM.
        bufs = [x_loc]
        for _ in range(la - 1):
            bufs.append(jax.lax.ppermute(bufs[-1], axis, perm))
        buf = jnp.stack(bufs)  # (I, m_loc, k)

        def partial(acc, g, x_chunk):
            src = (me - g) % p  # original owner of the chunk in hand
            tile = jnp.matmul(x_chunk, w_loc, preferred_element_type=accum_dtype)
            return jax.lax.dynamic_update_slice_in_dim(
                acc, tile, src * m_loc, axis=0
            )

        def body(carry, g):
            acc, b = carry
            nxt = jax.lax.ppermute(b[-1], axis, perm)  # hop g+I: independent
            acc = partial(acc, g, b[0])
            b = jnp.concatenate([b[1:], nxt[None]], axis=0)
            return (acc, b), None

        acc = jnp.zeros((p * m_loc, w_loc.shape[1]), accum_dtype)
        steady = p - la
        if steady > 0:
            (acc, buf), _ = jax.lax.scan(
                body, (acc, buf), jnp.arange(steady)
            )
        # Epilogue: drain the I buffered chunks.
        for i in range(la):
            acc = partial(acc, steady + i, buf[i])
        return acc.astype(x.dtype)

    m_entry = (*batch_axes, axis) if batch_axes else axis
    out_m_entry = (
        batch_axes if len(batch_axes) > 1 else batch_axes[0]
    ) if batch_axes else None
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(m_entry, None), P(None, axis)),
        out_specs=P(out_m_entry, axis),
        check_vma=False,
    )(x, w)
