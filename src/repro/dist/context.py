"""ParallelCtx: the one object that carries parallelism policy.

Every model / train / serve entry point takes a ``ParallelCtx``.  It
bundles the device mesh with the axis roles (which mesh axes act as data
parallel, which as tensor parallel) and the feature switches that the
dry-run driver sweeps (matmul strategy, attention implementation, ZeRO-1,
KV-cache quantization, ...).  Model code never touches the mesh directly;
it goes through ``ctx.wsc`` (sharding constraints), ``ctx.named``
(NamedSharding construction) and ``repro.dist.collective_matmul.project``
(matmuls), which all degrade to no-ops / plain einsums on ``mesh=None``
so the same code runs single-device smoke tests unchanged.

``matmul()`` is the factory that wires the paper's engine into the LM
stack: with ``matmul_strategy="summa"`` it builds a
``core.api.DistributedMatmul`` over the (dp x tp) mesh slice running the
task-based multiple-issue schedule (core.summa), and the FFN projections
route through it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ParallelCtx"]

#: matmul_strategy -> core.summa strategy actually executed
_MATMUL_STRATEGIES = {
    "xla": None,  # plain jnp.einsum, XLA chooses the collectives
    "summa": "taskbased",  # paper Eq. (1) multiple-issue SUMMA
    "allgather": "allgather",  # I = K endpoint of Eq. (1)
    # per-shape pick via the MatmulPlan cost model (ring vs SUMMA vs
    # allgather); the engine defaults to taskbased when costs tie.
    "auto": "taskbased",
}


@dataclasses.dataclass
class ParallelCtx:
    """Mesh + axis roles + parallelism feature switches.

    ``dp_axes`` may name several mesh axes (e.g. ``("pod", "data")`` on
    the two-pod production mesh); they act as one flattened data-parallel
    axis.  ``pure_dp=True`` folds the tensor-parallel axis into data
    parallelism: ``tp_axis`` becomes ``None`` and every weight is fully
    replicated along the former TP axis.
    """

    mesh: Mesh | None
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str | None = "model"
    matmul_strategy: str = "xla"  # "xla" | "summa" | "allgather" | "auto"
    attention_impl: str = "ref"  # "ref" | "chunked"
    mlstm_chunk: int | None = None
    zero1: bool = False
    kv_quant: bool = False
    slstm_replicated: bool = False
    pure_dp: bool = False
    # Static block-sparsity of projection weights: maps (d_in, d_out) ->
    # bool block mask.  ``project`` consults it so sparse FFN weights run
    # the planned block-sparse schedule (and the xla path stays masked for
    # an identical arithmetic contract).
    weight_block_masks: Any = None

    def __post_init__(self):
        if isinstance(self.dp_axes, str):
            self.dp_axes = (self.dp_axes,)
        else:
            self.dp_axes = tuple(self.dp_axes)
        if self.matmul_strategy not in _MATMUL_STRATEGIES:
            raise ValueError(
                f"matmul_strategy={self.matmul_strategy!r}; "
                f"known: {sorted(_MATMUL_STRATEGIES)}"
            )
        # With pure DP there is no tensor-parallel axis: remember the raw
        # name for SUMMA grid construction but expose tp_axis=None so no
        # sharding rule places anything on it.
        self._tp_axis_raw = self.tp_axis
        if self.pure_dp:
            self.tp_axis = None
        self._mm_cache = None

    # -- mesh geometry -------------------------------------------------------

    @property
    def has_mesh(self) -> bool:
        return self.mesh is not None and not self.mesh.empty

    @property
    def dp(self):
        """The data-parallel PartitionSpec entry (name or tuple of names)."""
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    @property
    def dp_size(self) -> int:
        if not self.has_mesh:
            return 1
        return math.prod(self.mesh.shape[a] for a in self.dp_axes)

    @property
    def tp_size(self) -> int:
        if not self.has_mesh or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    # -- sharding helpers ----------------------------------------------------

    def named(self, *entries) -> NamedSharding:
        """``NamedSharding(mesh, P(*entries))``; requires a mesh."""
        if self.mesh is None:
            raise ValueError("ParallelCtx.named() needs a mesh")
        return NamedSharding(self.mesh, P(*entries))

    def wsc(self, x: jax.Array, *entries) -> jax.Array:
        """with_sharding_constraint under ``P(*entries)``; identity when
        meshless so model code stays single-device clean."""
        if not self.has_mesh:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(*entries))

    # -- static weight sparsity ----------------------------------------------

    def weight_mask(self, shape) -> Any:
        """Block mask registered for a (d_in, d_out) weight shape, if any."""
        if not self.weight_block_masks:
            return None
        return self.weight_block_masks.get(tuple(shape))

    # -- the paper's engine --------------------------------------------------

    def matmul(self) -> Any:
        """Factory: the ``core.api.DistributedMatmul`` realising this ctx's
        matmul strategy on the (dp x tp) mesh slice.

        Cached — SUMMA configuration is static per context, so every FFN
        projection in a scanned stack shares one engine (and therefore
        one shard_map program) per context.
        """
        if self._mm_cache is not None:
            return self._mm_cache
        if not self.has_mesh:
            raise ValueError("matmul_strategy needs a mesh; got mesh=None")
        strategy = _MATMUL_STRATEGIES[self.matmul_strategy]
        if strategy is None:
            raise ValueError("matmul() is not used for the 'xla' strategy")
        if self._tp_axis_raw is None:
            raise ValueError("SUMMA needs a tensor-parallel mesh axis")
        from repro.core.api import DistributedMatmul  # deferred: no cycle

        self._mm_cache = DistributedMatmul(
            mesh=self.mesh,
            row_axis=self.dp,
            col_axis=self._tp_axis_raw,
            strategy=strategy,
        )
        return self._mm_cache

    def plan_projection(
        self, m: int, d_in: int, d_out: int, *, itemsize=4, tune=False,
        stationarity: str = "C", strategy: str | None = None,
        lookahead: int | None = None, comm_mode: str = "broadcast",
        k_blocks: int | None = None,
    ):
        """Pre-build (and cache) the plan for an (m, d_in)x(d_in, d_out)
        projection — call outside jit so traced call paths (scanned
        layers, prefill vs decode shapes) hit the plan cache instead of
        re-deriving the schedule at trace time.  No-op on the xla path.
        ``tune=True`` additionally runs the schedule autotuner (what the
        ``"auto"`` strategy executes), so the simulator search also
        happens outside tracing.  ``stationarity`` forwards to the
        planner (``"auto"`` lets the comm-volume model pick the
        A-/B-/C-stationary schedule, repro.spgemm).  ``strategy`` /
        ``lookahead`` / ``comm_mode`` / ``k_blocks`` pin a previously
        tuned schedule explicitly — the persistent plan service
        (``serve.plan_service``) re-applies stored winners through these
        instead of re-running the tuner.
        """
        if (
            not self.has_mesh
            or self.matmul_strategy == "xla"
            or self.pure_dp
        ):
            return None
        return self.matmul().plan(
            m, d_in, d_out,
            b_mask=self.weight_mask((d_in, d_out)),
            itemsize=itemsize,
            tune=tune,
            stationarity=stationarity,
            strategy=strategy,
            lookahead=lookahead,
            comm_mode=comm_mode,
            k_blocks=k_blocks,
        )
