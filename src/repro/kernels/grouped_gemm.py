"""Grouped (block-diagonal) GEMM Pallas TPU kernel for MoE experts.

MoE expert compute *is* block-sparse matrix multiplication — the paper's
target domain: tokens routed to expert e multiply only W[e], i.e. a
block-diagonal sparsity over the (token-group × expert) grid with
*nonuniform* group sizes (the router decides), exactly the irregular
blocking the paper simulates with random block sizes.

Layout contract (MegaBlocks-style, TPU-adapted): tokens arrive sorted by
expert and padded so every ``bt``-row tile is owned by a single expert;
``tile_expert[t]`` names that expert and is scalar-prefetched so the W
BlockSpec chases it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

__all__ = ["grouped_gemm_kernel", "grouped_gemm_pallas"]


def grouped_gemm_kernel(te_ref, x_ref, w_ref, y_ref, acc_ref, *, k_tiles):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_tiles - 1)
    def _flush():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bt", "bk", "bn", "interpret", "out_dtype")
)
def grouped_gemm_pallas(
    x: jax.Array,  # (T, D) tokens, tile-aligned groups
    w: jax.Array,  # (E, D, F) expert weights
    tile_expert: jax.Array,  # (T // bt,) int32
    *,
    bt: int,
    bk: int,
    bn: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    t, d = x.shape
    e, d2, f = w.shape
    if d != d2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if t % bt or d % bk or f % bn:
        raise ValueError(f"shape must divide tiles ({bt},{bk},{bn})")
    if tile_expert.shape != (t // bt,):
        raise ValueError("tile_expert must have one entry per token tile")
    out_dtype = out_dtype or x.dtype
    k_tiles = d // bk
    grid = (t // bt, f // bn, k_tiles)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda ti, n, k, te: (ti, k)),
            pl.BlockSpec((1, bk, bn), lambda ti, n, k, te: (te[ti], k, n)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda ti, n, k, te: (ti, n)),
        scratch_shapes=[pltpu.VMEM((bt, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(grouped_gemm_kernel, k_tiles=k_tiles),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, f), out_dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tile_expert, x, w)
