"""FlashAttention (forward) Pallas TPU kernel.

Online-softmax tiled attention with causal and sliding-window masking and
GQA head grouping.  Blocks that the mask eliminates entirely are skipped
with ``pl.when`` (no MXU work, no VMEM traffic beyond the prefetch), which
makes causal attention ~2× and sliding-window attention O(S·W) — the same
"skip empty blocks" discipline as the block-sparse matmul kernel.

Used by the serving path for prefill; ref.py::flash_attention_ref is the
oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

__all__ = ["flash_attention_pallas"]

_LANES = 128
_NEG_INF = -1e30


def _fa_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    bq: int,
    bk: int,
    k_steps: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # Static-shape mask reasoning is impossible (qi/ki traced), so the
    # skip is a runtime predicate — cheap, and the backend elides the
    # whole block body.
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1  # some key <= some query
    if window is not None:
        live &= q_start - (k_start + bk - 1) < window

    @pl.when(live)
    def _attend():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        pos_q = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        pos_k = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= pos_q >= pos_k
        if window is not None:
            mask &= pos_q - pos_k < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == k_steps - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, S, Dh)
    k: jax.Array,  # (B, Hkv, S, Dh)
    v: jax.Array,  # (B, Hkv, S, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, dh = q.shape
    _, hkv, sk, _ = k.shape
    if s % bq or sk % bk:
        raise ValueError(f"seq {s}/{sk} must divide blocks ({bq},{bk})")
    if h % hkv:
        raise ValueError(f"q heads {h} must be a multiple of kv heads {hkv}")
    g = h // hkv
    scale_val = float(scale) if scale is not None else 1.0 / float(np.sqrt(dh))
    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * hkv, sk, dh)
    vf = v.reshape(b * hkv, sk, dh)
    k_steps = sk // bk
    grid = (b * h, s // bq, k_steps)

    def kv_index(bh, qi, ki):
        return ((bh // h) * hkv + (bh % h) // g, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel,
            scale=scale_val,
            causal=causal,
            window=window,
            bq=bq,
            bk=bk,
            k_steps=k_steps,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, bk, dh), kv_index),
            pl.BlockSpec((None, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)
