"""Block-sparse matmul (BSMM) Pallas TPU kernel.

The compute payload of the paper's "block-sparse tensor computing": C =
A·B where A carries a block-level sparsity structure.  The block map is a
padded CSR-of-blocks (core.sparsity.BlockCSR) delivered through *scalar
prefetch*, so the kernel's BlockSpec index_maps chase the sparse column
indices and only nonzero A blocks are ever copied into VMEM or multiplied
— FLOPs and HBM traffic scale with the block fill-in, not the dense
shape.

Grid layout: ``(M_blocks, N_blocks, S)`` with ``S`` = max nonzeros per
block row (padded with ``-1`` sentinels).  The S axis is "arbitrary"
(sequential) and accumulates into VMEM scratch; sentinel steps are
masked with ``pl.when`` and their (deduped) loads point at block 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

__all__ = ["bsmm_kernel", "bsmm_pallas"]


def bsmm_kernel(
    cols_ref,  # scalar prefetch: (M_blocks, S) int32, -1 padded
    a_ref,
    b_ref,
    c_ref,
    acc_ref,
    *,
    s_steps: int,
):
    i = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(cols_ref[i, s] >= 0)
    def _accum():
        acc_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(s == s_steps - 1)
    def _flush():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "interpret", "out_dtype"),
)
def bsmm_pallas(
    a: jax.Array,
    b: jax.Array,
    cols: jax.Array,  # (M_blocks, S) int32 padded col map
    *,
    bm: int,
    bk: int,
    bn: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B where A's block-sparsity is given by the padded col map.

    ``a``: (M, K) dense-stored, blocks of (bm, bk); blocks absent from
    ``cols`` are *skipped* (never loaded / multiplied).  ``cols[i, s]`` is
    the s-th nonzero block column of block row i, or -1.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    if m % bm or k % bk or n % bn:
        raise ValueError(f"shape must divide tiles ({bm},{bk},{bn})")
    m_blocks = m // bm
    s_steps = cols.shape[1]
    if cols.shape[0] != m_blocks:
        raise ValueError(
            f"col map rows {cols.shape[0]} != M blocks {m_blocks}"
        )
    out_dtype = out_dtype or a.dtype
    grid = (m_blocks, n // bn, s_steps)

    def a_index(i, j, s, cols_ref):
        kk = jnp.maximum(cols_ref[i, s], 0)  # sentinel -> block 0 (masked)
        return (i, kk)

    def b_index(i, j, s, cols_ref):
        kk = jnp.maximum(cols_ref[i, s], 0)
        return (kk, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), a_index),
            pl.BlockSpec((bk, bn), b_index),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s, cols_ref: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(bsmm_kernel, s_steps=s_steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(cols, a, b)
