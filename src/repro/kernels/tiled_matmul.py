"""Dense tiled matmul Pallas TPU kernel.

The local block-multiply engine of the task-based SUMMA: the intra-node
"tasks" of the paper (TBB threads working on sub-blocks of the local
result, Fig. 3) map onto the Pallas grid — each (i, j) grid cell owns one
C sub-block, the K dimension is the innermost ("arbitrary") grid axis and
accumulates into a VMEM scratch, so different C sub-blocks are independent
exactly like the paper's decomposed rank-k-update tasks.

Block shapes are MXU-aligned (multiples of 128 on the minor dims by
default); fp32 accumulation in VMEM scratch; output cast to the operand
dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import pallas_tpu_compiler_params

__all__ = ["tiled_matmul_kernel", "tiled_matmul_pallas"]

DEFAULT_BM = 256
DEFAULT_BK = 256
DEFAULT_BN = 256


def tiled_matmul_kernel(a_ref, b_ref, c_ref, acc_ref, *, k_tiles: int):
    """One (i, j, k) grid cell: acc += A[i,k] @ B[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_tiles - 1)
    def _flush():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret", "out_dtype")
)
def tiled_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with explicit VMEM tiling. Shapes must divide the tiles."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    if m % bm or k % bk or n % bn:
        raise ValueError(
            f"shape ({m},{k},{n}) must divide tiles ({bm},{bk},{bn}); "
            "use kernels.ops.tiled_matmul for auto-padding"
        )
    out_dtype = out_dtype or a.dtype
    k_tiles = k // bk
    grid = (m // bm, n // bn, k_tiles)
    return pl.pallas_call(
        functools.partial(tiled_matmul_kernel, k_tiles=k_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
