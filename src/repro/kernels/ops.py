"""Public wrappers for the Pallas kernels.

Handle shape padding, tile selection, dtype policy, and backend dispatch:
on TPU the kernels run compiled; on CPU they run in ``interpret=True``
mode (Python-level execution of the kernel body) so every test validates
the *same* kernel code that targets the MXU.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsity import block_csr_from_mask
from repro.kernels import ref
from repro.kernels.bsmm import bsmm_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.grouped_gemm import grouped_gemm_pallas
from repro.kernels.tiled_matmul import tiled_matmul_pallas

__all__ = [
    "tiled_matmul",
    "bsmm",
    "grouped_gemm",
    "ranksparse_matmul",
    "flash_attention",
]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad2(x, mults):
    pads = [(0, -(-d // m) * m - d) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def _pick_tile(dim: int, pref: int) -> int:
    """Largest power-of-two tile <= pref that keeps padding reasonable."""
    t = pref
    while t > 8 and dim % t and dim < t:
        t //= 2
    return max(t, 8)


def tiled_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 256,
    bk: int = 256,
    bn: int = 256,
    accum_dtype=jnp.float32,
    out_dtype=None,
) -> jax.Array:
    """C = A @ B via the tiled Pallas kernel, auto-padded."""
    del accum_dtype  # kernel always accumulates fp32
    m, k = a.shape
    _, n = b.shape
    bm = _pick_tile(m, bm)
    bk = _pick_tile(k, bk)
    bn = _pick_tile(n, bn)
    a_p = _pad2(a, (bm, bk))
    b_p = _pad2(b, (bk, bn))
    c = tiled_matmul_pallas(
        a_p, b_p, bm=bm, bk=bk, bn=bn, out_dtype=out_dtype, interpret=_interpret()
    )
    return c[:m, :n]


def bsmm(
    a: jax.Array,
    b: jax.Array,
    mask: np.ndarray,
    *,
    bn: int = 256,
    out_dtype=None,
) -> jax.Array:
    """Block-sparse C = A @ B; ``mask`` is the (M_blk, K_blk) block mask.

    Block sizes are derived from the mask grid; A's shape must divide the
    mask evenly.  Zero block rows produce zero C rows.
    """
    m, k = a.shape
    _, n = b.shape
    mask = np.asarray(mask, bool)
    mb, kb = mask.shape
    if m % mb or k % kb:
        raise ValueError(f"operand {a.shape} not divisible by mask {mask.shape}")
    bm_sz, bk_sz = m // mb, k // kb
    csr = block_csr_from_mask(mask)
    cols = jnp.asarray(csr.padded_cols(max(csr.max_row_nnz, 1)))
    bn = _pick_tile(n, bn)
    b_p = _pad2(b, (bk_sz, bn))
    c = bsmm_pallas(
        a,
        b_p,
        cols,
        bm=bm_sz,
        bk=bk_sz,
        bn=bn,
        out_dtype=out_dtype,
        interpret=_interpret(),
    )
    return c[:, :n]


def grouped_gemm(
    x: jax.Array,
    w: jax.Array,
    tile_expert: jax.Array,
    *,
    bt: int = 256,
    bk: int = 256,
    bn: int = 256,
    out_dtype=None,
) -> jax.Array:
    """Tile-aligned grouped GEMM (MoE expert compute)."""
    t, d = x.shape
    e, _, f = w.shape
    if t % bt:
        raise ValueError(f"token count {t} must divide tile {bt}")
    bk = _pick_tile(d, bk)
    bn = _pick_tile(f, bn)
    x_p = _pad2(x, (bt, bk))
    w_p = jnp.pad(
        w,
        (
            (0, 0),
            (0, x_p.shape[1] - d),
            (0, -(-f // bn) * bn - f),
        ),
    )
    y = grouped_gemm_pallas(
        x_p,
        w_p,
        tile_expert,
        bt=bt,
        bk=bk,
        bn=bn,
        out_dtype=out_dtype,
        interpret=_interpret(),
    )
    return y[:, :f]


def ranksparse_matmul(
    a_ranks,
    b: jax.Array,
    *,
    bn: int = 256,
    out_dtype=None,
) -> jax.Array:
    """Local C = A @ B with A block-rank-sparse (a ``RankCSR``).

    The ragged per-rank stage (every stored block's ``V[s] @ B[k_s]``,
    blocks of different panels and ranks interleaved) is ONE grouped-gemm
    kernel launch: stacked V rows are the tokens, each ``r_pad``-row tile
    chases its block's K panel through scalar prefetch (``tile_expert`` =
    the CSR column index), exactly the MegaBlocks layout of
    ``grouped_gemm_pallas``.  Stage 2 applies the U factors per block and
    segment-sums into C's block rows.  FLOPs scale with ``nnz · r_pad``,
    not the dense shape.
    """
    k, n = b.shape
    bm_sz, bk_sz = a_ranks.bm, a_ranks.bk
    csr = a_ranks.csr
    if k != csr.n_blocks * bk_sz:
        raise ValueError(
            f"B rows {k} != rank structure K {csr.n_blocks * bk_sz}"
        )
    out_dtype = out_dtype or b.dtype
    m = csr.m_blocks * bm_sz
    if csr.nnz == 0:
        return jnp.zeros((m, n), out_dtype)
    r_pad = a_ranks.r_pad
    # stage 1: y[s] = V[s] @ B_panel[col_idx[s]] for every stored block
    v_tokens = jnp.asarray(a_ranks.v.reshape(csr.nnz * r_pad, bk_sz))
    b_panels = b.reshape(csr.n_blocks, bk_sz, n)
    bn = _pick_tile(n, bn)
    b_p = jnp.pad(b_panels, ((0, 0), (0, 0), (0, -(-n // bn) * bn - n)))
    y = grouped_gemm_pallas(
        v_tokens,
        b_p,
        jnp.asarray(csr.col_idx),
        bt=r_pad,
        bk=bk_sz,
        bn=bn,
        out_dtype=jnp.float32,
        interpret=_interpret(),
    )[:, :n]
    # stage 2: per-block U application + segment sum into C block rows
    y3 = y.reshape(csr.nnz, r_pad, n)
    partials = jnp.einsum(
        "sbr,srn->sbn", jnp.asarray(a_ranks.u), y3,
        preferred_element_type=jnp.float32,
    )
    row_ids = jnp.asarray(
        np.repeat(np.arange(csr.m_blocks), csr.row_lengths())
    )
    c_blocks = jax.ops.segment_sum(
        partials, row_ids, num_segments=csr.m_blocks
    )
    return c_blocks.reshape(m, n).astype(out_dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 256,
    bk: int = 256,
) -> jax.Array:
    """Tiled online-softmax attention (forward)."""
    s = q.shape[2]
    bq = _pick_tile(s, bq)
    bk = _pick_tile(k.shape[2], bk)
    if s % bq or k.shape[2] % bk:
        # fall back to padded ref for awkward shapes (rare; serving pads)
        return ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, scale=scale
        )
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        scale=scale,
        bq=bq,
        bk=bk,
        interpret=_interpret(),
    )
