"""Per-block-shape kernel autotune cache (the DBCSR ``libsmm_acc`` idea).

Nonuniform tilings hand the local engines a zoo of block shapes, and one
generic kernel choice (``jnp.matmul`` vs the tiled Pallas kernel vs the
block-sparse/grouped/factored routes) cannot win everywhere — DBCSR
(arXiv:1910.13555) ships a per-block-shape tuned kernel library for
exactly this reason.  This module is the runtime analogue:

* shapes are coarsened into **buckets** ``(bm, bk, bn, rank, dtype)``
  (power-of-two rounding), so one measurement covers a neighborhood;
* :meth:`KernelAutotuner.tune` benchmarks every applicable route on a
  representative problem of the bucket shape and records the winner and
  the per-route times;
* winners persist to JSON (:meth:`save` / :meth:`load`) the way
  ``serve.engine.warm_matmul_plans`` persists schedule choices, and the
  ``REPRO_AUTOTUNE_CACHE`` env var points the process singleton at a
  cache file;
* consumers (``core.summa._local_dot``, ``core.api.NonuniformMatmul``)
  only ever call :meth:`lookup` / :meth:`winner` — **lookup never
  benchmarks**, so consults inside jit tracing are free and an empty or
  disabled cache (``REPRO_AUTOTUNE=0``) leaves every execution path and
  executable-cache key bitwise identical to the pre-autotune behavior
  (:func:`cache_fingerprint` returns ``""`` exactly then).

Routes benchmarked per bucket:

``xla``
    ``jnp.matmul`` — the generic baseline; always a candidate, so a
    recorded winner is by construction never slower than the generic
    kernel on its own bucket (measured on the tuning machine).
``pallas``
    ``kernels.ops.tiled_matmul`` over a small tile sweep; the winning
    ``(bm, bk, bn)`` tile triple is recorded as ``tiles``.
``bsmm``
    the block-sparse kernel with a full mask — prices the CSR indirection
    so masked plans know when the structured kernel stops paying.
``grouped``
    the MegaBlocks-layout grouped GEMM with a single expert — the
    rank-sparse stage-1 shape (``kernels.ops.ranksparse_matmul``).
``factored``
    only when ``rank > 0``: the two-stage ``U @ (V @ B)`` skinny-gemm
    pipeline at the bucket's rank.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time

import numpy as np

__all__ = [
    "KernelAutotuner",
    "bucket_key",
    "autotune_cache",
    "set_autotune_cache",
    "cache_fingerprint",
    "autotune_enabled",
    "preferred_tile",
]

#: every route the tuner knows; ``factored`` only applies at rank > 0.
ROUTES = ("xla", "pallas", "bsmm", "grouped", "factored")

#: pallas tile sweep per bucket (clamped to the bucket shape).
TILE_CANDIDATES = (128, 256, 512)


def _pow2_bucket(x: int, lo: int = 8, hi: int = 4096) -> int:
    """Round up to the next power of two, clamped to [lo, hi]."""
    x = int(max(x, 1))
    b = 1 << (x - 1).bit_length()
    return int(min(max(b, lo), hi))


def bucket_key(
    m: int, k: int, n: int, *, rank: int = 0, dtype="float32"
) -> tuple:
    """Coarsen a local-gemm shape into its autotune bucket.

    ``rank=0`` means dense (no factored structure); positive ranks bucket
    to powers of two with a floor of 8 so nearby ranks share entries.
    """
    rb = _pow2_bucket(rank, lo=8, hi=1024) if rank > 0 else 0
    return (
        _pow2_bucket(m),
        _pow2_bucket(k),
        _pow2_bucket(n),
        rb,
        str(np.dtype(dtype)),
    )


def _key_str(key: tuple) -> str:
    m, k, n, r, dt = key
    return f"{m}x{k}x{n}xr{r}x{dt}"


def _key_parse(s: str) -> tuple:
    m, k, n, r, dt = s.split("x", 4)
    return (int(m), int(k), int(n), int(r[1:]), dt)


def autotune_enabled() -> bool:
    """``REPRO_AUTOTUNE=0`` disables every consult (bitwise-off switch)."""
    return os.environ.get("REPRO_AUTOTUNE", "1") != "0"


def _time_call(fn, *args, repeats: int = 3) -> float:
    """Best-of-``repeats`` wall time of ``fn(*args)`` (post-compile)."""
    import jax

    jax.block_until_ready(fn(*args))  # compile outside the timed region
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


@dataclasses.dataclass
class KernelAutotuner:
    """Bucketed route winners; see the module docstring for semantics."""

    table: dict = dataclasses.field(default_factory=dict)

    # -- consult (lookup-only: safe inside jit tracing) ----------------------

    def lookup(
        self, m: int, k: int, n: int, *, rank: int = 0, dtype="float32"
    ) -> dict | None:
        """The bucket's entry, or ``None`` (miss / disabled). Never tunes."""
        if not autotune_enabled():
            return None
        return self.table.get(bucket_key(m, k, n, rank=rank, dtype=dtype))

    def winner(
        self, m: int, k: int, n: int, *, rank: int = 0, dtype="float32"
    ) -> str | None:
        entry = self.lookup(m, k, n, rank=rank, dtype=dtype)
        return entry["winner"] if entry else None

    def fingerprint(self) -> str:
        """Content digest of the table; ``""`` when empty or disabled.

        Consumers append a non-empty fingerprint to their executable
        cache keys, so flipping the cache never aliases two different
        traced programs — and an empty/disabled cache leaves the keys
        (and therefore plan-digest behavior) bitwise unchanged.
        """
        if not autotune_enabled() or not self.table:
            return ""
        h = hashlib.sha1()
        for k in sorted(self.table, key=_key_str):
            e = self.table[k]
            h.update(_key_str(k).encode())
            h.update(str(e.get("winner")).encode())
            h.update(str(e.get("tiles")).encode())
        return h.hexdigest()[:16]

    # -- tuning (benchmarks: never call inside tracing) ----------------------

    def _routes(self, key: tuple):
        """Build ``{route: (callable, args)}`` for a bucket; jit-wrapped.

        The ``pallas`` route is parameterized by its tile triple, so it is
        returned as ``(tiles -> callable, args)`` and swept by ``tune``.
        """
        import jax
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        bm, bk, bn, rb, dt = key
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((bm, bk)), dtype=dt)
        b = jnp.asarray(rng.standard_normal((bk, bn)), dtype=dt)
        routes = {"xla": (jax.jit(jnp.matmul), (a, b))}

        def pallas_fn(tiles):
            return jax.jit(
                lambda x, y, _t=tiles: kops.tiled_matmul(
                    x, y, bm=_t[0], bk=_t[1], bn=_t[2]
                )
            )

        routes["pallas"] = (pallas_fn, (a, b))

        blk = min(bm, bk, 128)
        mask = np.ones((bm // blk, bk // blk), dtype=bool)
        routes["bsmm"] = (
            jax.jit(lambda x, y: kops.bsmm(x, y, mask)), (a, b)
        )

        bt = min(bm, 256)  # bm is a power of two, so bt divides it
        te = jnp.zeros((bm // bt,), jnp.int32)
        routes["grouped"] = (
            jax.jit(
                lambda x, y: kops.grouped_gemm(x, y[None], te, bt=bt)
            ),
            (a, b),
        )

        if rb > 0:
            u = jnp.asarray(rng.standard_normal((bm, rb)), dtype=dt)
            v = jnp.asarray(rng.standard_normal((rb, bk)), dtype=dt)
            routes["factored"] = (
                jax.jit(lambda uu, vv, y: uu @ (vv @ y)), (u, v, b)
            )
        return routes

    def tune(
        self,
        m: int,
        k: int,
        n: int,
        *,
        rank: int = 0,
        dtype="float32",
        repeats: int = 3,
        routes: tuple[str, ...] | None = None,
    ) -> dict:
        """Benchmark the routes on this shape's bucket and record the winner.

        Idempotent per bucket (re-tuning overwrites).  ``routes`` limits
        the sweep (e.g. ``("xla", "pallas")`` on hosts where the
        interpret-mode structured kernels are too slow to time).
        Returns the entry: ``{"winner", "times_s", "tiles"}``.
        """
        key = bucket_key(m, k, n, rank=rank, dtype=dtype)
        bm, bk, bn = key[:3]
        built = self._routes(key)
        times: dict[str, float] = {}
        tiles = None
        for name, (fn, args) in built.items():
            if routes is not None and name not in routes:
                continue
            try:
                if name == "pallas":
                    best_t = float("inf")
                    for t in TILE_CANDIDATES:
                        cand = (min(t, bm), min(t, bk), min(t, bn))
                        tt = _time_call(fn(cand), *args, repeats=repeats)
                        if tt < best_t:
                            best_t, tiles = tt, cand
                        if cand == (bm, bk, bn):
                            break  # larger candidates clamp to the same tiling
                    times[name] = best_t
                else:
                    times[name] = _time_call(fn, *args, repeats=repeats)
            except Exception:  # route inapplicable on this backend/shape
                continue
        if not times:
            raise ValueError(f"no route could be timed for bucket {key}")
        winner = min(times, key=times.get)
        entry = {
            "winner": winner,
            "times_s": {r: float(t) for r, t in times.items()},
            "tiles": list(tiles) if tiles else None,
        }
        self.table[key] = entry
        return entry

    # -- persistence (the ``warm_matmul_plans`` analogue) --------------------

    def save(self, path: str) -> None:
        data = {
            "version": 1,
            "entries": {_key_str(k): v for k, v in self.table.items()},
        }
        with open(path, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)

    def load(self, path: str, *, merge: bool = True) -> int:
        """Load entries from ``path``; returns how many were installed.

        ``merge=True`` (default) keeps existing in-memory entries on key
        collisions losing to the file — the file is the persisted truth.
        """
        with open(path) as f:
            data = json.load(f)
        entries = data.get("entries", {})
        if not merge:
            self.table.clear()
        for ks, e in entries.items():
            self.table[_key_parse(ks)] = e
        return len(entries)


_CACHE: KernelAutotuner | None = None


def autotune_cache() -> KernelAutotuner:
    """The process singleton; seeded from ``REPRO_AUTOTUNE_CACHE`` if the
    env var names an existing JSON file (the CI warm-restore path)."""
    global _CACHE
    if _CACHE is None:
        _CACHE = KernelAutotuner()
        path = os.environ.get("REPRO_AUTOTUNE_CACHE", "")
        if path and os.path.exists(path):
            _CACHE.load(path)
    return _CACHE


def set_autotune_cache(cache: KernelAutotuner | None) -> None:
    """Swap the process singleton (tests; ``None`` resets to empty-lazy)."""
    global _CACHE
    _CACHE = cache


def cache_fingerprint() -> str:
    """Singleton fingerprint without forcing env-file loading semantics on
    callers; ``""`` when the cache is empty or disabled."""
    return autotune_cache().fingerprint()


def preferred_tile(
    max_block: int, *, dtype="float32", candidates=TILE_CANDIDATES
) -> int | None:
    """Physical tile choice for ``NonuniformMatmul`` bucketing.

    Scans square ``(c, c, c)`` buckets the cache has measured and returns
    the candidate whose winning route is fastest, ``None`` on a cold
    cache (caller falls back to its static default).  ``max_block`` caps
    the tile at the largest logical block so bucketization stays exact.
    """
    cache = autotune_cache()
    best_c, best_t = None, float("inf")
    for c in candidates:
        if c > _pow2_bucket(max_block, lo=8):
            continue
        entry = cache.lookup(c, c, c, dtype=dtype)
        if not entry:
            continue
        t = entry["times_s"][entry["winner"]]
        # normalize by the bucket's flops so sizes are comparable
        t_norm = t / float(c) ** 3
        if t_norm < best_t:
            best_c, best_t = c, t_norm
    return best_c
