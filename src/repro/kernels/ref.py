"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "matmul_ref",
    "bsmm_ref",
    "grouped_gemm_ref",
    "flash_attention_ref",
]


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def bsmm_ref(
    a: jax.Array,
    b: jax.Array,
    mask: np.ndarray,  # (M_blocks, K_blocks) bool
    out_dtype=None,
) -> jax.Array:
    """Zero A's masked blocks, then dense matmul."""
    m, k = a.shape
    mb, kb = np.asarray(mask).shape
    fine = np.repeat(np.repeat(np.asarray(mask, bool), m // mb, 0), k // kb, 1)
    a_z = jnp.where(jnp.asarray(fine), a, jnp.zeros((), a.dtype))
    return matmul_ref(a_z, b, out_dtype)


def grouped_gemm_ref(
    x: jax.Array,  # (T, D)
    w: jax.Array,  # (E, D, F)
    tile_expert: jax.Array,  # (T // bt,) int32
    bt: int,
    out_dtype=None,
) -> jax.Array:
    """Per-tile expert matmul: y[tile] = x[tile] @ w[tile_expert[tile]]."""
    out_dtype = out_dtype or x.dtype
    t, d = x.shape
    xt = x.reshape(t // bt, bt, d)
    wt = jnp.take(w, tile_expert, axis=0)  # (T//bt, D, F)
    y = jnp.einsum("tbd,tdf->tbf", xt, wt, preferred_element_type=jnp.float32)
    return y.reshape(t, -1).astype(out_dtype)


def flash_attention_ref(
    q: jax.Array,  # (B, H, S, Dh)
    k: jax.Array,  # (B, Hkv, S, Dh)
    v: jax.Array,  # (B, Hkv, S, Dh)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    out_dtype=None,
) -> jax.Array:
    """Reference attention with GQA, causal and sliding-window masks."""
    out_dtype = out_dtype or q.dtype
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, hkv, g, s, dh)
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qg, kf)
    pos_q = jnp.arange(s)[:, None]
    pos_k = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos_q >= pos_k
    if window is not None:
        mask &= pos_q - pos_k < window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", probs, vf)
    return out.reshape(b, h, s, dh).astype(out_dtype)
