"""End-to-end training example: a ~25M-param LLaMA-style model for a few
hundred steps on the deterministic synthetic stream.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

(The assigned full configs are exercised via the 512-chip dry-run; this
example is sized for this container's single CPU core.  On a real pod,
point --arch at any config in src/repro/configs.)

Shows: checkpointing every 50 steps, deterministic resume, loss curve.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    args = ap.parse_args()

    # ~25M params: 6 layers x d512 (mini-llama geometry, vocab 8192 via
    # smoke config scaling isn't exposed on the CLI, so we use the arch
    # registry's smoke config scaled through seq/batch instead).
    losses = train_main(
        [
            "--arch", "llama3.2-1b", "--smoke",
            "--steps", str(args.steps),
            "--global-batch", "8",
            "--seq", "256",
            "--microbatches", "2",
            "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "50",
            "--log-every", "20",
        ]
    )
    n = len(losses)
    print("\nloss curve (every ~20 steps):")
    for i in range(0, n, max(n // 15, 1)):
        bar = "#" * int(max(losses[i], 0) / max(losses[0], 1e-9) * 40)
        print(f"  step {i + 1:4d}  {losses[i]:8.4f}  {bar}")
    assert losses[-1] < losses[0], "training must reduce loss"
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} over {n} steps")


if __name__ == "__main__":
    main()
