"""Batched serving example: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python examples/serve_batch.py [--arch mixtral-8x7b]

Runs the smoke-size config of the chosen arch (including MoE and hybrid
recurrent archs — each uses its own cache kind: KV ring buffers for
sliding-window attention, O(1) recurrent state for RG-LRU/xLSTM).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    serve_main(
        [
            "--arch", args.arch, "--smoke",
            "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len),
            "--gen", str(args.gen),
        ]
    )


if __name__ == "__main__":
    main()
