"""Block-sparse tensor computing: the paper's target workload.

    PYTHONPATH=src python examples/blocksparse_contraction.py

1. Block-sparse C = A.B with distance-decay structure: dead panels are
   skipped at trace time (communication AND compute scale with fill).
2. Nonuniformly blocked matrices (physics-driven blocking) through the
   bucketized uniform-tile engine.
3. A block-sparse *tensor* contraction T[abd] = sum_c X[abc] Y[cd]
   through the einsum front-end (repro.core.contract): modes merge
   block-contiguously, masks matricize exactly, and the product runs
   through the same MatmulPlan engine.
4. A chained contraction D = (A.B).C scheduled *jointly*: the union
   task graph lets step 2's broadcasts overlap step 1's tail (the
   paper's "no explicit internodal synchronization lets multiple MMs
   overlap"), the tuner picks per-step windows, and execution honors
   them.  The inferred intermediate mask propagates through the chain.
"""
import os
import sys

sys.path.insert(0, "src")

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import analyze_hlo
from repro.core import (
    BlockSparseTensor,
    DistributedMatmul,
    NonuniformMatmul,
    decay_block_mask,
    nonuniform_tiling,
    reference_blocksparse_matmul,
    reference_matmul,
)
from repro.core.summa import SummaConfig, summa_blocksparse_matmul, summa_matmul
from repro.launch.mesh import make_mesh


def main():
    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)

    # --- 1. block-sparse with distance decay --------------------------------
    n, kb = 1024, 16
    a = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    am = decay_block_mask(kb, kb, decay=0.5, threshold=5e-2)
    bm = decay_block_mask(kb, kb, decay=0.5, threshold=5e-2)
    # compact operator support: the last quarter of the inner dimension is
    # screened out entirely -> those SUMMA panels are dead (never
    # broadcast, never multiplied)
    am[:, 3 * kb // 4 :] = False
    bm[3 * kb // 4 :, :] = False
    cfg = SummaConfig(mesh=mesh, strategy="taskbased", k_blocks=kb)
    got = np.asarray(summa_blocksparse_matmul(a, b, am, bm, cfg))
    want = np.asarray(reference_blocksparse_matmul(a, b, am, bm))
    fill = am.mean()
    print(f"decay mask fill={fill:.2f}  max|err|={np.abs(got - want).max():.2e}")

    dense_txt = (
        jax.jit(lambda a, b: summa_matmul(a, b, cfg)).lower(a, b).compile().as_text()
    )
    sparse_txt = (
        jax.jit(lambda a, b: summa_blocksparse_matmul(a, b, am, bm, cfg))
        .lower(a, b)
        .compile()
        .as_text()
    )
    cd, cs = analyze_hlo(dense_txt), analyze_hlo(sparse_txt)
    print(
        f"collective bytes/device: dense {cd.coll_bytes:.3g} -> "
        f"sparse {cs.coll_bytes:.3g} "
        f"({cs.coll_bytes / max(cd.coll_bytes, 1):.0%})"
    )

    # --- 2. nonuniform (physics-driven) blocking -----------------------------
    rt = nonuniform_tiling(1000, 12, seed=1)
    it = nonuniform_tiling(1200, 10, seed=2)
    ct = nonuniform_tiling(900, 9, seed=3)
    a2 = jnp.asarray(rng.normal(size=(1000, 1200)), jnp.float32)
    b2 = jnp.asarray(rng.normal(size=(1200, 900)), jnp.float32)
    nmm = NonuniformMatmul(
        DistributedMatmul(mesh, strategy="taskbased"), rt, it, ct, tile=64
    )
    got2 = np.asarray(nmm(a2, b2))
    want2 = np.asarray(reference_matmul(a2, b2))
    print(
        f"nonuniform blocks {rt.sizes[:4]}...  "
        f"padding waste {nmm.padding_waste}  "
        f"max|err|={np.abs(got2 - want2).max():.2e}"
    )

    # --- 3. block-sparse tensor contraction T[abd] = sum_c X[abc] Y[cd] ------
    mm = DistributedMatmul(mesh, strategy="taskbased")
    x3 = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(8, 64, 512)), jnp.float32),
        block_shape=(4, 16, 32),
        mask=rng.random((2, 4, 16)) < 0.5,
    )
    y3 = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(512, 384)), jnp.float32),
        block_shape=(32, 32),
        mask=decay_block_mask(16, 12, decay=0.4, threshold=5e-2),
    )
    t3 = mm.contract("abc,cd->abd", x3, y3)
    ref3 = np.einsum(
        "abc,cd->abd",
        x3.to_dense().astype(np.float64),
        y3.to_dense().astype(np.float64),
    )
    print(
        f"tensor contraction abc,cd->abd  operand fills "
        f"{x3.fill():.2f}/{y3.fill():.2f} -> out fill {t3.fill():.2f}  "
        f"max|err|={np.abs(np.asarray(t3.data) - ref3).max():.2e}"
    )

    # --- 4. chained contraction D = (A.B).C, jointly scheduled ---------------
    am2 = decay_block_mask(kb, kb, decay=0.5, threshold=5e-2)
    xc = BlockSparseTensor.from_dense(a, block_shape=(n // kb, n // kb), mask=am2)
    yc = BlockSparseTensor.from_dense(b, block_shape=(n // kb, n // kb), mask=am2)
    zc = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(n, n)), jnp.float32),
        block_shape=(n // kb, n // kb),
    )
    d, report = mm.contract_chain(
        [("ab,bc->ac", xc, yc), ("ab,bc->ac", zc)], tune=True
    )
    want4 = (
        xc.to_dense().astype(np.float64) @ yc.to_dense().astype(np.float64)
    ) @ np.asarray(zc.data, np.float64)
    print(
        f"chained contraction (A.B).C  max|err|="
        f"{np.abs(np.asarray(d.data) - want4).max():.2e}"
    )
    print(
        f"  joint schedule {report['joint_makespan_s']*1e6:.1f}us vs "
        f"sequential {report['sequential_makespan_s']*1e6:.1f}us "
        f"(x{report['speedup_vs_sequential']:.2f}, per-step "
        f"I={report['lookaheads']}); intermediate mask propagated, "
        f"D fill {d.fill():.2f}"
    )


if __name__ == "__main__":
    main()
