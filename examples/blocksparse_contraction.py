"""Block-sparse tensor computing: the paper's target workload.

    PYTHONPATH=src python examples/blocksparse_contraction.py

1. Block-sparse C = A.B with distance-decay structure: dead panels are
   skipped at trace time (communication AND compute scale with fill).
2. Nonuniformly blocked matrices (physics-driven blocking) through the
   bucketized uniform-tile engine.
3. A chained contraction D = (A.B).C — two SUMMA multiplications in one
   jitted program, schedulable jointly (the paper's "no global sync
   lets multiple MMs overlap").
"""
import os
import sys

sys.path.insert(0, "src")

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import analyze_hlo
from repro.core import (
    DistributedMatmul,
    NonuniformMatmul,
    decay_block_mask,
    nonuniform_tiling,
    reference_blocksparse_matmul,
    reference_matmul,
)
from repro.core.summa import SummaConfig, summa_blocksparse_matmul, summa_matmul
from repro.launch.mesh import make_mesh


def main():
    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)

    # --- 1. block-sparse with distance decay --------------------------------
    n, kb = 1024, 16
    a = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    am = decay_block_mask(kb, kb, decay=0.5, threshold=5e-2)
    bm = decay_block_mask(kb, kb, decay=0.5, threshold=5e-2)
    # compact operator support: the last quarter of the inner dimension is
    # screened out entirely -> those SUMMA panels are dead (never
    # broadcast, never multiplied)
    am[:, 3 * kb // 4 :] = False
    bm[3 * kb // 4 :, :] = False
    cfg = SummaConfig(mesh=mesh, strategy="taskbased", k_blocks=kb)
    got = np.asarray(summa_blocksparse_matmul(a, b, am, bm, cfg))
    want = np.asarray(reference_blocksparse_matmul(a, b, am, bm))
    fill = am.mean()
    print(f"decay mask fill={fill:.2f}  max|err|={np.abs(got - want).max():.2e}")

    dense_txt = (
        jax.jit(lambda a, b: summa_matmul(a, b, cfg)).lower(a, b).compile().as_text()
    )
    sparse_txt = (
        jax.jit(lambda a, b: summa_blocksparse_matmul(a, b, am, bm, cfg))
        .lower(a, b)
        .compile()
        .as_text()
    )
    cd, cs = analyze_hlo(dense_txt), analyze_hlo(sparse_txt)
    print(
        f"collective bytes/device: dense {cd.coll_bytes:.3g} -> "
        f"sparse {cs.coll_bytes:.3g} "
        f"({cs.coll_bytes / max(cd.coll_bytes, 1):.0%})"
    )

    # --- 2. nonuniform (physics-driven) blocking -----------------------------
    rt = nonuniform_tiling(1000, 12, seed=1)
    it = nonuniform_tiling(1200, 10, seed=2)
    ct = nonuniform_tiling(900, 9, seed=3)
    a2 = jnp.asarray(rng.normal(size=(1000, 1200)), jnp.float32)
    b2 = jnp.asarray(rng.normal(size=(1200, 900)), jnp.float32)
    nmm = NonuniformMatmul(
        DistributedMatmul(mesh, strategy="taskbased"), rt, it, ct, tile=64
    )
    got2 = np.asarray(nmm(a2, b2))
    want2 = np.asarray(reference_matmul(a2, b2))
    print(
        f"nonuniform blocks {rt.sizes[:4]}...  "
        f"padding waste {nmm.padding_waste}  "
        f"max|err|={np.abs(got2 - want2).max():.2e}"
    )

    # --- 3. chained contraction D = (A.B).C ----------------------------------
    c = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)

    @jax.jit
    def chain(a, b, c):
        ab = summa_matmul(a, b, cfg)
        return summa_matmul(ab, c, cfg)

    got3 = np.asarray(chain(a, b, c))
    want3 = np.asarray(reference_matmul(jnp.asarray(want := a @ b), c))
    print(f"chained contraction max|err|={np.abs(got3 - np.asarray(want3)).max():.2e}")


if __name__ == "__main__":
    main()
