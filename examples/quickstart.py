"""Quickstart: task-based SUMMA in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's algorithm family on an emulated 2x4 mesh:
procedural baseline, multiple-issue task-based SUMMA (Eq. 1 lookahead),
and the all-gather extreme — all bit-compatible with the dense oracle.
"""
import os
import sys

sys.path.insert(0, "src")

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np

from repro.core import DistributedMatmul, multi_issue_limit, reference_matmul
from repro.launch.mesh import make_mesh


def main():
    mesh = make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)}")

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(512, 1024)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1024, 768)), jnp.float32)
    want = np.asarray(reference_matmul(a, b))

    # paper Eq. (1): how many SUMMA iterations are in flight
    k_steps = 8
    print(
        f"multiple-issue limit I(P_row=2, P_col=4, K={k_steps}) = "
        f"{multi_issue_limit(2, 4, k_steps)}"
    )

    for strategy in ("procedural", "taskbased", "allgather"):
        mm = DistributedMatmul(mesh, strategy=strategy, k_blocks=k_steps)
        got = np.asarray(mm(a, b))
        err = np.abs(got - want).max()
        print(f"{strategy:11s}: max |err| = {err:.2e}")

    # over-decomposition: more K panels -> finer pipeline slots
    for kb in (4, 8, 16):
        mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=kb)
        got = np.asarray(mm(a, b))
        print(f"k_blocks={kb:3d}: max |err| = {np.abs(got - want).max():.2e}")


if __name__ == "__main__":
    main()
