"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from results/.

    PYTHONPATH=src python scripts/make_tables.py [--results results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "recurrentgemma-9b", "xlstm-1.3b", "hubert-xlarge", "llama3.2-1b",
    "gemma-2b", "qwen2.5-32b", "command-r-35b", "mixtral-8x7b",
    "kimi-k2-1t-a32b", "qwen2-vl-72b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.2f}"
    return f"{x:.4f}"


def load(results_dir, pod="1pod"):
    cells = {}
    for f in glob.glob(os.path.join(results_dir, f"*__{pod}.json")):
        r = json.load(open(f))
        cells[(r["arch"], r["shape"])] = r
    return cells


def roofline_table(cells):
    print("| arch | shape | compute s | memory s | collective s | dominant |"
          " bound s | useful | frac-of-roofline |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = cells.get((a, s))
            if r is None:
                print(f"| {a} | {s} | - | - | - | missing | - | - | - |")
                continue
            if r["status"] != "ok":
                print(f"| {a} | {s} | — | — | — | {r['status']} | — | — | — |")
                continue
            rf = r["roofline"]
            frac = rf["compute_s"] / rf["bound_s"] if rf["bound_s"] else 0
            print(
                f"| {a} | {s} | {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
                f"| {fmt_s(rf['collective_s'])} | **{rf['dominant']}** "
                f"| {fmt_s(rf['bound_s'])} | {rf['useful_ratio']:.2f} "
                f"| {frac:.3f} |"
            )


def dryrun_table(cells1, cells2):
    print("| arch | shape | 16x16 compile | bytes/dev (args+temp) "
          "| 2x16x16 compile | collectives (AG/AR/RS/A2A/CP counts) |")
    print("|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = cells1.get((a, s))
            r2 = cells2.get((a, s))
            if r1 is None or r1["status"] != "ok":
                status = r1["status"] if r1 else "missing"
                print(f"| {a} | {s} | {status} | — | — | — |")
                continue
            mem = r1.get("memory_analysis", {})
            gb = (
                mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
            ) / 1e9
            cc = r1.get("collective_counts", {})
            counts = "/".join(
                str(int(cc.get(k, 0)))
                for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")
            )
            c2 = r2["compile_s"] if r2 and r2["status"] == "ok" else "—"
            print(
                f"| {a} | {s} | {r1['compile_s']}s | {gb:.2f} GB "
                f"| {c2}s | {counts} |"
            )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun", "perf"])
    ap.add_argument("--perf-dir", default="results/perf")
    args = ap.parse_args()
    c1 = load(args.results, "1pod")
    if args.table == "roofline":
        roofline_table(c1)
    elif args.table == "dryrun":
        c2 = load(args.results, "2pod")
        dryrun_table(c1, c2)
    else:
        for f in sorted(glob.glob(os.path.join(args.perf_dir, "*.json"))):
            r = json.load(open(f))
            if r["status"] != "ok":
                print(f"{os.path.basename(f)}: {r['status']}")
                continue
            rf = r["roofline"]
            print(
                f"{os.path.basename(f)[:-5]}: compute={fmt_s(rf['compute_s'])} "
                f"memory={fmt_s(rf['memory_s'])} coll={fmt_s(rf['collective_s'])} "
                f"dom={rf['dominant']} bound={fmt_s(rf['bound_s'])} "
                f"useful={rf['useful_ratio']:.2f}"
            )


if __name__ == "__main__":
    main()
