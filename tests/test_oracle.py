"""Differential-oracle harness: every strategy x structure x grid vs NumPy.

The systematic cross-strategy correctness gate: one parametrized sweep
running every execution route (procedural / taskbased / allgather / ring
/ auto) against every structure family the planner absorbs (dense,
random, banded, decay, one-sided, rank-sparse) on a 1x1 grid in-process
and on real 2x2 / 2x4 meshes in subprocesses, all against a float64
NumPy reference with one shared tolerance (tests/conftest.py holds the
case builders).  Also pins the rank-cost acceptance claims (plan FLOPs
scale with average block rank) and the sparsity-generator bugfixes.
"""
import numpy as np
import pytest

from conftest import (
    CONTRACT_SPECS,
    CONTRACT_SWEEP_CODE,
    ORACLE_FAMILIES,
    ORACLE_STRATEGIES,
    ORACLE_SWEEP_CODE,
    SPGEMM_COMM_MODES,
    SPGEMM_FAMILIES,
    check_case,
    check_contract_case,
    contract_case,
    oracle_case,
    run_contract,
    run_spgemm,
    run_strategy,
    spgemm_case,
)
from repro.core import (
    DistributedMatmul,
    decay_block_mask,
    decay_rank_map,
    plan_matmul,
    random_block_mask,
    synthesize_rank_csr,
)
from repro.core.summa import SummaConfig
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    def __init__(self, sizes):
        self.shape = sizes


def _grid_cfg(p_row, p_col, **kw):
    return SummaConfig(
        mesh=FakeMesh({"data": p_row, "model": p_col}),
        row_axis="data",
        col_axis="model",
        **kw,
    )


# ---------------------------------------------------------------------------
# 1x1 grid: full strategy x family cross, in-process
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ORACLE_STRATEGIES)
@pytest.mark.parametrize("family", ORACLE_FAMILIES)
def test_oracle_1x1(family, strategy):
    mesh = make_host_mesh(1, 1)
    case = oracle_case(family, seed=3)
    got = run_strategy(case, mesh, strategy)
    check_case(case, got, f"{family}/{strategy}/1x1")


def test_oracle_pallas_rank_kernel_1x1():
    """The grouped-gemm rank executor and the local grouped-gemm kernel
    agree with the densify oracle.  Blocks are 32x32 with r_pad=8 so the
    factor width sits *below* the comm crossover (r* = 16) and the
    grouped stage actually runs (small blocks would densify instead)."""
    import jax.numpy as jnp

    from repro.core import reference_ranksparse_matmul, synthesize_rank_csr
    from repro.kernels import ops as kops

    mesh = make_host_mesh(1, 1)
    rank_map = decay_rank_map(4, 4, 32, 32, max_rank=8, decay=0.8)
    rcsr = synthesize_rank_csr(rank_map, seed=5)
    assert rcsr.r_pad == 8
    rng = np.random.default_rng(5)
    b = jnp.asarray(rng.normal(size=(128, 96)), jnp.float32)
    want = np.asarray(reference_ranksparse_matmul(rcsr, b))
    mm = DistributedMatmul(mesh, strategy="taskbased", local_matmul="pallas")
    got = np.asarray(mm(None, b, a_ranks=rcsr))
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=1e-4)
    # the single-launch local kernel route (stage 1 = one grouped gemm)
    got_local = np.asarray(kops.ranksparse_matmul(rcsr, b))
    np.testing.assert_allclose(got_local, want, atol=5e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# sparse x sparse (SpGEMM): structure on BOTH operands, both comm modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", SPGEMM_COMM_MODES)
@pytest.mark.parametrize("family", SPGEMM_FAMILIES)
def test_spgemm_oracle_1x1(family, mode):
    mesh = make_host_mesh(1, 1)
    case = spgemm_case(family, seed=3)
    got = run_spgemm(case, mesh, mode)
    check_case(case, got, f"{family}/{mode}/1x1")


@pytest.mark.parametrize("mode", SPGEMM_COMM_MODES)
@pytest.mark.parametrize("family", SPGEMM_FAMILIES)
def test_spgemm_compiled_matches_eager_1x1(family, mode):
    """The digest-keyed executables must stay a pure dispatch
    optimization under the new c_mask / pull routes: compiled and eager
    outputs pinned bitwise, per comm mode."""
    mesh = make_host_mesh(1, 1)
    case = spgemm_case(family, seed=9)
    got_compiled = run_spgemm(case, mesh, mode)
    got_eager = run_spgemm(case, mesh, mode, compiled=False)
    np.testing.assert_array_equal(
        got_compiled, got_eager,
        err_msg=f"spgemm compiled != eager: {family}/{mode}/1x1",
    )
    check_case(case, got_compiled, f"compiled:{family}/{mode}/1x1")


#: the families the tuner may flip between comm modes — mask-only
#: pipelines (rank payloads execute factored on broadcast but densify
#: under pull, a different algorithm, so only tolerance equality holds
#: there; ``tune_plan`` guards on ``a_ranks is None`` for the same
#: reason)
SPGEMM_MASK_FAMILIES = tuple(
    f for f in SPGEMM_FAMILIES if not f.startswith("rank")
)


@pytest.mark.parametrize("family", SPGEMM_MASK_FAMILIES)
def test_spgemm_pull_matches_broadcast_bitwise_1x1(family):
    """Pull's gather-by-index executor accumulates the same panels in
    the same order as the broadcast masked DAG — outputs are bitwise
    equal, so flipping the comm mode (e.g. by the tuner) can never move
    numerics."""
    mesh = make_host_mesh(1, 1)
    case = spgemm_case(family, seed=5)
    got_bcast = run_spgemm(case, mesh, "broadcast")
    got_pull = run_spgemm(case, mesh, "pull")
    np.testing.assert_array_equal(
        got_bcast, got_pull,
        err_msg=f"spgemm pull != broadcast: {family}/1x1",
    )


# ---------------------------------------------------------------------------
# real 2x2 and 2x4 meshes: the same sweep under shard_map semantics
# ---------------------------------------------------------------------------


def test_oracle_sweep_2x2(subproc):
    out = subproc(ORACLE_SWEEP_CODE.format(p_row=2, p_col=2), devices=4)
    assert "ORACLE_SWEEP_OK" in out


def test_oracle_sweep_2x4(subproc):
    out = subproc(ORACLE_SWEEP_CODE.format(p_row=2, p_col=4), devices=8)
    assert "ORACLE_SWEEP_OK" in out


# ---------------------------------------------------------------------------
# contraction oracle: every spec family vs float64 np.einsum
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", CONTRACT_SPECS)
def test_contract_oracle_1x1(family):
    mesh = make_host_mesh(1, 1)
    case = contract_case(family, seed=5)
    got = run_contract(case, mesh)
    check_contract_case(case, got, f"{family}/1x1")


@pytest.mark.slow
def test_contract_oracle_sweep_2x2(subproc):
    out = subproc(CONTRACT_SWEEP_CODE.format(p_row=2, p_col=2), devices=4)
    assert "CONTRACT_SWEEP_OK" in out


@pytest.mark.slow
def test_contract_oracle_sweep_2x4(subproc):
    out = subproc(CONTRACT_SWEEP_CODE.format(p_row=2, p_col=4), devices=8)
    assert "CONTRACT_SWEEP_OK" in out


# ---------------------------------------------------------------------------
# compiled executables vs eager interpreters: bitwise differential
#
# The executable cache (core/summa.py + core/contract.py) must be a pure
# dispatch optimization: the jitted program and the eager interpreter
# trace the same jnp ops, so their outputs must match bitwise — any
# drift means the compiled closure baked in stale state.
# ---------------------------------------------------------------------------

#: ring bypasses DistributedMatmul entirely, so it has no compiled twin
COMPILED_STRATEGIES = tuple(s for s in ORACLE_STRATEGIES if s != "ring")


@pytest.mark.parametrize("strategy", COMPILED_STRATEGIES)
@pytest.mark.parametrize("family", ORACLE_FAMILIES)
def test_compiled_matches_eager_1x1(family, strategy):
    mesh = make_host_mesh(1, 1)
    case = oracle_case(family, seed=9)
    got_compiled = run_strategy(case, mesh, strategy)
    got_eager = run_strategy(case, mesh, strategy, compiled=False)
    np.testing.assert_array_equal(
        got_compiled, got_eager,
        err_msg=f"compiled != eager: {family}/{strategy}/1x1",
    )
    check_case(case, got_compiled, f"compiled:{family}/{strategy}/1x1")


@pytest.mark.parametrize("family", CONTRACT_SPECS)
def test_contract_compiled_matches_eager_1x1(family):
    mesh = make_host_mesh(1, 1)
    case = contract_case(family, seed=9)
    got_compiled = run_contract(case, mesh)
    got_eager = run_contract(case, mesh, compiled=False)
    np.testing.assert_array_equal(
        got_compiled, got_eager,
        err_msg=f"contract compiled != eager: {family}/1x1",
    )
    check_contract_case(case, got_compiled, f"compiled:{family}/1x1")


# ---------------------------------------------------------------------------
# acceptance: plan FLOPs scale with average block rank
# ---------------------------------------------------------------------------


def test_plan_flops_scale_with_mean_rank():
    """Halving the rank budget must shrink planned FLOPs accordingly: the
    factored cost is linear in rank below the dense-fallback threshold,
    and always bounded by the mask-only accounting."""
    cfg = _grid_cfg(2, 2)
    flops = []
    means = []
    for max_rank in (4, 8, 16):
        rm = decay_rank_map(
            16, 16, 64, 64, max_rank=max_rank, decay=0.4, threshold=5e-2
        )
        plan = plan_matmul(1024, 1024, 1024, cfg, a_ranks=rm)
        assert plan.local_impl == "ranksparse"
        assert plan.cost.flops_sparse < plan.cost.flops_mask
        flops.append(plan.cost.flops_sparse)
        means.append(rm.mean_rank)
    assert flops[0] < flops[1] < flops[2]
    # linear regime: FLOPs track mean rank within 25%
    for i in (1, 2):
        ratio = flops[i] / flops[0]
        rank_ratio = means[i] / means[0]
        assert abs(ratio - rank_ratio) / rank_ratio < 0.25, (ratio, rank_ratio)


def test_rank_comm_bytes_below_mask_only():
    """Factor panels travel instead of dense panels: the rank plan's
    broadcast bytes are strictly below the mask-only plan's for the same
    structure (multi-row/col grid so both operands broadcast)."""
    cfg = _grid_cfg(2, 2)
    rm = decay_rank_map(16, 16, 64, 64, max_rank=8, decay=0.4, threshold=5e-2)
    rank_plan = plan_matmul(1024, 1024, 1024, cfg, a_ranks=rm)
    mask_plan = plan_matmul(1024, 1024, 1024, cfg, a_mask=rm.mask)
    for strat in ("procedural", "taskbased"):
        assert (
            rank_plan.cost.comm_bytes[strat] < mask_plan.cost.comm_bytes[strat]
        )
    # gather-style schedules stay sparsity- and rank-blind
    for strat in ("allgather", "ring"):
        assert (
            rank_plan.cost.comm_bytes[strat] == mask_plan.cost.comm_bytes[strat]
        )


def test_rank_map_cache_key_is_structural():
    """Same rank structure => same cached plan; different ranks => new."""
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased")
    rm = decay_rank_map(4, 4, 16, 16, max_rank=8, decay=0.9)
    r1 = synthesize_rank_csr(rm, seed=1)
    r2 = synthesize_rank_csr(rm, seed=2)  # same structure, new factors
    p1 = mm.plan(64, 64, 64, a_ranks=r1)
    assert mm.plan(64, 64, 64, a_ranks=r2) is p1
    rm_lo = decay_rank_map(4, 4, 16, 16, max_rank=4, decay=0.9)
    assert mm.plan(64, 64, 64, a_ranks=synthesize_rank_csr(rm_lo)) is not p1


def test_nonuniform_rank_map_screens_blocks():
    """NonuniformMatmul accepts a logical per-block rank map: rank-0
    blocks are screened out of the product, everything else matches the
    dense oracle; the expanded physical plan is rank-sparse."""
    import jax.numpy as jnp

    from repro.core import NonuniformMatmul, nonuniform_tiling

    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased")
    rt = nonuniform_tiling(100, 5, seed=3)
    it = nonuniform_tiling(120, 4, seed=4)
    ct = nonuniform_tiling(90, 6, seed=5)
    nm = NonuniformMatmul(mm, rt, it, ct, tile=16)
    rng = np.random.default_rng(0)
    a = rng.normal(size=(100, 120)).astype(np.float32)
    b = rng.normal(size=(120, 90)).astype(np.float32)
    full = np.full((5, 4), 16, dtype=np.int32)
    got = np.asarray(nm(jnp.asarray(a), jnp.asarray(b), a_ranks=full))
    np.testing.assert_allclose(
        got, a.astype(np.float64) @ b.astype(np.float64),
        atol=5e-4, rtol=1e-4,
    )
    ranks = full.copy()
    ranks[1, 2] = 0  # screen one logical block out entirely
    a_z = a.copy()
    a_z[rt.offsets[1] : rt.offsets[2], it.offsets[2] : it.offsets[3]] = 0
    got2 = np.asarray(nm(jnp.asarray(a), jnp.asarray(b), a_ranks=ranks))
    np.testing.assert_allclose(
        got2, a_z.astype(np.float64) @ b.astype(np.float64),
        atol=5e-4, rtol=1e-4,
    )
    plan = nm.plan(a_ranks=ranks)
    # no factor payload behind a bare rank map: the plan schedules the
    # masked DAG it will actually execute (the rank structure still
    # screens blocks and refines the useful-work accounting)
    assert plan.local_impl == "masked"
    assert plan.a_ranks is not None
    assert plan.cost.fill_in < 1.0


# ---------------------------------------------------------------------------
# satellite bugfixes: generator validation + realized-fill clamp
# ---------------------------------------------------------------------------


def test_random_block_mask_realized_fill_clamped():
    """The row/column coverage fix-up must not silently overshoot the
    requested fill.  Hard guarantee (any grid/fill/seed): nnz <=
    max(ceil(fill*size), m + n), since every surviving surplus block is
    the sole support of its row or column.  Previously a 1 x n grid at
    tiny fill came back fully dense."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        mb = int(rng.integers(1, 12))
        nb = int(rng.integers(1, 12))
        fill = float(rng.uniform(0.01, 1.0))
        mask = random_block_mask(mb, nb, fill, seed=int(rng.integers(1e6)))
        hard = max(int(np.ceil(fill * mb * nb)), mb + nb)
        assert mask.sum() <= hard, (mb, nb, fill, int(mask.sum()), hard)
        # coverage guarantee intact
        assert mask.any(axis=1).all() and mask.any(axis=0).all()
    # typical bound max(ceil, max(m, n)) on representative cases,
    # including the degenerate single-row/column grids of the bug report
    for mb, nb, fill, seed in [
        (1, 16, 0.05, 0), (16, 1, 0.05, 1), (2, 9, 0.1, 2),
        (8, 8, 0.3, 3), (5, 5, 0.9, 4), (3, 17, 0.02, 5),
    ]:
        mask = random_block_mask(mb, nb, fill, seed=seed)
        bound = max(int(np.ceil(fill * mb * nb)), max(mb, nb))
        assert mask.sum() <= bound, (mb, nb, fill, int(mask.sum()), bound)


def test_decay_block_mask_validates_parameters():
    with pytest.raises(ValueError, match="decay must be > 0"):
        decay_block_mask(4, 4, decay=0.0)
    with pytest.raises(ValueError, match="decay must be > 0"):
        decay_block_mask(4, 4, decay=-1.0)
    with pytest.raises(ValueError, match="threshold must be in"):
        decay_block_mask(4, 4, threshold=1.5)
    with pytest.raises(ValueError, match="threshold must be in"):
        decay_block_mask(4, 4, threshold=0.0)
    with pytest.raises(ValueError, match="block grid"):
        decay_block_mask(0, 4)
