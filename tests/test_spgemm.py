"""repro.spgemm: output-structure-aware sparse x sparse planning.

Covers the symbolic structure pass (output masks / rank bounds vs numpy
references, and the contract() front-end deduplication over the oracle
spec families), the stationarity chooser (modeled comm volumes,
auto-selection, plan-digest preservation when C-stationary is chosen),
dead-output pruning in the task graph, the one-sided pull fetch DAG
(structure, owner contention, pull-vs-broadcast byte crossover both
directions), the B-panel broadcast sizing fix, and real-mesh executor
correctness for both comm modes and all three stationarities.
"""
import dataclasses

import numpy as np
import pytest

from conftest import (
    CONTRACT_SPECS,
    SPGEMM_SWEEP_CODE,
    contract_case,
)
from repro.core import (
    DistributedMatmul,
    banded_block_mask,
    block_diag_block_mask,
    decay_rank_map,
    plan_matmul,
)
from repro.core.summa import SummaConfig
from repro.launch.mesh import make_host_mesh
from repro.sched.simulator import simulate
from repro.sched.taskgraph import abstract_summa_config, from_plan
from repro.spgemm import (
    STATIONARITIES,
    as_block_mask,
    choose_stationarity,
    live_elems,
    output_mask,
    output_rank_bound,
    stationarity_comm_volumes,
)


class FakeMesh:
    def __init__(self, sizes):
        self.shape = sizes


def _grid_cfg(p_row, p_col, **kw):
    return SummaConfig(
        mesh=FakeMesh({"data": p_row, "model": p_col}),
        row_axis="data",
        col_axis="model",
        **kw,
    )


# ---------------------------------------------------------------------------
# symbolic structure pass
# ---------------------------------------------------------------------------


def test_output_mask_is_boolean_block_product():
    rng = np.random.default_rng(0)
    for _ in range(20):
        mb, kb, nb = rng.integers(1, 10, size=3)
        am = rng.random((mb, kb)) < 0.4
        bm = rng.random((kb, nb)) < 0.4
        want = (am.astype(int) @ bm.astype(int)) > 0
        np.testing.assert_array_equal(output_mask(am, bm), want)


def test_output_mask_one_sided_and_dense():
    am = banded_block_mask(4, 6, 1)
    # dense B: every row of A with any support reaches every B column
    got = output_mask(am, None, n_blocks=5)
    np.testing.assert_array_equal(
        got, np.broadcast_to(am.any(axis=1)[:, None], (4, 5))
    )
    bm = banded_block_mask(6, 4, 1)
    got = output_mask(None, bm, m_blocks=3)
    np.testing.assert_array_equal(
        got, np.broadcast_to(bm.any(axis=0)[None, :], (3, 4))
    )
    assert output_mask(None, None) is None


def test_output_mask_rank_structures_contribute_support():
    rm = decay_rank_map(4, 4, 16, 16, max_rank=4, decay=0.9, threshold=5e-2)
    bm = banded_block_mask(4, 4, 0)
    want = ((rm.ranks > 0).astype(int) @ bm.astype(int)) > 0
    np.testing.assert_array_equal(output_mask(rm, bm), want)
    np.testing.assert_array_equal(as_block_mask(rm), rm.ranks > 0)


def test_output_rank_bound_min_and_subadditive():
    rm = decay_rank_map(4, 4, 32, 32, max_rank=8, decay=0.6, threshold=5e-2)
    bm = banded_block_mask(4, 4, 1)
    bound = output_rank_bound(rm, bm)
    ra = np.asarray(rm.ranks, np.int64)
    # independent reference: sum_k min(ra[i,k], inf if bm else 0)
    want = np.zeros((4, 4), np.int64)
    for i in range(4):
        for j in range(4):
            want[i, j] = sum(
                int(ra[i, kk]) for kk in range(4) if bm[kk, j]
            )
    np.testing.assert_array_equal(bound, want)
    # mask x mask: each live addend contributes 1
    am = banded_block_mask(4, 4, 1)
    want_mm = am.astype(np.int64) @ bm.astype(np.int64)
    np.testing.assert_array_equal(output_rank_bound(am, bm), want_mm)


def test_live_elems_matches_structures():
    assert live_elems(None, (64, 96)) == 64 * 96
    am = banded_block_mask(4, 4, 0)
    assert live_elems(am, (64, 64)) == 4 * 16 * 16
    rm = decay_rank_map(4, 4, 32, 32, max_rank=4, decay=0.9)
    want = float(
        np.minimum(
            np.asarray(rm.ranks)[rm.mask] * 64, 32 * 32
        ).sum()
    )
    assert live_elems(rm, (128, 128)) == want


@pytest.mark.parametrize("family", CONTRACT_SPECS)
def test_contract_inferred_mask_equals_symbolic_pass(family):
    """Satellite: contract()'s inferred C mask must be the symbolic
    pass's output on every oracle spec family — einsum over the boolean
    block masks is the independent reference."""
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased")
    case = contract_case(family, seed=5)
    x, y = case["x"], case["y"]
    out = mm.contract(case["spec"], x, y, tile=case["tile"])
    x_plain = x.mask is None and x.ranks is None and x.rank_csr is None
    y_plain = y.mask is None and y.ranks is None and y.rank_csr is None
    if x_plain and y_plain:
        assert out.mask is None
        return
    want = (
        np.einsum(
            case["spec"],
            x.block_mask.astype(np.int64),
            y.block_mask.astype(np.int64),
        ) > 0
    )
    np.testing.assert_array_equal(out.mask, want)


def test_contract_geometry_routes_symbolic_pass():
    """The matricized inferred mask on the cached geometry is exactly
    ``output_mask`` of the matricized operand masks, and it reaches the
    planner as ``c_mask`` (dead C blocks emit no gemm tasks)."""
    from repro.core.contract import (
        BlockSparseTensor,
        _geometry_cached,
        _plan_step,
    )

    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased")
    rng = np.random.default_rng(0)
    am = block_diag_block_mask(4, 4)
    bm = block_diag_block_mask(4, 4)
    x = BlockSparseTensor.from_dense(
        rng.normal(size=(64, 64)).astype(np.float32),
        block_shape=(16, 16), mask=am,
    )
    y = BlockSparseTensor.from_dense(
        rng.normal(size=(64, 64)).astype(np.float32),
        block_shape=(16, 16), mask=bm,
    )
    geom = _geometry_cached(mm, "ij,jk->ik", x, y, 64)
    np.testing.assert_array_equal(geom.c_mask2, output_mask(am, bm))
    plan = _plan_step(mm, geom, x)
    assert plan.c_mask is not None
    np.testing.assert_array_equal(plan.c_mask, output_mask(am, bm))
    # block-diagonal x block-diagonal stays block-diagonal: exactly one
    # live gemm per diagonal C block
    assert int(plan.device_live.sum()) == 4


# ---------------------------------------------------------------------------
# stationarity chooser
# ---------------------------------------------------------------------------


def test_stationarity_volumes_dense_formulas():
    m, k, n = 256, 512, 128
    p_row, p_col, itemsize = 4, 2, 4
    vols = stationarity_comm_volumes(
        None, None, m=m, k=k, n=n, p_row=p_row, p_col=p_col,
        itemsize=itemsize,
    )
    F = 2.0  # broadcast-as-allreduce factor (BCAST_FACTOR)
    assert vols["C"] == F * itemsize * (m * k + k * n)
    assert vols["A"] == F * itemsize * k * n + itemsize * m * n
    assert vols["B"] == F * itemsize * m * k + itemsize * m * n
    best, got = choose_stationarity(
        None, None, m=m, k=k, n=n, p_row=p_row, p_col=p_col,
        itemsize=itemsize,
    )
    assert got == vols
    assert vols[best] <= min(vols.values())


def test_stationarity_single_axis_grids_prefer_c():
    """On a 1x1 grid all volumes are zero — ties keep "C", so default
    plans are bitwise-preserved."""
    best, vols = choose_stationarity(
        None, None, m=64, k=64, n=64, p_row=1, p_col=1, itemsize=4
    )
    assert best == "C"
    assert all(v == 0.0 for v in vols.values())


def test_stationarity_skinny_output_prefers_a():
    """Tiny C (m, n << k): moving C beats moving the huge K-panels."""
    best, vols = choose_stationarity(
        None, None, m=64, k=65536, n=64, p_row=4, p_col=4, itemsize=4
    )
    assert best == "A"
    assert vols["A"] < vols["C"] and vols["A"] <= vols["B"]


def test_plan_auto_stationarity_matches_chooser():
    cfg = _grid_cfg(4, 4)
    amask = banded_block_mask(4, 4, 1)
    bmask = banded_block_mask(4, 4, 1)
    plan = plan_matmul(
        256, 256, 256, cfg, a_mask=amask, b_mask=bmask,
        stationarity="auto",
    )
    best, _ = choose_stationarity(
        amask, bmask, m=256, k=256, n=256, p_row=4, p_col=4, itemsize=4,
        c_structure=output_mask(amask, bmask),
    )
    assert plan.stationarity == best
    # the chooser's volumes ride in the cost model (per device)
    for s in STATIONARITIES:
        key = f"{s.lower()}_stationary"
        assert key in plan.cost.comm_bytes


def test_auto_digest_equals_explicit_choice():
    """When the chooser picks X, the auto plan is the explicit-X plan —
    same digest, so they share compiled executables."""
    cfg = _grid_cfg(4, 4)
    plan_auto = plan_matmul(
        64, 65536, 64, cfg, stationarity="auto", itemsize=4
    )
    explicit = plan_matmul(
        64, 65536, 64, cfg, stationarity=plan_auto.stationarity,
        itemsize=4,
    )
    assert plan_auto.digest() == explicit.digest()


def test_non_c_stationarity_forces_masked_pipeline():
    cfg = _grid_cfg(2, 2)
    rm = decay_rank_map(8, 8, 32, 32, max_rank=4, decay=0.6)
    plan_c = plan_matmul(256, 256, 256, cfg, a_ranks=rm)
    assert plan_c.local_impl == "ranksparse"
    plan_a = plan_matmul(
        256, 256, 256, cfg, a_ranks=rm, stationarity="A"
    )
    assert plan_a.local_impl == "masked"


def test_pull_requires_masks_and_c_stationarity():
    cfg = _grid_cfg(2, 2)
    with pytest.raises(ValueError, match="pull"):
        plan_matmul(64, 64, 64, cfg, comm_mode="pull")
    with pytest.raises(ValueError, match="pull"):
        plan_matmul(
            64, 64, 64, cfg, a_mask=banded_block_mask(4, 4, 1),
            comm_mode="pull", stationarity="B",
        )


# ---------------------------------------------------------------------------
# dead-output pruning + the B-panel sizing fix in the task graph
# ---------------------------------------------------------------------------


def _gemms(graph):
    return sum(1 for t in graph.tasks if t.kind == "gemm" and t.flops > 0)


def test_output_aware_plan_prunes_gemm_tasks():
    """Acceptance: banded x banded on a 16x16-block product — the
    output-aware plan emits strictly fewer gemm tasks than the
    A-structure-only plan."""
    cfg = abstract_summa_config(16, 16, strategy="taskbased")
    amask = banded_block_mask(16, 16, 1)
    bmask = banded_block_mask(16, 16, 1)
    g_aonly = from_plan(plan_matmul(1024, 1024, 1024, cfg, a_mask=amask))
    g_aware = from_plan(plan_matmul(
        1024, 1024, 1024, cfg, a_mask=amask, b_mask=bmask,
        c_mask=output_mask(amask, bmask),
    ))
    assert _gemms(g_aware) < _gemms(g_aonly)
    g_aware.validate()


def test_c_mask_narrows_device_live_beyond_operands():
    """An explicit output mask narrower than the inferred one prunes
    further (the caller knows which C blocks it will keep)."""
    cfg = abstract_summa_config(4, 4, strategy="taskbased")
    amask = banded_block_mask(4, 4, 1)
    bmask = banded_block_mask(4, 4, 1)
    inferred = plan_matmul(
        256, 256, 256, cfg, a_mask=amask, b_mask=bmask,
        c_mask=output_mask(amask, bmask),
    )
    narrow = plan_matmul(
        256, 256, 256, cfg, a_mask=amask, b_mask=bmask,
        c_mask=banded_block_mask(4, 4, 0),
    )
    assert int(narrow.device_live.sum()) < int(inferred.device_live.sum())


def test_b_bcast_bytes_sized_from_surviving_blocks():
    """Satellite fix: bcast_b tasks charge the B panel's *surviving*
    blocks (mirroring the A side), not the full dense panel; an all-ones
    mask reproduces the old full-panel sizing bitwise."""
    cfg = abstract_summa_config(4, 4, strategy="taskbased")
    amask = np.ones((4, 4), bool)
    bmask = banded_block_mask(4, 4, 0)
    g_sparse = from_plan(plan_matmul(
        256, 256, 256, cfg, a_mask=amask, b_mask=bmask
    ))
    g_dense = from_plan(plan_matmul(
        256, 256, 256, cfg, a_mask=amask, b_mask=np.ones((4, 4), bool)
    ))

    def b_bytes(graph):
        return sorted(
            t.bytes for t in graph.tasks if t.kind == "bcast_b"
        )

    sparse_b, dense_b = b_bytes(g_sparse), b_bytes(g_dense)
    assert sum(sparse_b) < sum(dense_b)
    # all-ones B mask == dense panel sizing (bitwise-compatible)
    full = 2.0 * (256 // 4) * (256 // 4) * 4
    assert all(b == full for b in dense_b)


# ---------------------------------------------------------------------------
# the one-sided pull fetch DAG
# ---------------------------------------------------------------------------


def _pull_graphs(p, amask, bmask, n=1024):
    cfg = abstract_summa_config(p, p, strategy="taskbased")
    cm = output_mask(amask, bmask)
    kw = dict(a_mask=amask, b_mask=bmask, c_mask=cm)
    g_bcast = from_plan(plan_matmul(n, n, n, cfg, **kw))
    g_pull = from_plan(plan_matmul(
        n, n, n, cfg, comm_mode="pull", **kw
    ))
    return g_bcast, g_pull


def _comm_bytes(graph):
    return float(
        sum(t.bytes for t in graph.tasks if t.resource == "comm")
    )


def test_fetch_tasks_name_receiver_and_owner():
    amask = banded_block_mask(16, 16, 1)
    _, g_pull = _pull_graphs(16, amask, amask)
    g_pull.validate()
    fetches = [t for t in g_pull.tasks if t.kind.startswith("fetch")]
    assert fetches, "pull graph emitted no fetch tasks"
    assert all(t.resource == "comm" for t in fetches)
    for t in fetches:
        assert len(t.devices) == 2
        receiver, owner = t.devices
        assert receiver != owner  # owner-local reads are free (no task)
    # no broadcast tasks in a pull graph
    assert not any(t.kind.startswith("bcast") for t in g_pull.tasks)
    assert g_pull.meta["comm_mode"] == "pull"


def test_pull_vs_broadcast_crossover_both_directions():
    """Pull wins bytes at low fill (per-gemm fetches of surviving
    panels), broadcast wins at dense (one panel serves the whole
    row/column); the 16x16 virtual grid is the ISSUE's acceptance
    point."""
    banded = banded_block_mask(16, 16, 1)
    g_b, g_p = _pull_graphs(16, banded, banded)
    assert _comm_bytes(g_p) < _comm_bytes(g_b)
    dense = np.ones((16, 16), bool)
    g_b, g_p = _pull_graphs(16, dense, dense)
    assert _comm_bytes(g_p) > _comm_bytes(g_b)


def test_pull_owner_contention_prices_hot_panels():
    """Every fetch occupies the owner's comm clock too: a hot owner
    serializes its requesters, which the simulator must surface as
    nonzero comm busy-time on the owner."""
    amask = banded_block_mask(16, 16, 1)
    _, g_pull = _pull_graphs(16, amask, amask)
    sim = simulate(g_pull)
    owners = {
        t.devices[1] for t in g_pull.tasks if t.kind.startswith("fetch")
    }
    assert owners
    assert all(sim.busy_comm_s[d] > 0 for d in owners)


def test_pull_plan_digest_differs_from_broadcast():
    cfg = abstract_summa_config(4, 4, strategy="taskbased")
    amask = banded_block_mask(4, 4, 1)
    kw = dict(a_mask=amask, b_mask=amask, c_mask=output_mask(amask, amask))
    p_b = plan_matmul(256, 256, 256, cfg, **kw)
    p_p = plan_matmul(256, 256, 256, cfg, comm_mode="pull", **kw)
    assert p_b.digest() != p_p.digest()
    # comm-mode flips through dataclasses.replace drop the digest memo
    assert dataclasses.replace(p_b, comm_mode="pull").digest() == p_p.digest()


def test_tuner_considers_pull_for_masked_plans():
    """The tuner's candidate set includes the pull schedule exactly for
    mask-only C-stationary plans, and the tuned result is never worse
    than the static broadcast schedule in simulated makespan."""
    from repro.sched.tuner import tune_plan

    cfg = abstract_summa_config(8, 8, strategy="taskbased")
    amask = banded_block_mask(8, 8, 1)
    plan = plan_matmul(
        512, 512, 512, cfg, a_mask=amask, b_mask=amask,
        c_mask=output_mask(amask, amask),
    )
    tuned = tune_plan(plan)
    assert tuned.comm_mode in ("broadcast", "pull")
    assert tuned.tuned["makespan_s"] <= (
        tuned.tuned["static_makespan_s"] * (1 + 1e-9)
    )


# ---------------------------------------------------------------------------
# executors on a real mesh (both comm modes, all three stationarities)
# ---------------------------------------------------------------------------


def test_spgemm_executor_sweep_2x2(subproc):
    out = subproc(SPGEMM_SWEEP_CODE.format(p_row=2, p_col=2), devices=4)
    assert "SPGEMM_SWEEP_OK" in out


STATIONARITY_SWEEP_CODE = r"""
import numpy as np
import jax.numpy as jnp
from repro.core import DistributedMatmul, banded_block_mask
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(0)
a = rng.normal(size=(64, 128)).astype(np.float32)
b = rng.normal(size=(128, 96)).astype(np.float32)
ref = a.astype(np.float64) @ b.astype(np.float64)
mm = DistributedMatmul(mesh, strategy="taskbased")
for stat in ("C", "A", "B", "auto"):
    got = np.asarray(mm(jnp.asarray(a), jnp.asarray(b), stationarity=stat))
    np.testing.assert_allclose(
        got, ref, atol=5e-4, rtol=1e-4, err_msg=f"stationarity {stat}"
    )
am = banded_block_mask(4, 8, 1)
bm = banded_block_mask(8, 4, 1)
a_z = a * np.kron(am, np.ones((16, 16), bool))
b_z = b * np.kron(bm, np.ones((16, 24), bool))
ref_m = a_z.astype(np.float64) @ b_z.astype(np.float64)
for stat in ("C", "A", "B"):
    got = np.asarray(mm(
        jnp.asarray(a), jnp.asarray(b), a_mask=am, b_mask=bm,
        stationarity=stat,
    ))
    np.testing.assert_allclose(
        got, ref_m, atol=5e-4, rtol=1e-4, err_msg=f"masked {stat}"
    )
print("STATIONARITY_SWEEP_OK")
"""


def test_stationarity_executor_sweep_2x2(subproc):
    out = subproc(STATIONARITY_SWEEP_CODE, devices=4)
    assert "STATIONARITY_SWEEP_OK" in out


# hypothesis property tests for the chooser live in
# tests/test_spgemm_props.py ([dev]-gated module skip, like
# tests/test_blocking.py — this module must keep running without the
# dev extras)
