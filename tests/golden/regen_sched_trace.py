"""Regenerate the committed golden schedule trace.

Run after an *intentional* schedule/simulator change:

    PYTHONPATH=src:tests python tests/golden/regen_sched_trace.py

and commit the refreshed ``sched_trace_small.json`` together with the
change that moved it — the golden test exists so sched refactors diff
loudly, not silently.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from test_sched import GOLDEN_TRACE, _golden_graph  # noqa: E402

from repro.sched import simulate  # noqa: E402


def main() -> None:
    sim = simulate(_golden_graph(), trace=True)
    payload = {
        "makespan_s": sim.makespan_s,
        "fingerprint": sim.fingerprint(),
        "trace": sim.chrome_trace(),
    }
    with open(GOLDEN_TRACE, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(
        f"wrote {GOLDEN_TRACE}: makespan={sim.makespan_s:.3e}s, "
        f"fingerprint={sim.fingerprint()[:12]}, "
        f"{len(sim.spans)} spans"
    )


if __name__ == "__main__":
    main()
