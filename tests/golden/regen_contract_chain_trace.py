"""Regenerate the committed golden chained-contraction trace.

Run after an *intentional* change to the chain union-graph builder
(``sched.taskgraph.chain_graphs``), the window edges, or the simulator:

    PYTHONPATH=src:tests python tests/golden/regen_contract_chain_trace.py

and commit the refreshed ``contract_chain_trace.json`` together with the
change that moved it.  The payload also pins the chain's reason to
exist — the joint makespan never exceeding the sequential sum — so a
regression there diffs loudly too.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from test_contract import GOLDEN_CHAIN_TRACE, _chain_golden_graphs  # noqa: E402

from repro.sched import chain_graphs, simulate  # noqa: E402


def main() -> None:
    graphs = _chain_golden_graphs()
    sequential = float(sum(simulate(g).makespan_s for g in graphs))
    sim = simulate(chain_graphs(graphs), trace=True)
    payload = {
        "makespan_s": sim.makespan_s,
        "joint_makespan_s": sim.makespan_s,
        "sequential_makespan_s": sequential,
        "fingerprint": sim.fingerprint(),
        "trace": sim.chrome_trace(),
    }
    with open(GOLDEN_CHAIN_TRACE, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(
        f"wrote {GOLDEN_CHAIN_TRACE}: joint={sim.makespan_s:.3e}s vs "
        f"sequential={sequential:.3e}s, "
        f"fingerprint={sim.fingerprint()[:12]}, {len(sim.spans)} spans"
    )


if __name__ == "__main__":
    main()
