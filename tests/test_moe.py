"""MoE layer: routing exactness vs a dense loop-over-experts oracle,
capacity-drop accounting, EP sharding equivalence in a subprocess."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.context import ParallelCtx
from repro.models import layers as L
from repro.models.moe import capacity, init_moe, moe_ffn, padded_experts

CTX = ParallelCtx(mesh=None)


def dense_moe_oracle(p, x, cfg):
    """Compute every expert for every token, combine top-k — exact when no
    drops happen."""
    moe = cfg.moe
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,de->bse", h.astype(jnp.float32), p["router"]["w"])
    topv, topi = jax.lax.top_k(logits, moe.top_k)
    gates = jax.nn.softmax(topv, axis=-1)
    # all experts on all tokens
    g = jnp.einsum("bsd,edf->bsef", h, p["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", h, p["w_up"])
    mid = jax.nn.silu(g) * u
    y_all = jnp.einsum("bsef,efd->bsed", mid, p["w_down"])  # (B,S,E,D)
    sel = jnp.take_along_axis(y_all, topi[..., None], axis=2)  # (B,S,k,D)
    out = (sel * gates[..., None].astype(sel.dtype)).sum(axis=2)
    if "shared" in p:
        from repro.models.ffn import ffn
        from repro.models.moe import _shared_view

        out = out + ffn(p["shared"], x, _shared_view(cfg), CTX)
    return out


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "kimi-k2-1t-a32b"])
def test_moe_matches_dense_oracle_no_drops(arch):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(
        cfg,
        dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=32.0),
    )
    p = init_moe(jax.random.PRNGKey(0), cfg, CTX, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    got, aux = moe_ffn(p, x, cfg, CTX)
    want = dense_moe_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    assert float(aux) > 0.0


def test_capacity_drops_only_reduce_magnitude():
    """With a tiny capacity, outputs are a (token-wise) subset of the
    no-drop outputs — dropped copies contribute exactly zero."""
    cfg = get_config("mixtral-8x7b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, CTX, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    big = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=32.0)
    )
    tiny = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25)
    )
    y_big, _ = moe_ffn(p, x, big, CTX)
    y_tiny, _ = moe_ffn(p, x, tiny, CTX)
    assert np.isfinite(np.asarray(y_tiny)).all()
    # some tokens dropped -> strictly less "mass"
    assert float(jnp.abs(y_tiny).sum()) < float(jnp.abs(y_big).sum())


def test_grad_flows_through_dispatch():
    cfg = get_config("mixtral-8x7b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, CTX, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))

    def f(p):
        y, aux = moe_ffn(p, x, cfg, CTX)
        return jnp.sum(y**2) + aux

    g = jax.grad(f)(p)
    gw = g["w_gate"]
    assert float(jnp.abs(gw).max()) > 0
    assert np.isfinite(float(jnp.abs(g["router"]["w"]).max()))


def test_capacity_helpers():
    from repro.models.config import MoEConfig

    moe = MoEConfig(num_experts=8, top_k=2, d_ff=64)
    assert padded_experts(moe, 16) == 16
    assert padded_experts(moe, 4) == 8
    c = capacity(moe, seq=4096, e_pad=16)
    assert c >= 4096 * 2 // 16
    assert c % 8 == 0


EP_CODE = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.dist.context import ParallelCtx
from repro.models.moe import init_moe, moe_ffn
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
ctx = ParallelCtx(mesh=mesh)
ctx1 = ParallelCtx(mesh=None)
cfg = get_config("mixtral-8x7b", smoke=True)
cfg = dataclasses.replace(cfg, dtype="float32",
    moe=dataclasses.replace(cfg.moe, capacity_factor=32.0))
p = init_moe(jax.random.PRNGKey(0), cfg, ctx, dtype=jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
with mesh:
    y_ep, _ = jax.jit(lambda p, x: moe_ffn(p, x, cfg, ctx))(p, x)
y_1, _ = moe_ffn(p, x, cfg, ctx1)
err = np.abs(np.asarray(y_ep) - np.asarray(y_1)).max()
assert err < 1e-4, err
print("EP_MOE_OK")
"""


def test_expert_parallel_equivalence_subprocess(subproc):
    out = subproc(EP_CODE, devices=8)
    assert "EP_MOE_OK" in out
