"""End-to-end behaviour tests: training makes progress on learnable data;
SUMMA-strategy training matches XLA-strategy training; serving generates.
"""
import numpy as np


def test_training_reduces_loss(tmp_path):
    """Full e2e driver on a smoke config: loss must drop substantially on
    the synthetic (partly deterministic) stream."""
    from repro.launch.train import main as train_main

    losses = train_main(
        [
            "--arch", "llama3.2-1b", "--smoke", "--steps", "40",
            "--global-batch", "4", "--seq", "64", "--log-every", "100",
        ]
    )
    assert losses[-1] < losses[0] * 0.9, (losses[0], losses[-1])


def test_summa_strategy_training_matches_xla():
    """The paper's matmul engine inside the LM: same loss trajectory as
    the default einsum path (numerics differ only at accumulation order).
    """
    from repro.launch.train import main as train_main

    common = [
        "--arch", "llama3.2-1b", "--smoke", "--steps", "6",
        "--global-batch", "2", "--seq", "32", "--log-every", "100",
    ]
    l_xla = train_main(common + ["--matmul-strategy", "xla"])
    l_summa = train_main(common + ["--matmul-strategy", "summa"])
    np.testing.assert_allclose(l_xla, l_summa, rtol=2e-2)


def test_serving_generates_tokens():
    from repro.launch.serve import main as serve_main

    gen = serve_main(
        [
            "--arch", "llama3.2-1b", "--smoke", "--batch", "2",
            "--prompt-len", "32", "--gen", "8",
        ]
    )
    assert gen.shape == (2, 8)
    assert np.all(gen >= 0)


def test_hybrid_arch_end_to_end():
    from repro.launch.train import main as train_main

    losses = train_main(
        [
            "--arch", "recurrentgemma-9b", "--smoke", "--steps", "10",
            "--global-batch", "2", "--seq", "32", "--log-every", "100",
        ]
    )
    assert np.isfinite(losses).all()
