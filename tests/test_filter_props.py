"""Property tests (hypothesis) for norm filtering and the kernel
autotune cache — random block structures against the additive error
bound, the ``filter_eps=0`` bitwise no-op, and the winner-never-loses
contract of recorded autotune entries.

hypothesis is a dev extra (pyproject ``[dev]``); without it this module
skips rather than fails (CI installs ``[dev]`` and asserts it imports).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import plan_matmul, random_block_mask  # noqa: E402
from repro.core.sparsity import block_norms  # noqa: E402
from repro.core.summa import SummaConfig  # noqa: E402
from repro.spgemm import filter_keep  # noqa: E402


class FakeMesh:
    def __init__(self, sizes):
        self.shape = sizes


def _grid_cfg(p_row, p_col, **kw):
    return SummaConfig(
        mesh=FakeMesh({"data": p_row, "model": p_col}),
        row_axis="data",
        col_axis="model",
        **kw,
    )


_blocks = st.integers(min_value=2, max_value=6)
_grid = st.integers(min_value=1, max_value=4)


def _block_matrix(rng, blocks, bs, decay):
    x = rng.standard_normal((blocks * bs, blocks * bs))
    scale = np.exp(-decay * np.abs(
        np.arange(blocks)[:, None] - np.arange(blocks)[None, :]
    ))
    return (
        x.reshape(blocks, bs, blocks, bs) * scale[:, None, :, None]
    ).reshape(blocks * bs, blocks * bs)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    blocks=_blocks,
    frac=st.floats(min_value=0.0, max_value=1.0),
    decay=st.floats(min_value=0.0, max_value=2.0),
)
def test_filtered_error_within_bound(seed, blocks, frac, decay):
    """``‖C_exact − C_filtered‖_F ≤ filter_bound`` for any threshold:
    each dropped (i,k,j) product contributes at most ‖A_ik‖·‖B_kj‖
    (submultiplicativity), and the bound sums exactly those terms."""
    bs = 4
    rng = np.random.default_rng(seed)
    a = _block_matrix(rng, blocks, bs, decay)
    b = _block_matrix(rng, blocks, bs, decay)
    an = block_norms(a, blocks, blocks)
    bn = block_norms(b, blocks, blocks)
    eps = frac * float(np.max(an[:, :, None] * bn[None, :, :]))
    keep, bound = filter_keep(an, bn, eps)
    # materialize the filtered product: zero the dropped (i,k,j) terms
    filt = np.zeros_like(a @ b)
    for i in range(blocks):
        for j in range(blocks):
            for k in range(blocks):
                if keep[i, k, j]:
                    filt[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] += (
                        a[i * bs:(i + 1) * bs, k * bs:(k + 1) * bs]
                        @ b[k * bs:(k + 1) * bs, j * bs:(j + 1) * bs]
                    )
    err = float(np.linalg.norm(a @ b - filt))
    assert err <= bound + 1e-9 * (1.0 + float(np.linalg.norm(a @ b)))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    blocks=_blocks,
    p_row=_grid,
    p_col=_grid,
    fill=st.floats(min_value=0.2, max_value=1.0),
)
def test_eps_zero_plan_digest_preserved(seed, blocks, p_row, p_col, fill):
    """Passing norms with ``filter_eps=0`` must be a bitwise no-op on
    the plan digest — for dense and masked structures alike."""
    n = blocks * 32
    cfg = _grid_cfg(p_row, p_col, strategy="taskbased", k_blocks=blocks)
    rng = np.random.default_rng(seed)
    if fill < 0.95:
        mask = random_block_mask(blocks, blocks, fill, seed=seed)
        norms = np.where(mask, rng.uniform(0.5, 2.0, mask.shape), 0.0)
        base = plan_matmul(n, n, n, cfg, a_mask=mask, b_mask=mask)
        p0 = plan_matmul(
            n, n, n, cfg, a_mask=mask, b_mask=mask,
            a_norms=norms, b_norms=norms, filter_eps=0.0,
        )
    else:
        norms = rng.uniform(0.5, 2.0, (blocks, blocks))
        base = plan_matmul(n, n, n, cfg)
        p0 = plan_matmul(
            n, n, n, cfg, a_norms=norms, b_norms=norms, filter_eps=0.0
        )
    assert p0.digest() == base.digest()
    assert p0.filter_bound == 0.0


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    blocks=_blocks,
    eps_frac=st.floats(min_value=1e-4, max_value=0.5),
)
def test_plan_bound_matches_dropped_mass(seed, blocks, eps_frac):
    """The plan-level ``filter_bound`` equals the filter_keep bound for
    the same norms/threshold, and task screening is monotone in eps."""
    n = blocks * 32
    cfg = _grid_cfg(2, 2, strategy="taskbased", k_blocks=blocks)
    rng = np.random.default_rng(seed)
    an = rng.uniform(0.0, 1.0, (blocks, blocks))
    bn = rng.uniform(0.0, 1.0, (blocks, blocks))
    eps = eps_frac * float(np.max(an[:, :, None] * bn[None, :, :]))
    keep, bound = filter_keep(an, bn, eps)
    p = plan_matmul(n, n, n, cfg, a_norms=an, b_norms=bn, filter_eps=eps)
    assert p.filter_bound == pytest.approx(bound)
    p_loose = plan_matmul(
        n, n, n, cfg, a_norms=an, b_norms=bn, filter_eps=eps / 2
    )
    assert p_loose.filter_bound <= p.filter_bound + 1e-12


# One measured entry shared across examples — tuning is the expensive
# part; the property quantifies over lookups against it.
@pytest.fixture(scope="module")
def tuned_entry():
    from repro.kernels.autotune import KernelAutotuner

    t = KernelAutotuner()
    entry = t.tune(48, 48, 48, repeats=1, routes=("xla", "pallas"))
    return t, entry


@settings(max_examples=40, deadline=None)
@given(
    dm=st.integers(min_value=-15, max_value=15),
    dk=st.integers(min_value=-15, max_value=15),
    dn=st.integers(min_value=-15, max_value=15),
)
def test_autotune_winner_never_loses_on_own_bucket(tuned_entry, dm, dk, dn):
    """Every recorded winner beat the generic route when measured, and
    every shape inside the bucket resolves to that same entry."""
    t, entry = tuned_entry
    assert entry["times_s"][entry["winner"]] <= entry["times_s"]["xla"]
    got = t.lookup(48 + dm, 48 + dk, 48 + dn)
    assert got is entry
