"""Serving correctness: prefill + decode must reproduce the full forward
pass logits (fp32, no-drop MoE capacity to make the oracle exact)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.dist.context import ParallelCtx
from repro.models.model import forward, init_model
from repro.serve.engine import decode_step, init_cache, prefill

CTX = ParallelCtx(mesh=None)
S_PRE, N_DEC, B = 24, 4, 2

DECODE_ARCHS = [
    a for a in ARCH_IDS
    if get_config(a, smoke=True).family not in ("audio", "vlm")
]


def _fp32_nodrop(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )
    return cfg


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _fp32_nodrop(get_config(arch, smoke=True))
    params = init_model(jax.random.PRNGKey(0), cfg, CTX)
    total = S_PRE + N_DEC
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, total), 0, cfg.vocab_size)
    logits_full, _ = forward(params, {"tokens": toks}, cfg, CTX, remat=False)
    lp, cache = prefill(params, {"tokens": toks[:, :S_PRE]}, cfg, CTX, max_len=total)
    scale = float(np.abs(np.asarray(logits_full)).max())
    tol = max(2e-3 * scale, 1e-3)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(logits_full[:, S_PRE - 1]), atol=tol, rtol=0.01
    )
    for t in range(N_DEC):
        lp, cache = decode_step(params, cache, toks[:, S_PRE + t], cfg, CTX)
        np.testing.assert_allclose(
            np.asarray(lp), np.asarray(logits_full[:, S_PRE + t]),
            atol=tol, rtol=0.01,
        )


def test_sliding_window_ring_cache():
    """Prefill longer than the window: ring buffer must hold the last W
    tokens and decode must keep matching the full forward pass."""
    cfg = _fp32_nodrop(get_config("mixtral-8x7b", smoke=True))
    assert cfg.window is not None and S_PRE > cfg.window
    params = init_model(jax.random.PRNGKey(0), cfg, CTX)
    total = S_PRE + N_DEC
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, total), 0, cfg.vocab_size)
    logits_full, _ = forward(params, {"tokens": toks}, cfg, CTX, remat=False)
    lp, cache = prefill(params, {"tokens": toks[:, :S_PRE]}, cfg, CTX, max_len=total)
    assert cache["units"]["b0"]["k"].shape[-2] == cfg.window  # O(W) state
    scale = float(np.abs(np.asarray(logits_full)).max())
    for t in range(N_DEC):
        lp, cache = decode_step(params, cache, toks[:, S_PRE + t], cfg, CTX)
        np.testing.assert_allclose(
            np.asarray(lp), np.asarray(logits_full[:, S_PRE + t]),
            atol=max(2e-3 * scale, 1e-3), rtol=0.01,
        )


def test_recurrent_state_is_o1_in_seq_len():
    """long_500k feasibility: cache size must not grow with max_len for
    subquadratic archs."""
    for arch in ("xlstm-1.3b", "recurrentgemma-9b", "mixtral-8x7b"):
        cfg = get_config(arch, smoke=True)
        c_small = init_cache(cfg, batch=1, max_len=64)
        c_large = init_cache(cfg, batch=1, max_len=4096)
        n_small = sum(x.size for x in jax.tree.leaves(c_small))
        n_large = sum(x.size for x in jax.tree.leaves(c_large))
        if cfg.window is not None or cfg.family == "ssm":
            assert n_large <= n_small * (cfg.window or 1) / 1 + n_small, arch
        if cfg.family == "ssm":
            assert n_small == n_large, arch  # strictly O(1)


SHARDED_DECODE_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.serve.engine import _decode_attention
from repro.dist.context import ParallelCtx
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
ctx = ParallelCtx(mesh=mesh)
ctx1 = ParallelCtx(mesh=None)
rng = np.random.default_rng(0)
B, H, Hkv, S, Dh = 4, 8, 2, 64, 32
q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
kn = jnp.asarray(rng.normal(size=(B, Hkv, 1, Dh)), jnp.float32)
vn = jnp.asarray(rng.normal(size=(B, Hkv, 1, Dh)), jnp.float32)
for n_valid in (1, 17, 33, 64):
    slot = jnp.int32(n_valid - 1)  # write the new token, then attend
    got, gk, gv = _decode_attention(
        q, kn, vn, k, v, slot, jnp.int32(n_valid), ctx)
    want, wk, wv = _decode_attention(
        q, kn, vn, k, v, slot, jnp.int32(n_valid), ctx1)
    assert np.abs(np.asarray(got) - np.asarray(want)).max() < 1e-4, n_valid
    # the ring write landed identically on the sharded cache
    assert np.abs(np.asarray(gk) - np.asarray(wk)).max() < 1e-6, n_valid
    assert np.abs(np.asarray(gv) - np.asarray(wv)).max() < 1e-6, n_valid
print("SHARDED_DECODE_OK")
"""


def test_seq_sharded_decode_attention_subprocess(subproc):
    out = subproc(SHARDED_DECODE_CODE, devices=8)
    assert "SHARDED_DECODE_OK" in out
