"""Serving correctness: prefill + decode must reproduce the full forward
pass logits (fp32, no-drop MoE capacity to make the oracle exact)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.dist.context import ParallelCtx
from repro.models.model import forward, init_model
from repro.serve import engine
from repro.serve.engine import decode_step, init_cache, prefill

CTX = ParallelCtx(mesh=None)
S_PRE, N_DEC, B = 24, 4, 2

DECODE_ARCHS = [
    a for a in ARCH_IDS
    if get_config(a, smoke=True).family not in ("audio", "vlm")
]


def _fp32_nodrop(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )
    return cfg


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _fp32_nodrop(get_config(arch, smoke=True))
    params = init_model(jax.random.PRNGKey(0), cfg, CTX)
    total = S_PRE + N_DEC
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, total), 0, cfg.vocab_size)
    logits_full, _ = forward(params, {"tokens": toks}, cfg, CTX, remat=False)
    lp, cache = prefill(params, {"tokens": toks[:, :S_PRE]}, cfg, CTX, max_len=total)
    scale = float(np.abs(np.asarray(logits_full)).max())
    tol = max(2e-3 * scale, 1e-3)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(logits_full[:, S_PRE - 1]), atol=tol, rtol=0.01
    )
    for t in range(N_DEC):
        lp, cache = decode_step(params, cache, toks[:, S_PRE + t], cfg, CTX)
        np.testing.assert_allclose(
            np.asarray(lp), np.asarray(logits_full[:, S_PRE + t]),
            atol=tol, rtol=0.01,
        )


def test_sliding_window_ring_cache():
    """Prefill longer than the window: ring buffer must hold the last W
    tokens and decode must keep matching the full forward pass."""
    cfg = _fp32_nodrop(get_config("mixtral-8x7b", smoke=True))
    assert cfg.window is not None and S_PRE > cfg.window
    params = init_model(jax.random.PRNGKey(0), cfg, CTX)
    total = S_PRE + N_DEC
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, total), 0, cfg.vocab_size)
    logits_full, _ = forward(params, {"tokens": toks}, cfg, CTX, remat=False)
    lp, cache = prefill(params, {"tokens": toks[:, :S_PRE]}, cfg, CTX, max_len=total)
    assert cache["units"]["b0"]["k"].shape[-2] == cfg.window  # O(W) state
    scale = float(np.abs(np.asarray(logits_full)).max())
    for t in range(N_DEC):
        lp, cache = decode_step(params, cache, toks[:, S_PRE + t], cfg, CTX)
        np.testing.assert_allclose(
            np.asarray(lp), np.asarray(logits_full[:, S_PRE + t]),
            atol=max(2e-3 * scale, 1e-3), rtol=0.01,
        )


def test_recurrent_state_is_o1_in_seq_len():
    """long_500k feasibility: cache size must not grow with max_len for
    subquadratic archs."""
    for arch in ("xlstm-1.3b", "recurrentgemma-9b", "mixtral-8x7b"):
        cfg = get_config(arch, smoke=True)
        c_small = init_cache(cfg, batch=1, max_len=64)
        c_large = init_cache(cfg, batch=1, max_len=4096)
        n_small = sum(x.size for x in jax.tree.leaves(c_small))
        n_large = sum(x.size for x in jax.tree.leaves(c_large))
        if cfg.window is not None or cfg.family == "ssm":
            assert n_large <= n_small * (cfg.window or 1) / 1 + n_small, arch
        if cfg.family == "ssm":
            assert n_small == n_large, arch  # strictly O(1)


SHARDED_DECODE_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.serve.engine import _decode_attention
from repro.dist.context import ParallelCtx
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
ctx = ParallelCtx(mesh=mesh)
ctx1 = ParallelCtx(mesh=None)
rng = np.random.default_rng(0)
B, H, Hkv, S, Dh = 4, 8, 2, 64, 32
q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
kn = jnp.asarray(rng.normal(size=(B, Hkv, 1, Dh)), jnp.float32)
vn = jnp.asarray(rng.normal(size=(B, Hkv, 1, Dh)), jnp.float32)
for n_valid in (1, 17, 33, 64):
    slot = jnp.int32(n_valid - 1)  # write the new token, then attend
    got, gk, gv = _decode_attention(
        q, kn, vn, k, v, slot, jnp.int32(n_valid), ctx)
    want, wk, wv = _decode_attention(
        q, kn, vn, k, v, slot, jnp.int32(n_valid), ctx1)
    assert np.abs(np.asarray(got) - np.asarray(want)).max() < 1e-4, n_valid
    # the ring write landed identically on the sharded cache
    assert np.abs(np.asarray(gk) - np.asarray(wk)).max() < 1e-6, n_valid
    assert np.abs(np.asarray(gv) - np.asarray(wv)).max() < 1e-6, n_valid
print("SHARDED_DECODE_OK")
"""


def test_seq_sharded_decode_attention_subprocess(subproc):
    out = subproc(SHARDED_DECODE_CODE, devices=8)
    assert "SHARDED_DECODE_OK" in out


# ---------------------------------------------------------------------------
# cache_shardings: one function, classified by leaf name + path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_quant", [False, True])
@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "xlstm-1.3b", "recurrentgemma-9b"]
)
def test_cache_shardings_every_kind(arch, kv_quant):
    """KV leaves (values AND int8 scales): batch over DP + S over TP.
    Recurrent/conv states and ``pos``: batch over DP only — the size-3
    conv axis of stacked ``(U, B, 3, d)`` caches must never hit TP (the
    old shape-sniffing classifier sharded it)."""
    from repro.launch.mesh import make_mesh

    cfg = get_config(arch, smoke=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S_PRE, kv_quant=kv_quant)
    )
    sh = engine.cache_shardings(cache, ctx, B)
    seen = set()
    for path, spec in jax.tree_util.tree_leaves_with_path(sh):
        name = engine._leaf_key(path[-1])
        entries = tuple(spec.spec)
        ndim = len(entries)
        if name in engine._KV_LEAF_KEYS:
            seen.add("kv")
            assert entries[-2] == "model", (path, entries)  # S over TP
            assert entries[-4] == "data", (path, entries)  # B over DP
            assert all(
                e is None for i, e in enumerate(entries)
                if i not in (ndim - 2, ndim - 4)
            ), (path, entries)
        else:
            seen.add(name)
            ax = engine.cache_batch_axis(path)
            assert "model" not in entries, (path, entries)
            assert entries[ax] == "data", (path, entries)
            assert all(
                e is None for i, e in enumerate(entries) if i != ax
            ), (path, entries)
    assert "pos" in seen
    if arch == "llama3.2-1b":
        assert "kv" in seen
    if arch == "recurrentgemma-9b":
        assert {"kv", "conv", "h"} <= seen  # mixed attn + rglru stack
    if arch == "xlstm-1.3b":
        assert "conv" in seen and ("c" in seen or "h" in seen)


def test_cache_shardings_engine_is_the_only_impl():
    """The dryrun duplicate must delegate to the engine's classifier."""
    from repro.launch import dryrun
    from repro.launch.mesh import make_mesh

    cfg = get_config("xlstm-1.3b", smoke=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = ParallelCtx(mesh=mesh)
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S_PRE))
    a = dryrun._cache_shardings(cache, ctx, B)
    b = engine.cache_shardings(cache, ctx, B)
    assert jax.tree.map(lambda x, y: x == y, a, b)
    assert all(jax.tree.leaves(jax.tree.map(lambda x, y: x == y, a, b)))


# ---------------------------------------------------------------------------
# capacity: over-capacity writes are dropped, never clamped onto the
# final slot (regression: the old ``jnp.minimum(pos, s_c - 1)`` clamp
# silently overwrote the last KV slot forever)
# ---------------------------------------------------------------------------


def test_decode_past_capacity_drops_writes():
    cfg = _fp32_nodrop(get_config("llama3.2-1b", smoke=True))
    assert cfg.window is None
    params = init_model(jax.random.PRNGKey(0), cfg, CTX)
    max_len = S_PRE + 2
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (B, max_len + 3), 0, cfg.vocab_size
    )
    _, cache = prefill(
        params, {"tokens": toks[:, :S_PRE]}, cfg, CTX, max_len=max_len
    )
    for t in range(2):  # fill to exactly max_len
        _, cache = decode_step(params, cache, toks[:, S_PRE + t], cfg, CTX)
    full = jax.tree.map(np.asarray, cache)
    assert int(full["pos"][0]) == max_len
    # decoding past capacity must leave every KV slot intact
    _, over = decode_step(params, cache, toks[:, max_len], cfg, CTX)
    for key in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(over["units"]["b0"][key]), full["units"]["b0"][key]
        )
    assert int(np.asarray(over["pos"])[0]) == max_len + 1


def test_scheduler_raises_capacity_error():
    from repro.serve.scheduler import Request, Scheduler

    cfg = get_config("llama3.2-1b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg, CTX)
    sched = Scheduler(params, cfg, CTX, n_slots=1, max_len=8)
    req = Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                  max_new_tokens=5)  # 6 + 5 > 8
    with pytest.raises(engine.CacheCapacityError):
        sched.submit(req)


# ---------------------------------------------------------------------------
# per-slot position vectors: rows decode at independent depths
# ---------------------------------------------------------------------------


def test_ragged_positions_match_individual_decode():
    """Merge two batch-1 caches at different prefill depths into one
    batch-2 cache; one ragged decode_step must equal the two individual
    steps (the refactor continuous batching is built on)."""
    cfg = _fp32_nodrop(get_config("llama3.2-1b", smoke=True))
    params = init_model(jax.random.PRNGKey(0), cfg, CTX)
    max_len = S_PRE + N_DEC
    toks = jax.random.randint(
        jax.random.PRNGKey(2), (2, max_len), 0, cfg.vocab_size
    )
    lens = (10, S_PRE)
    singles = [
        prefill(
            params, {"tokens": toks[i: i + 1, : lens[i]]}, cfg, CTX,
            max_len=max_len,
        )
        for i in range(2)
    ]

    def merge(path, a, b):
        ax = engine.cache_batch_axis(path)
        return jnp.concatenate([a, b], axis=ax)

    merged = jax.tree_util.tree_map_with_path(
        merge, singles[0][1], singles[1][1]
    )
    assert np.asarray(merged["pos"]).tolist() == list(lens)
    step_toks = jnp.asarray(
        [int(toks[0, lens[0]]), int(toks[1, lens[1]])], jnp.int32
    )
    logits, merged = decode_step(params, merged, step_toks, cfg, CTX)
    for i in range(2):
        li, _ = decode_step(
            params, singles[i][1], step_toks[i: i + 1], cfg, CTX
        )
        np.testing.assert_allclose(
            np.asarray(logits[i]), np.asarray(li[0]), atol=2e-4, rtol=1e-3
        )


def test_active_mask_freezes_inactive_rows():
    cfg = _fp32_nodrop(get_config("llama3.2-1b", smoke=True))
    params = init_model(jax.random.PRNGKey(0), cfg, CTX)
    toks = jax.random.randint(
        jax.random.PRNGKey(4), (B, S_PRE + 2), 0, cfg.vocab_size
    )
    _, cache = prefill(
        params, {"tokens": toks[:, :S_PRE]}, cfg, CTX, max_len=S_PRE + 2
    )
    _, cache = decode_step(
        params, cache, toks[:, S_PRE], cfg, CTX,
        active=jnp.asarray([1, 0], jnp.int32),
    )
    assert np.asarray(cache["pos"]).tolist() == [S_PRE + 1, S_PRE]


# ---------------------------------------------------------------------------
# real-mesh subprocess coverage: quantized seq-sharded decode + the DP
# divisibility boundary
# ---------------------------------------------------------------------------

QUANT_SHARDED_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.serve.engine import _decode_attention, _quantize_kv
from repro.dist.context import ParallelCtx
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
ctx = ParallelCtx(mesh=mesh, kv_quant=True)
ctx1 = ParallelCtx(mesh=None, kv_quant=True)
rng = np.random.default_rng(0)
B, H, Hkv, S, Dh = 4, 8, 2, 32, 16
q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
k = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
v = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
kq, ks = _quantize_kv(k)
vq, vs = _quantize_kv(v)
kn = jnp.asarray(rng.normal(size=(B, Hkv, 1, Dh)), jnp.float32)
vn = jnp.asarray(rng.normal(size=(B, Hkv, 1, Dh)), jnp.float32)
# ragged per-row positions: the quant + TP LSE-combine path must accept
# (B,) slot / n_valid vectors and match the unsharded engine bit-for-bit
n_valid = jnp.asarray([1, 9, 17, 32], jnp.int32)
slot = n_valid - 1
got = _decode_attention(q, kn, vn, kq, vq, slot, n_valid, ctx, ks, vs)
want = _decode_attention(q, kn, vn, kq, vq, slot, n_valid, ctx1, ks, vs)
for g, w, name in zip(got, want, ("o", "k", "v", "ks", "vs")):
    err = np.abs(np.asarray(g, np.float32) - np.asarray(w, np.float32)).max()
    assert err < (1e-4 if name == "o" else 1e-6), (name, err)
print("QUANT_SHARDED_OK")
"""


def test_quantized_seq_sharded_decode_subprocess(subproc):
    out = subproc(QUANT_SHARDED_CODE, devices=4)
    assert "QUANT_SHARDED_OK" in out


DP_BOUNDARY_CODE = r"""
import warnings
import numpy as np, jax, jax.numpy as jnp
from repro.serve import engine
from repro.serve.engine import _decode_attention
from repro.dist.context import ParallelCtx
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
ctx = ParallelCtx(mesh=mesh)
ctx1 = ParallelCtx(mesh=None)
rng = np.random.default_rng(1)
H, Hkv, S, Dh = 8, 2, 32, 16
for b, should_warn in ((4, False), (3, True)):
    q = jnp.asarray(rng.normal(size=(b, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, Hkv, S, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, Hkv, S, Dh)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, Hkv, 1, Dh)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, Hkv, 1, Dh)), jnp.float32)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got, gk, gv = _decode_attention(
            q, kn, vn, k, v, jnp.int32(7), jnp.int32(8), ctx)
    warned = any("not divisible by dp" in str(w.message) for w in rec)
    assert warned == should_warn, (b, warned)
    want, _, _ = _decode_attention(
        q, kn, vn, k, v, jnp.int32(7), jnp.int32(8), ctx1)
    err = np.abs(np.asarray(got) - np.asarray(want)).max()
    assert err < 1e-4, (b, err)
    # the sharding classifier makes the same call on the same boundary
    cache = {"tail": [{"k": k}], "units": {}, "pos": jnp.zeros((b,), jnp.int32)}
    sh = engine.cache_shardings(cache, ctx, b)
    bs = sh["tail"][0]["k"].spec[0]
    assert (bs is None) == should_warn, (b, bs)
print("DP_BOUNDARY_OK")
"""


def test_dp_divisibility_boundary_subprocess(subproc):
    out = subproc(DP_BOUNDARY_CODE, devices=4)
    assert "DP_BOUNDARY_OK" in out
