"""Shared fixtures + the differential-oracle case builders.

NOTE: no XLA_FLAGS here — tests see the real device count (1);
multi-device behaviour is tested via subprocesses that set
--xla_force_host_platform_device_count themselves.  The tests directory
is put on the subprocess PYTHONPATH so subprocess code can reuse the
oracle helpers (``from conftest import oracle_case, run_strategy``).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
TESTS = os.path.join(REPO, "tests")


def run_subprocess(code: str, devices: int = 8, timeout: int = 600):
    """Run python code in a fresh process with N emulated host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + TESTS
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess


# ---------------------------------------------------------------------------
# Differential-oracle harness (tests/test_oracle.py + subprocess sweeps)
#
# One case builder + one strategy runner shared by every grid, so the
# "strategy x structure x grid vs NumPy" sweep is specified exactly once.
# ---------------------------------------------------------------------------

#: every structure family the planner claims to absorb
ORACLE_FAMILIES = (
    "dense", "random", "banded", "decay", "one_sided", "rank_sparse"
)
#: every execution route the front-ends expose
ORACLE_STRATEGIES = ("procedural", "taskbased", "allgather", "ring", "auto")
#: shared comparison tolerance vs the float64 NumPy reference (all paths
#: accumulate in f32; K=128 keeps accumulation error ~1e-5)
ORACLE_ATOL = 5e-4
ORACLE_RTOL = 1e-4


def _expand(mask: np.ndarray, br: int, bc: int) -> np.ndarray:
    return np.kron(np.asarray(mask, bool), np.ones((br, bc), bool))


def oracle_case(family: str, *, m=64, k=128, n=96, blocks=8, seed=0) -> dict:
    """Build one oracle case: operands, structure, float64 NumPy reference.

    Returns a dict with ``a``/``b`` (float32), the structure arguments to
    pass to ``DistributedMatmul`` (``a_mask``/``b_mask``/``a_ranks``), and
    ``ref`` — the NumPy float64 product of the structure-zeroed operands
    (for ``rank_sparse``, of the densified factorization).
    """
    from repro.core import (
        banded_block_mask,
        decay_block_mask,
        decay_rank_map,
        random_block_mask,
        synthesize_rank_csr,
    )

    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    bm_sz, bk_sz, bn_sz = m // blocks, k // blocks, n // blocks
    a_mask = b_mask = a_ranks = None
    if family == "dense":
        pass
    elif family == "random":
        a_mask = random_block_mask(blocks, blocks, 0.5, seed=seed + 1)
        b_mask = random_block_mask(blocks, blocks, 0.6, seed=seed + 2)
    elif family == "banded":
        a_mask = banded_block_mask(blocks, blocks, 1)
        b_mask = banded_block_mask(blocks, blocks, 2)
    elif family == "decay":
        a_mask = decay_block_mask(blocks, blocks, decay=0.8, threshold=5e-2)
        b_mask = decay_block_mask(blocks, blocks, decay=0.5, threshold=5e-2)
    elif family == "one_sided":
        b_mask = banded_block_mask(blocks, blocks, 2)
    elif family == "rank_sparse":
        rank_map = decay_rank_map(
            blocks, blocks, bm_sz, bk_sz,
            max_rank=max(2, min(bm_sz, bk_sz) // 4),
            decay=0.7, threshold=2e-2,
        )
        a_ranks = synthesize_rank_csr(rank_map, seed=seed + 3)
        a = a_ranks.to_dense()  # dense-stored twin of the factorization
    else:
        raise ValueError(f"unknown oracle family {family!r}")
    a_z = a * _expand(a_mask, bm_sz, bk_sz) if a_mask is not None else a
    b_z = b * _expand(b_mask, bk_sz, bn_sz) if b_mask is not None else b
    ref = a_z.astype(np.float64) @ b_z.astype(np.float64)
    return {
        "family": family,
        "a": a, "b": b,
        "a_mask": a_mask, "b_mask": b_mask, "a_ranks": a_ranks,
        "ref": ref,
        "shape": (m, k, n),
        "blocks": blocks,
    }


def run_strategy(case: dict, mesh, strategy: str, *, row_axis="data",
                 col_axis="model") -> np.ndarray:
    """Execute one oracle case with one strategy on ``mesh``.

    ``procedural``/``taskbased``/``allgather`` go through
    ``DistributedMatmul``; ``auto`` is the tuner-driven route
    (``tune=True``); ``ring`` is the sparsity-blind collective matmul
    (``dist.collective_matmul.allgather_matmul``) fed structure-zeroed
    operands, since it takes no masks by design.
    """
    import jax.numpy as jnp

    from repro.core import DistributedMatmul

    a, b = case["a"], case["b"]
    if strategy == "ring":
        from repro.dist.collective_matmul import allgather_matmul

        blocks = case["blocks"]
        m, k, n = case["shape"]
        a_z = a
        if case["a_mask"] is not None:
            a_z = a * _expand(case["a_mask"], m // blocks, k // blocks)
        b_z = b
        if case["b_mask"] is not None:
            b_z = b * _expand(case["b_mask"], k // blocks, n // blocks)
        return np.asarray(
            allgather_matmul(
                jnp.asarray(a_z), jnp.asarray(b_z),
                mesh=mesh, axis=col_axis, batch_axes=(row_axis,),
            )
        )
    tune = strategy == "auto"
    mm = DistributedMatmul(
        mesh,
        row_axis=row_axis,
        col_axis=col_axis,
        strategy="taskbased" if tune else strategy,
    )
    if case["a_ranks"] is not None:
        out = mm(
            None, jnp.asarray(b), a_ranks=case["a_ranks"],
            b_mask=case["b_mask"], tune=tune,
        )
    else:
        out = mm(
            jnp.asarray(a), jnp.asarray(b),
            a_mask=case["a_mask"], b_mask=case["b_mask"], tune=tune,
        )
    return np.asarray(out)


def check_case(case: dict, got: np.ndarray, label: str = "") -> None:
    np.testing.assert_allclose(
        got, case["ref"], atol=ORACLE_ATOL, rtol=ORACLE_RTOL,
        err_msg=f"oracle mismatch: {label or case['family']}",
    )


#: the subprocess sweep body — one grid per subprocess, full
#: strategy x family cross inside (shared by test_oracle.py)
ORACLE_SWEEP_CODE = r"""
import numpy as np
from conftest import (ORACLE_FAMILIES, ORACLE_STRATEGIES, check_case,
                      oracle_case, run_strategy)
from repro.launch.mesh import make_mesh

grid = ({p_row}, {p_col})
mesh = make_mesh(grid, ("data", "model"))
for family in ORACLE_FAMILIES:
    case = oracle_case(family, seed=7)
    for strategy in ORACLE_STRATEGIES:
        got = run_strategy(case, mesh, strategy)
        check_case(case, got, f"{{family}}/{{strategy}}/{p_row}x{p_col}")
print("ORACLE_SWEEP_OK")
"""
