"""Shared fixtures + the differential-oracle case builders.

NOTE: no XLA_FLAGS here — tests see the real device count (1);
multi-device behaviour is tested via subprocesses that set
--xla_force_host_platform_device_count themselves.  The tests directory
is put on the subprocess PYTHONPATH so subprocess code can reuse the
oracle helpers (``from conftest import oracle_case, run_strategy``).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
TESTS = os.path.join(REPO, "tests")


def run_subprocess(code: str, devices: int = 8, timeout: int = 600):
    """Run python code in a fresh process with N emulated host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + TESTS
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess


# ---------------------------------------------------------------------------
# Differential-oracle harness (tests/test_oracle.py + subprocess sweeps)
#
# One case builder + one strategy runner shared by every grid, so the
# "strategy x structure x grid vs NumPy" sweep is specified exactly once.
# ---------------------------------------------------------------------------

#: every structure family the planner claims to absorb
ORACLE_FAMILIES = (
    "dense", "random", "banded", "decay", "one_sided", "rank_sparse"
)
#: every execution route the front-ends expose
ORACLE_STRATEGIES = ("procedural", "taskbased", "allgather", "ring", "auto")
#: shared comparison tolerance vs the float64 NumPy reference (all paths
#: accumulate in f32; K=128 keeps accumulation error ~1e-5)
ORACLE_ATOL = 5e-4
ORACLE_RTOL = 1e-4


def _expand(mask: np.ndarray, br: int, bc: int) -> np.ndarray:
    return np.kron(np.asarray(mask, bool), np.ones((br, bc), bool))


def oracle_case(family: str, *, m=64, k=128, n=96, blocks=8, seed=0) -> dict:
    """Build one oracle case: operands, structure, float64 NumPy reference.

    Returns a dict with ``a``/``b`` (float32), the structure arguments to
    pass to ``DistributedMatmul`` (``a_mask``/``b_mask``/``a_ranks``), and
    ``ref`` — the NumPy float64 product of the structure-zeroed operands
    (for ``rank_sparse``, of the densified factorization).
    """
    from repro.core import (
        banded_block_mask,
        decay_block_mask,
        decay_rank_map,
        random_block_mask,
        synthesize_rank_csr,
    )

    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    bm_sz, bk_sz, bn_sz = m // blocks, k // blocks, n // blocks
    a_mask = b_mask = a_ranks = None
    if family == "dense":
        pass
    elif family == "random":
        a_mask = random_block_mask(blocks, blocks, 0.5, seed=seed + 1)
        b_mask = random_block_mask(blocks, blocks, 0.6, seed=seed + 2)
    elif family == "banded":
        a_mask = banded_block_mask(blocks, blocks, 1)
        b_mask = banded_block_mask(blocks, blocks, 2)
    elif family == "decay":
        a_mask = decay_block_mask(blocks, blocks, decay=0.8, threshold=5e-2)
        b_mask = decay_block_mask(blocks, blocks, decay=0.5, threshold=5e-2)
    elif family == "one_sided":
        b_mask = banded_block_mask(blocks, blocks, 2)
    elif family == "rank_sparse":
        rank_map = decay_rank_map(
            blocks, blocks, bm_sz, bk_sz,
            max_rank=max(2, min(bm_sz, bk_sz) // 4),
            decay=0.7, threshold=2e-2,
        )
        a_ranks = synthesize_rank_csr(rank_map, seed=seed + 3)
        a = a_ranks.to_dense()  # dense-stored twin of the factorization
    else:
        raise ValueError(f"unknown oracle family {family!r}")
    a_z = a * _expand(a_mask, bm_sz, bk_sz) if a_mask is not None else a
    b_z = b * _expand(b_mask, bk_sz, bn_sz) if b_mask is not None else b
    ref = a_z.astype(np.float64) @ b_z.astype(np.float64)
    return {
        "family": family,
        "a": a, "b": b,
        "a_mask": a_mask, "b_mask": b_mask, "a_ranks": a_ranks,
        "ref": ref,
        "shape": (m, k, n),
        "blocks": blocks,
    }


def run_strategy(case: dict, mesh, strategy: str, *, row_axis="data",
                 col_axis="model", compiled: bool = True) -> np.ndarray:
    """Execute one oracle case with one strategy on ``mesh``.

    ``procedural``/``taskbased``/``allgather`` go through
    ``DistributedMatmul``; ``auto`` is the tuner-driven route
    (``tune=True``); ``ring`` is the sparsity-blind collective matmul
    (``dist.collective_matmul.allgather_matmul``) fed structure-zeroed
    operands, since it takes no masks by design.  ``compiled=False``
    forces the eager plan interpreters (bypassing the executable cache)
    for compiled-vs-eager differential tests; ``ring`` ignores it.
    """
    import jax.numpy as jnp

    from repro.core import DistributedMatmul

    a, b = case["a"], case["b"]
    if strategy == "ring":
        from repro.dist.collective_matmul import allgather_matmul

        blocks = case["blocks"]
        m, k, n = case["shape"]
        a_z = a
        if case["a_mask"] is not None:
            a_z = a * _expand(case["a_mask"], m // blocks, k // blocks)
        b_z = b
        if case["b_mask"] is not None:
            b_z = b * _expand(case["b_mask"], k // blocks, n // blocks)
        return np.asarray(
            allgather_matmul(
                jnp.asarray(a_z), jnp.asarray(b_z),
                mesh=mesh, axis=col_axis, batch_axes=(row_axis,),
            )
        )
    tune = strategy == "auto"
    mm = DistributedMatmul(
        mesh,
        row_axis=row_axis,
        col_axis=col_axis,
        strategy="taskbased" if tune else strategy,
        compiled=compiled,
    )
    if case["a_ranks"] is not None:
        out = mm(
            None, jnp.asarray(b), a_ranks=case["a_ranks"],
            b_mask=case["b_mask"], tune=tune,
        )
    else:
        out = mm(
            jnp.asarray(a), jnp.asarray(b),
            a_mask=case["a_mask"], b_mask=case["b_mask"], tune=tune,
        )
    return np.asarray(out)


# ---------------------------------------------------------------------------
# SpGEMM differential oracle (structure on BOTH operands, repro.spgemm)
# ---------------------------------------------------------------------------

#: every sparse x sparse structure pairing the planner claims to absorb
SPGEMM_FAMILIES = (
    "banded_banded", "random_random", "blockdiag_blockdiag", "rank_random"
)
#: both comm schedules of the masked pipeline
SPGEMM_COMM_MODES = ("broadcast", "pull")


def spgemm_case(family: str, *, m=64, k=128, n=96, blocks=8, seed=0) -> dict:
    """Build one sparse x sparse case: structure on both operands, the
    inferred output mask from the symbolic pass
    (``repro.spgemm.output_mask``), and the float64 NumPy reference of
    the structure-zeroed product."""
    from repro.core import (
        banded_block_mask,
        block_diag_block_mask,
        decay_rank_map,
        random_block_mask,
        synthesize_rank_csr,
    )
    from repro.spgemm import output_mask

    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    bm_sz, bk_sz, bn_sz = m // blocks, k // blocks, n // blocks
    a_mask = a_ranks = None
    if family == "banded_banded":
        a_mask = banded_block_mask(blocks, blocks, 1)
        b_mask = banded_block_mask(blocks, blocks, 1)
    elif family == "random_random":
        a_mask = random_block_mask(blocks, blocks, 0.3, seed=seed + 1)
        b_mask = random_block_mask(blocks, blocks, 0.3, seed=seed + 2)
    elif family == "blockdiag_blockdiag":
        a_mask = block_diag_block_mask(blocks, blocks)
        b_mask = block_diag_block_mask(blocks, blocks)
    elif family == "rank_random":
        rank_map = decay_rank_map(
            blocks, blocks, bm_sz, bk_sz,
            max_rank=max(2, min(bm_sz, bk_sz) // 4),
            decay=0.7, threshold=2e-2,
        )
        a_ranks = synthesize_rank_csr(rank_map, seed=seed + 3)
        a = a_ranks.to_dense()  # dense-stored twin of the factorization
        b_mask = random_block_mask(blocks, blocks, 0.4, seed=seed + 2)
    else:
        raise ValueError(f"unknown spgemm family {family!r}")
    c_mask = output_mask(
        a_ranks.rank_map() if a_ranks is not None else a_mask, b_mask
    )
    a_z = a * _expand(a_mask, bm_sz, bk_sz) if a_mask is not None else a
    b_z = b * _expand(b_mask, bk_sz, bn_sz)
    ref = a_z.astype(np.float64) @ b_z.astype(np.float64)
    return {
        "family": family,
        "a": a, "b": b,
        "a_mask": a_mask, "b_mask": b_mask, "a_ranks": a_ranks,
        "c_mask": c_mask,
        "ref": ref,
        "shape": (m, k, n),
        "blocks": blocks,
    }


def run_spgemm(case: dict, mesh, comm_mode: str, *, row_axis="data",
               col_axis="model", compiled: bool = True) -> np.ndarray:
    """Execute one SpGEMM case through ``DistributedMatmul`` under the
    given comm schedule, feeding back the inferred output mask."""
    import jax.numpy as jnp

    from repro.core import DistributedMatmul

    mm = DistributedMatmul(
        mesh, row_axis=row_axis, col_axis=col_axis, strategy="taskbased",
        compiled=compiled,
    )
    if case["a_ranks"] is not None:
        out = mm(
            None, jnp.asarray(case["b"]), a_ranks=case["a_ranks"],
            b_mask=case["b_mask"], c_mask=case["c_mask"],
            comm_mode=comm_mode,
        )
    else:
        out = mm(
            jnp.asarray(case["a"]), jnp.asarray(case["b"]),
            a_mask=case["a_mask"], b_mask=case["b_mask"],
            c_mask=case["c_mask"], comm_mode=comm_mode,
        )
    return np.asarray(out)


def check_case(case: dict, got: np.ndarray, label: str = "") -> None:
    np.testing.assert_allclose(
        got, case["ref"], atol=ORACLE_ATOL, rtol=ORACLE_RTOL,
        err_msg=f"oracle mismatch: {label or case['family']}",
    )


# ---------------------------------------------------------------------------
# Contraction differential oracle (repro.contract vs float64 np.einsum)
#
# One case builder + one runner, shared between the in-process 1x1 sweep
# and the real-mesh subprocess sweeps, mirroring the matmul oracle above.
# ---------------------------------------------------------------------------

#: every contraction family the front-end claims to absorb
CONTRACT_SPECS = (
    "matmul",            # ab,bc->ac       pure matmul, masks both sides
    "free2",             # abc,cd->abd     merged free modes on x
    "multi_contracted",  # abc,bcd->ad     two contracted modes merge
    "transpose",         # ab,ca->cb       both operands need transposes
    "batch",             # sab,sbc->sac    true einsum batch mode
    "rank_sparse",       # ab,bc->ac       x is a RankCSR factor payload
    "nonuniform",        # ab,bc->ac       nonuniform mode extents + x mask
)


def contract_case(name: str, *, seed: int = 0) -> dict:
    """Build one contraction oracle case: operands (as
    ``BlockSparseTensor``), the spec, and the float64 ``np.einsum``
    reference of the structure-zeroed operands."""
    import jax.numpy as jnp

    from repro.core import (
        BlockSparseTensor,
        banded_block_mask,
        decay_rank_map,
        nonuniform_tiling,
        synthesize_rank_csr,
    )

    rng = np.random.default_rng(seed)

    def dense(shape, block_shape, mask=None):
        data = rng.normal(size=shape).astype(np.float32)
        return BlockSparseTensor.from_dense(
            jnp.asarray(data), block_shape=block_shape, mask=mask
        )

    tile = 64
    if name == "matmul":
        spec = "ab,bc->ac"
        x = dense((64, 96), (16, 12), mask=banded_block_mask(4, 8, 2))
        y = dense((96, 80), (12, 20), mask=rng.random((8, 4)) < 0.6)
    elif name == "free2":
        spec = "abc,cd->abd"
        x = dense((8, 16, 96), (4, 8, 12), mask=rng.random((2, 2, 8)) < 0.6)
        y = dense((96, 80), (12, 20), mask=rng.random((8, 4)) < 0.7)
    elif name == "multi_contracted":
        spec = "abc,bcd->ad"
        x = dense((64, 8, 24), (16, 4, 6), mask=rng.random((4, 2, 4)) < 0.7)
        y = dense((8, 24, 40), (4, 6, 20))
    elif name == "transpose":
        spec = "ab,ca->cb"
        x = dense((64, 48), (16, 12), mask=rng.random((4, 4)) < 0.7)
        y = dense((40, 64), (20, 16), mask=rng.random((2, 4)) < 0.7)
    elif name == "batch":
        spec = "sab,sbc->sac"
        x = dense((4, 16, 24), (2, 8, 6), mask=rng.random((2, 2, 4)) < 0.6)
        y = dense((4, 24, 32), (2, 6, 8))
    elif name == "rank_sparse":
        spec = "ab,bc->ac"
        rank_map = decay_rank_map(4, 8, 16, 12, max_rank=4, decay=0.6)
        x = BlockSparseTensor.from_rank_csr(
            synthesize_rank_csr(rank_map, seed=seed + 3)
        )
        y = dense((96, 80), (12, 20), mask=rng.random((8, 4)) < 0.7)
    elif name == "nonuniform":
        spec = "ab,bc->ac"
        rt = nonuniform_tiling(70, 5, seed=seed + 1)
        it = nonuniform_tiling(90, 6, seed=seed + 2)
        ct = nonuniform_tiling(60, 4, seed=seed + 3)
        x = BlockSparseTensor(
            data=jnp.asarray(rng.normal(size=(70, 90)).astype(np.float32)),
            tilings=(rt, it),
            mask=rng.random((5, 6)) < 0.7,
        )
        y = BlockSparseTensor(
            data=jnp.asarray(rng.normal(size=(90, 60)).astype(np.float32)),
            tilings=(it, ct),
        )
        tile = 16
    else:
        raise ValueError(f"unknown contraction family {name!r}")
    ref = np.einsum(
        spec,
        x.to_dense().astype(np.float64),
        y.to_dense().astype(np.float64),
    )
    return {"family": name, "spec": spec, "x": x, "y": y, "ref": ref,
            "tile": tile}


def run_contract(case: dict, mesh, *, row_axis="data",
                 col_axis="model", compiled: bool = True) -> np.ndarray:
    """Execute one contraction case on ``mesh`` through the front-end.

    ``compiled=False`` forces the eager per-step execution path
    (bypassing the contraction executable cache) so tests can compare
    compiled vs eager results bitwise."""
    from repro.core import DistributedMatmul

    mm = DistributedMatmul(
        mesh, row_axis=row_axis, col_axis=col_axis, strategy="taskbased",
        compiled=compiled,
    )
    out = mm.contract(
        case["spec"], case["x"], case["y"], tile=case["tile"]
    )
    return np.asarray(out.data)


def check_contract_case(case: dict, got: np.ndarray, label: str = "") -> None:
    np.testing.assert_allclose(
        got, case["ref"], atol=ORACLE_ATOL, rtol=ORACLE_RTOL,
        err_msg=f"contraction oracle mismatch: {label or case['family']}",
    )


#: the contraction subprocess sweep body — one grid per subprocess
CONTRACT_SWEEP_CODE = r"""
import numpy as np
from conftest import (CONTRACT_SPECS, check_contract_case, contract_case,
                      run_contract)
from repro.launch.mesh import make_mesh

grid = ({p_row}, {p_col})
mesh = make_mesh(grid, ("data", "model"))
for family in CONTRACT_SPECS:
    case = contract_case(family, seed=11)
    got = run_contract(case, mesh)
    check_contract_case(case, got, f"{{family}}/{p_row}x{p_col}")
print("CONTRACT_SWEEP_OK")
"""


#: the SpGEMM subprocess sweep body — one grid per subprocess, full
#: family x comm-mode cross inside (shared by test_spgemm.py)
SPGEMM_SWEEP_CODE = r"""
import numpy as np
from conftest import (SPGEMM_COMM_MODES, SPGEMM_FAMILIES, check_case,
                      run_spgemm, spgemm_case)
from repro.launch.mesh import make_mesh

grid = ({p_row}, {p_col})
mesh = make_mesh(grid, ("data", "model"))
for family in SPGEMM_FAMILIES:
    case = spgemm_case(family, seed=13)
    for mode in SPGEMM_COMM_MODES:
        got = run_spgemm(case, mesh, mode)
        check_case(case, got, f"{{family}}/{{mode}}/{p_row}x{p_col}")
print("SPGEMM_SWEEP_OK")
"""


#: the subprocess sweep body — one grid per subprocess, full
#: strategy x family cross inside (shared by test_oracle.py)
ORACLE_SWEEP_CODE = r"""
import numpy as np
from conftest import (ORACLE_FAMILIES, ORACLE_STRATEGIES, check_case,
                      oracle_case, run_strategy)
from repro.launch.mesh import make_mesh

grid = ({p_row}, {p_col})
mesh = make_mesh(grid, ("data", "model"))
for family in ORACLE_FAMILIES:
    case = oracle_case(family, seed=7)
    for strategy in ORACLE_STRATEGIES:
        got = run_strategy(case, mesh, strategy)
        check_case(case, got, f"{{family}}/{{strategy}}/{p_row}x{p_col}")
print("ORACLE_SWEEP_OK")
"""
