"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the real device
count (1); multi-device behaviour is tested via subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 600):
    """Run python code in a fresh process with N emulated host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
