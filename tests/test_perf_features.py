"""Correctness of the §Perf optimizations: chunked attention (custom VJP),
chunkwise mLSTM, ZeRO-1 state sharding — each must match its baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import flash_attention_ref
from repro.models.chunked_attention import chunked_attention
from repro.models.recurrent import _mlstm_core, _mlstm_core_chunked

RNG = np.random.default_rng(7)


@pytest.mark.parametrize(
    "causal,window,cq,ck",
    [(True, None, 64, 64), (True, 96, 64, 32), (False, None, 128, 64)],
)
def test_chunked_attention_fwd_and_grad(causal, window, cq, ck):
    b, h, hkv, s, dh = 2, 4, 2, 256, 32
    q = jnp.asarray(RNG.normal(size=(b, h, s, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)), jnp.float32)
    out = chunked_attention(
        q, k, v, causal=causal, window=window, chunk_q=cq, chunk_k=ck
    )
    want = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def loss(fn):
        return lambda q, k, v: jnp.sum(fn(q, k, v) ** 2)

    g1 = jax.grad(
        loss(
            lambda q, k, v: chunked_attention(
                q, k, v, causal=causal, window=window, chunk_q=cq, chunk_k=ck
            )
        ),
        argnums=(0, 1, 2),
    )(q, k, v)
    g2 = jax.grad(
        loss(lambda q, k, v: flash_attention_ref(q, k, v, causal=causal, window=window)),
        argnums=(0, 1, 2),
    )(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-4)


@pytest.mark.parametrize("chunk", [16, 64, 128])
def test_chunkwise_mlstm_matches_parallel(chunk):
    b, h, s, dh = 2, 3, 128, 32
    q, k, v = (
        jnp.asarray(RNG.normal(size=(b, h, s, dh)), jnp.float32) for _ in range(3)
    )
    i_pre = jnp.asarray(RNG.normal(size=(b, h, s)), jnp.float32)
    f_pre = jnp.asarray(RNG.normal(size=(b, h, s)) + 2.0, jnp.float32)
    full = _mlstm_core(q, k, v, i_pre, f_pre)
    ch = _mlstm_core_chunked(q, k, v, i_pre, f_pre, chunk)
    rel = float(jnp.abs(full - ch).max() / jnp.abs(full).max())
    assert rel < 1e-4, rel


def test_chunkwise_mlstm_grad():
    b, h, s, dh = 1, 2, 64, 16
    q, k, v = (
        jnp.asarray(RNG.normal(size=(b, h, s, dh)), jnp.float32) for _ in range(3)
    )
    i_pre = jnp.asarray(RNG.normal(size=(b, h, s)), jnp.float32)
    f_pre = jnp.asarray(RNG.normal(size=(b, h, s)) + 2.0, jnp.float32)
    g1 = jax.grad(lambda q: jnp.sum(_mlstm_core(q, k, v, i_pre, f_pre) ** 2))(q)
    g2 = jax.grad(
        lambda q: jnp.sum(_mlstm_core_chunked(q, k, v, i_pre, f_pre, 16) ** 2)
    )(q)
    rel = float(jnp.abs(g1 - g2).max() / jnp.abs(g1).max())
    assert rel < 1e-3, rel


def test_int8_kv_cache_accuracy():
    """int8 KV (per-token-per-head scales): logits within a few percent of
    the fp cache and greedy tokens overwhelmingly agree."""
    import dataclasses

    from repro.configs import get_config
    from repro.dist.context import ParallelCtx
    from repro.models.model import init_model
    from repro.serve.engine import decode_step, prefill

    cfg = dataclasses.replace(get_config("llama3.2-1b", smoke=True), dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg, ParallelCtx(mesh=None))
    s_pre, n_dec, b = 24, 4, 4
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (b, s_pre + n_dec), 0, cfg.vocab_size
    )
    outs = {}
    for quant in (False, True):
        ctx = ParallelCtx(mesh=None, kv_quant=quant)
        lp, cache = prefill(
            params, {"tokens": toks[:, :s_pre]}, cfg, ctx, max_len=s_pre + n_dec
        )
        ls = [np.asarray(lp)]
        for t in range(n_dec):
            lp, cache = decode_step(params, cache, toks[:, s_pre + t], cfg, ctx)
            ls.append(np.asarray(lp))
        outs[quant] = np.stack(ls)
    scale = np.abs(outs[False]).max()
    rel = np.abs(outs[True] - outs[False]).max() / scale
    assert rel < 0.05, rel
    agree = (outs[True].argmax(-1) == outs[False].argmax(-1)).mean()
    assert agree >= 0.9, agree
    # cache really is int8
    ctx = ParallelCtx(mesh=None, kv_quant=True)
    _, cache = prefill(params, {"tokens": toks[:, :s_pre]}, cfg, ctx, max_len=64)
    assert cache["units"]["b0"]["k"].dtype == jnp.int8


ZERO1_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.dist.context import ParallelCtx
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train import train_step as ts
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_config("llama3.2-1b", smoke=True)
opt = make_optimizer(OptimizerConfig(total_steps=10, warmup_steps=1))
rng = jax.random.PRNGKey(0)
batch = {
  "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size),
  "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab_size),
}
results = {}
with mesh:
    for zero1 in (False, True):
        ctx = ParallelCtx(mesh=mesh, zero1=zero1)
        abstract = ts.abstract_train_state(rng, cfg, ctx, opt)
        st_sh = ts.state_shardings(abstract, ctx)
        state = jax.jit(lambda r: ts.make_train_state(r, cfg, ctx, opt),
                        out_shardings=st_sh)(rng)
        step = ts.build_train_step(cfg, ctx, opt, microbatches=2)
        b_sh = ts.batch_shardings(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch), ctx)
        jitted = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
        b_dev = jax.tree.map(jax.device_put, batch, b_sh)
        for _ in range(3):
            state, metrics = jitted(state, b_dev)
        results[zero1] = (float(metrics["loss"]),
                          np.asarray(jax.device_get(state["params"]["final_norm"]["scale"])))
l0, p0 = results[False]
l1, p1 = results[True]
assert abs(l0 - l1) < 1e-3, (l0, l1)
np.testing.assert_allclose(p0, p1, atol=1e-3)
print("ZERO1_OK")
"""


def test_zero1_matches_fsdp_subprocess(subproc):
    out = subproc(ZERO1_CODE, devices=8, timeout=900)
    assert "ZERO1_OK" in out
