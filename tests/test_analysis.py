"""HLO analysis: weighting math on a synthetic module + a real lowered
scan (trip-count weighting of dot FLOPs)."""
import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze_hlo, roofline

SYNTH = """\
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %i0 = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%i0, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"},"known_init_step":{"init":"0","step":"1"}}
  %g = f32[8,8]{1,0} get-tuple-element(%w), index=1
  ROOT %d2 = f32[8,8]{1,0} dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_synthetic_module_weighting():
    wc = analyze_hlo(SYNTH)
    one_dot = 2 * 8 * 8 * 8
    assert wc.flops == 5 * one_dot + one_dot  # loop body x5 + entry dot
    assert wc.coll_bytes_by_op["all-reduce"] == 5 * 8 * 8 * 4
    assert wc.coll_counts_by_op["all-reduce"] == 5


def test_real_scan_weighting():
    """A scan of N matmuls must report ~N x the flops of one matmul."""
    n, d = 7, 64

    def f(x):
        def body(c, _):
            return jnp.dot(c, c, preferred_element_type=jnp.float32), None

        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    hlo = jax.jit(f).lower(jnp.ones((d, d), jnp.float32)).compile().as_text()
    wc = analyze_hlo(hlo)
    one = 2 * d**3
    assert wc.flops >= n * one * 0.99, (wc.flops, n * one)
    assert wc.flops <= n * one * 1.5


def test_roofline_terms_and_dominance():
    rep = roofline(
        flops=197e12, hbm_bytes=819e9 / 2, coll_bytes=0.0, chips=4,
        model_flops=4 * 197e12 * 0.8,
    )
    assert abs(rep.compute_s - 1.0) < 1e-9
    assert abs(rep.memory_s - 0.5) < 1e-9
    assert rep.dominant == "compute"
    assert abs(rep.useful_ratio - 0.8) < 1e-9
