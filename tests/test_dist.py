"""Sharding rules: every assigned arch's param tree gets valid,
divisibility-safe shardings on the production meshes (no allocation —
pure spec checks against eval_shape trees)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.dist.context import ParallelCtx
from repro.dist.partitioning import _validate_spec, param_shardings, param_specs
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_model

from jax.sharding import PartitionSpec as P


class FakeMesh:
    def __init__(self, sizes):
        self.shape = sizes


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_divide_production_mesh(arch):
    """Every sharded dim must divide its mesh-axis size on 16x16."""
    cfg = get_config(arch)  # FULL config
    ctx = ParallelCtx(mesh=None)
    params = jax.eval_shape(
        lambda r: init_model(r, cfg, ctx), jax.random.PRNGKey(0)
    )
    specs = param_specs(params)
    mesh = FakeMesh({"data": 16, "model": 16, "pod": 2})
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_s = treedef.flatten_up_to(specs)
    n_sharded = 0
    for p, s in zip(flat_p, flat_s):
        v = _validate_spec(s, p.shape, mesh)
        for dim, entry in zip(p.shape, tuple(v)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0
            n_sharded += 1
    assert n_sharded > 0  # the rules actually fire


def test_big_matrices_are_sharded():
    cfg = get_config("qwen2.5-32b")
    ctx = ParallelCtx(mesh=None)
    params = jax.eval_shape(
        lambda r: init_model(r, cfg, ctx), jax.random.PRNGKey(0)
    )
    specs = param_specs(params)
    # FFN up-projection: stacked + (data, model)
    assert specs["units"]["b0"]["ffn"]["w_up"]["w"] == P(None, "data", "model")
    assert specs["units"]["b0"]["attn"]["wq"]["w"] == P(None, "data", "model")
    assert specs["embed"]["embedding"] == P("model", "data")


def test_moe_experts_sharded_over_model():
    cfg = get_config("kimi-k2-1t-a32b")
    ctx = ParallelCtx(mesh=None)
    params = jax.eval_shape(
        lambda r: init_model(r, cfg, ctx), jax.random.PRNGKey(0)
    )
    specs = param_specs(params)
    assert specs["units"]["b0"]["moe"]["w_gate"] == P(None, "model", "data", None)
    assert specs["units"]["b0"]["moe"]["w_down"] == P(None, "model", None, "data")


def test_validate_spec_drops_indivisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    out = _validate_spec(P("data", "model"), (504, 64), mesh)
    assert out == P(None, "model")  # 504 % 16 != 0 -> replicated dim


def test_shardings_build_on_host_mesh():
    cfg = get_config("llama3.2-1b", smoke=True)
    mesh = make_host_mesh(1, 1)
    ctx = ParallelCtx(mesh=mesh)
    params = init_model(jax.random.PRNGKey(0), cfg, ctx)
    sh = param_shardings(params, mesh)
    assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(params))
