"""Sharding rules: every assigned arch's param tree gets valid,
divisibility-safe shardings on the production meshes (no allocation —
pure spec checks against eval_shape trees)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.dist.context import ParallelCtx
from repro.dist.partitioning import _validate_spec, param_shardings, param_specs
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_model

from jax.sharding import PartitionSpec as P


class FakeMesh:
    def __init__(self, sizes):
        self.shape = sizes


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_divide_production_mesh(arch):
    """Every sharded dim must divide its mesh-axis size on 16x16."""
    cfg = get_config(arch)  # FULL config
    ctx = ParallelCtx(mesh=None)
    params = jax.eval_shape(
        lambda r: init_model(r, cfg, ctx), jax.random.PRNGKey(0)
    )
    specs = param_specs(params)
    mesh = FakeMesh({"data": 16, "model": 16, "pod": 2})
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_s = treedef.flatten_up_to(specs)
    n_sharded = 0
    for p, s in zip(flat_p, flat_s):
        v = _validate_spec(s, p.shape, mesh)
        for dim, entry in zip(p.shape, tuple(v)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0
            n_sharded += 1
    assert n_sharded > 0  # the rules actually fire


def test_big_matrices_are_sharded():
    cfg = get_config("qwen2.5-32b")
    ctx = ParallelCtx(mesh=None)
    params = jax.eval_shape(
        lambda r: init_model(r, cfg, ctx), jax.random.PRNGKey(0)
    )
    specs = param_specs(params)
    # FFN up-projection: stacked + (data, model)
    assert specs["units"]["b0"]["ffn"]["w_up"]["w"] == P(None, "data", "model")
    assert specs["units"]["b0"]["attn"]["wq"]["w"] == P(None, "data", "model")
    assert specs["embed"]["embedding"] == P("model", "data")


def test_moe_experts_sharded_over_model():
    cfg = get_config("kimi-k2-1t-a32b")
    ctx = ParallelCtx(mesh=None)
    params = jax.eval_shape(
        lambda r: init_model(r, cfg, ctx), jax.random.PRNGKey(0)
    )
    specs = param_specs(params)
    assert specs["units"]["b0"]["moe"]["w_gate"] == P(None, "model", "data", None)
    assert specs["units"]["b0"]["moe"]["w_down"] == P(None, "model", None, "data")


def test_validate_spec_drops_indivisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    out = _validate_spec(P("data", "model"), (504, 64), mesh)
    assert out == P(None, "model")  # 504 % 16 != 0 -> replicated dim


def test_shardings_build_on_host_mesh():
    cfg = get_config("llama3.2-1b", smoke=True)
    mesh = make_host_mesh(1, 1)
    ctx = ParallelCtx(mesh=mesh)
    params = init_model(jax.random.PRNGKey(0), cfg, ctx)
    sh = param_shardings(params, mesh)
    assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(params))


def test_validate_spec_rejects_unknown_axis():
    mesh = FakeMesh({"data": 4, "model": 4})
    with pytest.raises(ValueError, match="unknown mesh axis"):
        _validate_spec(P("expert", None), (64, 64), mesh)


def test_validate_spec_rejects_oversharded():
    mesh = FakeMesh({"data": 4, "model": 4})
    with pytest.raises(ValueError, match="over-sharded"):
        _validate_spec(P("data", "model", None), (64, 64), mesh)


def test_param_shardings_roundtrip_host_mesh():
    """Placing a small model with its inferred shardings on a 1x1 CPU mesh
    must preserve every leaf bit-for-bit."""
    cfg = get_config("llama3.2-1b", smoke=True)
    mesh = make_host_mesh(1, 1)
    ctx = ParallelCtx(mesh=mesh)
    params = init_model(jax.random.PRNGKey(0), cfg, ctx)
    placed = jax.tree.map(jax.device_put, params, param_shardings(params, mesh))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(placed)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_ctx_matmul_factory_and_project():
    """ParallelCtx.matmul() wires the task-based SUMMA engine; project()
    routes (B, S, D) activations through it and matches the einsum path."""
    import jax.numpy as jnp

    from repro.core.api import DistributedMatmul
    from repro.dist.collective_matmul import project

    mesh = make_host_mesh(1, 1)
    ctx = ParallelCtx(mesh=mesh, matmul_strategy="summa")
    mm = ctx.matmul()
    assert isinstance(mm, DistributedMatmul)
    assert mm.strategy == "taskbased"
    assert mm is ctx.matmul()  # cached: one engine per context
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, 16)), jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).normal(size=(16, 12)), jnp.float32)
    got = project(x, w, ctx)
    want = jnp.einsum("bsd,df->bsf", x, w)
    assert got.shape == (2, 8, 12)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    # meshless / pure-dp contexts must fall back to plain einsum
    got0 = project(x, w, ParallelCtx(mesh=None, matmul_strategy="summa"))
    np.testing.assert_allclose(np.asarray(got0), np.asarray(want), atol=1e-5)


ALLGATHER_MM_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.dist.collective_matmul import allgather_matmul
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
w = jnp.asarray(rng.normal(size=(64, 24)), jnp.float32)
want = np.asarray(jnp.matmul(x, w, preferred_element_type=jnp.float32))
for la in (1, 2, 4):
    got = np.asarray(allgather_matmul(x, w, mesh=mesh, axis="model", lookahead=la))
    assert np.abs(got - want).max() < 1e-4, la
# M additionally sharded over the data axis (the project() integration path)
got = np.asarray(
    allgather_matmul(x, w, mesh=mesh, axis="model", batch_axes=("data",))
)
assert np.abs(got - want).max() < 1e-4
g = jax.grad(lambda w: jnp.sum(allgather_matmul(x, w, mesh=mesh, axis="model") ** 2))(w)
g_ref = jax.grad(lambda w: jnp.sum(jnp.matmul(x, w) ** 2))(w)
assert np.abs(np.asarray(g) - np.asarray(g_ref)).max() < 1e-3
print("ALLGATHER_MM_OK")
"""


def test_allgather_matmul_overlapped_subprocess(subproc):
    """Ring all-gather matmul (and its reduce-scatter transpose under AD)
    must be exact on a real 8-device mesh at every lookahead depth."""
    out = subproc(ALLGATHER_MM_CODE, devices=8)
    assert "ALLGATHER_MM_OK" in out


PROJECT_AUTO_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import random_block_mask
from repro.core.summa import reference_blocksparse_matmul
from repro.dist.context import ParallelCtx
from repro.dist.collective_matmul import project
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
w = jnp.asarray(rng.normal(size=(64, 24)), jnp.float32)
want = np.asarray(jnp.einsum("md,df->mf", x, w))
ctx = ParallelCtx(mesh=mesh, matmul_strategy="auto")
got = np.asarray(project(x, w, ctx))
assert np.abs(got - want).max() < 1e-4
# the cost model must rank the ring cheapest for this dense shape (it
# moves each activation chunk once; broadcast-as-allreduce moves ~2x)
plan = ctx.matmul().plan(16, 64, 24, itemsize=4)
assert plan.cost.best_strategy(("taskbased", "allgather", "ring")) == "ring"
# a weight mask reroutes auto onto the planned sparse schedule and still
# matches the masked oracle (the ring is sparsity-blind)
bm = random_block_mask(8, 4, 0.5, seed=7)
ctxm = ParallelCtx(mesh=mesh, matmul_strategy="auto",
                   weight_block_masks={(64, 24): bm})
gotm = np.asarray(project(x, w, ctxm))
wantm = np.asarray(reference_blocksparse_matmul(
    x, w, np.ones((1, 8), bool), bm))
assert np.abs(gotm - wantm).max() < 1e-4
# xla path applies the same mask for an identical arithmetic contract
ctxx = ParallelCtx(mesh=mesh, matmul_strategy="xla",
                   weight_block_masks={(64, 24): bm})
gotx = np.asarray(project(x, w, ctxx))
assert np.abs(gotx - wantm).max() < 1e-4
print("PROJECT_AUTO_OK")
"""


def test_project_auto_strategy_and_weight_masks(subproc):
    """matmul_strategy='auto' picks by the MatmulPlan cost model and
    weight block masks route every strategy onto the same masked
    product."""
    out = subproc(PROJECT_AUTO_CODE, devices=8)
    assert "PROJECT_AUTO_OK" in out
