"""Training substrate: optimizers, checkpoint/restart (incl. resharding),
data determinism, failure-injection + lossless resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.context import ParallelCtx
from repro.train import checkpoint as ck
from repro.train.data import Prefetcher, SyntheticData
from repro.train.optimizer import OptimizerConfig, make_optimizer

CTX = ParallelCtx(mesh=None)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_decreases_quadratic(name):
    opt = make_optimizer(
        OptimizerConfig(name=name, peak_lr=0.1, warmup_steps=1,
                        total_steps=100, weight_decay=0.0)
    )
    params = {"w": jnp.asarray([[3.0, -2.0], [1.0, 4.0]])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for step in range(50):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params, jnp.int32(step))
    assert float(loss(params)) < 0.1 * l0


def test_adafactor_state_is_factored():
    opt = make_optimizer(OptimizerConfig(name="adafactor"))
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    state = opt.init(params)
    assert state["v"]["w"]["vr"].shape == (64,)
    assert state["v"]["w"]["vc"].shape == (32,)
    assert state["v"]["b"]["v"].shape == (64,)
    # memory: factored state is O(m+n), not O(m*n)
    n_state = sum(x.size for x in jax.tree.leaves(state))
    assert n_state < params["w"].size


def test_checkpoint_roundtrip_and_checksum(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "step": jnp.int32(7)},
    }
    ck.save_checkpoint(str(tmp_path), 5, tree)
    assert ck.latest_step(str(tmp_path)) == 5
    restored = ck.restore_checkpoint(str(tmp_path), 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    # corruption detection
    base = tmp_path / "step_5"
    victim = next(f for f in os.listdir(base) if f.endswith(".npy"))
    with open(base / victim, "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        ck.restore_checkpoint(str(tmp_path), 5, tree)


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir from a crashed writer is never picked up."""
    tree = {"a": jnp.ones((2,))}
    ck.save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_9.tmp")
    assert ck.latest_step(str(tmp_path)) == 1


def test_data_determinism_and_resume():
    cfg = get_config("llama3.2-1b", smoke=True)
    d1 = SyntheticData(cfg, batch=4, seq=32, seed=3)
    d2 = SyntheticData(cfg, batch=4, seq=32, seed=3)
    b5 = d1.batch_at(5)
    np.testing.assert_array_equal(b5["tokens"], d2.batch_at(5)["tokens"])
    # prefetcher starting mid-stream yields the same step-5 batch
    pre = Prefetcher(d2, start_step=5)
    step, batch = pre.next()
    pre.stop()
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"], b5["tokens"])
    # learnable structure: second half follows t' = (3t+7) % V
    toks = b5["tokens"]
    s = toks.shape[1]
    expect = (3 * toks[:, s // 2] + 7) % cfg.vocab_size
    np.testing.assert_array_equal(toks[:, s // 2 + 1], expect)


def test_failure_injection_and_lossless_resume(tmp_path):
    """Kill at step 20, resume, final loss equals the uninterrupted run."""
    from repro.launch.train import main as train_main

    common = [
        "--arch", "llama3.2-1b", "--smoke", "--steps", "24",
        "--global-batch", "2", "--seq", "32", "--ckpt-every", "8",
        "--log-every", "50",
    ]
    ref_losses = train_main(common + ["--ckpt-dir", str(tmp_path / "ref")])
    with pytest.raises(SystemExit) as e:
        train_main(
            common + ["--ckpt-dir", str(tmp_path / "ft"), "--fail-at-step", "16"]
        )
    assert e.value.code == 42
    resumed = train_main(
        common + ["--ckpt-dir", str(tmp_path / "ft"), "--resume"]
    )
    assert abs(resumed[-1] - ref_losses[-1]) < 1e-4


RESHARD_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ck
import tempfile, os
tmp = tempfile.mkdtemp()
from repro.launch.mesh import make_mesh
mesh_a = make_mesh((4, 2), ("data", "model"))
mesh_b = make_mesh((2, 4), ("data", "model"))
x = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
ck.save_checkpoint(tmp, 1, {"w": xa})
# elastic restore: different mesh shape AND different layout
target = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
sh = {"w": NamedSharding(mesh_b, P("model", "data"))}
restored = ck.restore_checkpoint(tmp, 1, target, sh)
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
assert restored["w"].sharding == sh["w"]
print("RESHARD_OK")
"""


def test_elastic_reshard_restore_subprocess(subproc):
    out = subproc(RESHARD_CODE, devices=8)
    assert "RESHARD_OK" in out
