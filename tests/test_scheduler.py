"""Continuous-batching front-end: scheduler, paged KV, plan service.

The invariant everything hangs on: batch rows are independent, so any
admission order / backend must reproduce the per-request greedy decode
exactly (fp32 smoke model keeps the oracle bit-stable)."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.context import ParallelCtx
from repro.models.model import init_model
from repro.serve import engine, pages
from repro.serve.plan_service import PlanService
from repro.serve.scheduler import Scheduler, ragged_trace

CTX = ParallelCtx(mesh=None)


def _cfg():
    return dataclasses.replace(
        get_config("llama3.2-1b", smoke=True), dtype="float32"
    )


@pytest.fixture(scope="module")
def served():
    """One continuous run + the per-request serial reference."""
    cfg = _cfg()
    params = init_model(jax.random.PRNGKey(0), cfg, CTX)
    trace = lambda: ragged_trace(  # noqa: E731
        6, prompt_lens=(6, 10), gen_lens=(3, 8), vocab=cfg.vocab_size
    )
    sched = Scheduler(params, cfg, CTX, n_slots=2, max_len=24)
    res = sched.run(trace())
    ref = {}
    for r in trace():
        logits, cache = engine.prefill(
            params, {"tokens": jnp.asarray(r.prompt)[None]}, cfg, CTX,
            max_len=24,
        )
        toks = [int(jnp.argmax(logits[0]))]
        for _ in range(r.max_new_tokens - 1):
            logits, cache = engine.decode_step(
                params, cache, jnp.asarray([toks[-1]], jnp.int32), cfg, CTX
            )
            toks.append(int(jnp.argmax(logits[0])))
        ref[r.rid] = toks
    return cfg, params, trace, res, ref


def test_continuous_matches_per_request_reference(served):
    _, _, _, res, ref = served
    assert res["outputs"] == ref
    assert res["generated_tokens"] == sum(len(v) for v in ref.values())
    assert res["p50_step_ms"] > 0 and res["p99_step_ms"] >= res["p50_step_ms"]


def test_static_mode_same_outputs_more_steps(served):
    cfg, params, trace, res, ref = served
    static = Scheduler(
        params, cfg, CTX, n_slots=2, max_len=24, mode="static"
    ).run(trace())
    assert static["outputs"] == ref
    # the ragged trace pairs a short and a long request per static batch,
    # so static batching must burn strictly more steps
    assert static["steps"] > res["steps"], (static["steps"], res["steps"])


def test_paged_matches_dense(served):
    cfg, params, trace, res, ref = served
    paged = Scheduler(
        params, cfg, CTX, n_slots=2, max_len=24, backend="paged",
        page_size=4,  # several on-demand page growths per request
    ).run(trace())
    assert paged["outputs"] == ref
    assert paged["backend"] == "paged"


def test_admission_budget_defers_but_completes(served):
    cfg, params, trace, res, ref = served
    tight = Scheduler(
        params, cfg, CTX, n_slots=2, max_len=24,
        admit_budget_s=1e-12,  # < one prefill: one admission per step max
    )
    out = tight.run(trace())
    assert out["outputs"] == ref
    assert out["budget_deferrals"] > 0


def test_staggered_arrivals(served):
    cfg, params, _, _, ref = served
    trace = ragged_trace(
        6, prompt_lens=(6, 10), gen_lens=(3, 8), vocab=cfg.vocab_size,
        arrival_every=3,
    )
    out = Scheduler(params, cfg, CTX, n_slots=2, max_len=24).run(trace)
    assert out["outputs"] == ref  # arrival time never changes content


# ---------------------------------------------------------------------------
# page allocator (host-side unit tests, no model)
# ---------------------------------------------------------------------------


def test_page_allocator_alloc_release():
    a = pages.PageAllocator(n_pages=8, page_size=4, n_slots=2, max_pages=3)
    assert a.capacity == 12
    assert a.n_free() == 7  # page 0 reserved
    a.ensure(0, 5)  # 2 pages
    a.ensure(1, 4)  # 1 page
    assert a.n_free() == 4
    t = np.asarray(a.table())
    assert t.shape == (2, 3)
    assert (t[0, :2] > 0).all() and t[0, 2] == 0
    assert 0 not in a.slot_pages[0]  # trash page never allocated
    a.ensure(0, 5)  # idempotent
    assert a.n_free() == 4
    assert a.release(0) == 2
    assert a.n_free() == 6
    assert (np.asarray(a.table())[0] == 0).all()


def test_page_allocator_exhaustion_and_capacity():
    a = pages.PageAllocator(n_pages=4, page_size=2, n_slots=2, max_pages=4)
    a.ensure(0, 6)  # all 3 allocatable pages
    with pytest.raises(pages.OutOfPages):
        a.ensure(1, 1)
    before = list(a.slot_pages[1])
    assert before == []  # failed ensure allocates nothing
    with pytest.raises(engine.CacheCapacityError):
        a.ensure(0, 9)  # 5 pages > max_pages


def test_paged_pool_shapes():
    cfg = _cfg()
    cache = jax.eval_shape(
        lambda: pages.paged_init_cache(cfg, n_slots=2, n_pages=9,
                                       page_size=4, ctx=CTX)
    )
    k = cache["units"]["b0"]["k"]
    assert k.shape == (
        cfg.units, 9, cfg.num_kv_heads, 4, cfg.resolved_head_dim
    )
    assert cache["pos"].shape == (2,)


def test_paged_guards():
    cfg = _cfg()
    qctx = ParallelCtx(mesh=None, kv_quant=True)
    with pytest.raises(NotImplementedError):
        pages.paged_init_cache(cfg, 2, 9, 4, qctx)
    wcfg = get_config("mixtral-8x7b", smoke=True)
    assert wcfg.window is not None
    with pytest.raises(NotImplementedError):
        pages.paged_init_cache(wcfg, 2, 9, 4, CTX)


# ---------------------------------------------------------------------------
# persistent plan service
# ---------------------------------------------------------------------------


def _auto_ctx():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    return ParallelCtx(mesh=mesh, matmul_strategy="auto")


def test_plan_service_roundtrip(tmp_path):
    """Cold warm-up tunes once per shape; a restored service re-applies
    the stored winners with zero tuner runs and a stable fingerprint."""
    cfg = _cfg()
    ctx = _auto_ctx()
    cold = PlanService()
    plans = engine.warm_matmul_plans(
        cfg, ctx, 2, 8, warm_executables=False, service=cold
    )
    assert plans and cold.stats["tunes"] == len(cold.table) > 0
    assert cold.traffic == {"2x8": 1}
    path = os.fspath(tmp_path / "plans.json")
    cold.save(path)
    data = json.load(open(path))
    assert data["version"] == 1 and data["entries"]

    warm = PlanService()
    assert warm.load(path) == len(cold.table)
    replans = engine.warm_matmul_plans(
        cfg, ctx, 2, 8, warm_executables=False, service=warm
    )
    assert warm.stats["tunes"] == 0
    assert warm.stats["hits"] == len(plans)
    assert warm.fingerprint() == cold.fingerprint() != ""
    # the re-applied plans carry the tuned schedule, not the default
    for p, q in zip(plans, replans):
        assert q.cfg.strategy == p.tuned["strategy"]
        assert q.k_steps == p.tuned["k_blocks"]
        assert q.resolve_lookahead() == p.tuned["lookahead"]


def test_plan_service_keys_isolate_mesh_and_shape():
    cfg = _cfg()
    ctx = _auto_ctx()
    svc = PlanService()
    engine.warm_matmul_plans(cfg, ctx, 2, 8, warm_executables=False,
                             service=svc)
    n = len(svc.table)
    engine.warm_matmul_plans(cfg, ctx, 4, 8, warm_executables=False,
                             service=svc)  # new batch -> new decode shape
    assert len(svc.table) > n
    assert svc.top_traffic() == [(2, 8), (4, 8)]


PLAN_ENV_CODE = r"""
import os, tempfile
import jax
from repro.configs import get_config
from repro.dist.context import ParallelCtx
from repro.launch.mesh import make_mesh
from repro.serve import engine
from repro.serve.plan_service import PlanService, plan_service, set_plan_service

cfg = get_config("llama3.2-1b", smoke=True)
mesh = make_mesh((1, 1), ("data", "model"))
ctx = ParallelCtx(mesh=mesh, matmul_strategy="auto")
cold = PlanService()
engine.warm_matmul_plans(cfg, ctx, 2, 8, warm_executables=False, service=cold)
assert cold.stats["tunes"] > 0
d = tempfile.mkdtemp()
path = os.path.join(d, "plans.json")
cold.save(path)
# simulate the fresh process: env-seeded singleton, zero re-tunes
os.environ["REPRO_PLAN_CACHE"] = path
set_plan_service(None)
svc = plan_service()
assert len(svc.table) == len(cold.table)
engine.warm_matmul_plans(cfg, ctx, 2, 8, warm_executables=False)
assert svc.stats["tunes"] == 0, svc.stats
assert svc.stats["hits"] > 0
print("PLAN_ENV_OK")
"""


def test_plan_service_env_seeding_subprocess(subproc):
    out = subproc(PLAN_ENV_CODE, devices=1)
    assert "PLAN_ENV_OK" in out
