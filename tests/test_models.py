"""Per-arch smoke tests: reduced configs, one forward + one train step on
CPU, asserting output shapes and finite values (the required smoke suite).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.dist.context import ParallelCtx
from repro.models.model import forward, init_model
from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.train_step import build_train_step, make_train_state

CTX = ParallelCtx(mesh=None)
B, S = 2, 32


def smoke_batch(cfg):
    if cfg.family == "audio":
        return {
            "embeds": jnp.ones((B, S, cfg.d_model), jnp.bfloat16),
            "labels": jnp.zeros((B, S), jnp.int32),
        }
    if cfg.family == "vlm":
        sv = S // 4
        return {
            "tokens": jnp.zeros((B, S - sv), jnp.int32),
            "embeds": jnp.ones((B, sv, cfg.d_model), jnp.bfloat16),
            "positions": jnp.broadcast_to(
                jnp.arange(S)[None, :, None], (B, S, 3)
            ).astype(jnp.int32),
            "labels": jnp.zeros((B, S - sv), jnp.int32),
        }
    return {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.zeros((B, S), jnp.int32),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg, CTX)
    batch = smoke_batch(cfg)
    logits, aux = forward(params, batch, cfg, CTX)
    s_out = batch["labels"].shape[1] if cfg.family == "vlm" else S
    assert logits.shape[0] == B and logits.shape[2] == cfg.vocab_size
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    opt = make_optimizer(OptimizerConfig(total_steps=10, warmup_steps=1))
    state = make_train_state(jax.random.PRNGKey(0), cfg, CTX, opt)
    step = build_train_step(cfg, CTX, opt, microbatches=1)
    batch = smoke_batch(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = jax.tree.leaves(
        jax.tree.map(
            lambda a, b: float(
                jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()
            ),
            state["params"],
            new_state["params"],
        )
    )
    assert max(moved) > 0.0


def test_full_configs_match_spec():
    """Exact assigned configuration table."""
    spec = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "mixtral-8x7b": (32, 4096, 32, 8, 0, 32000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 0, 163840),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == l, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    # MoE extras
    mx = get_config("mixtral-8x7b").moe
    assert (mx.num_experts, mx.top_k, mx.d_ff) == (8, 2, 14336)
    km = get_config("kimi-k2-1t-a32b").moe
    assert (km.num_experts, km.top_k, km.d_ff) == (384, 8, 2048)


def test_microbatched_grad_accum_matches_single():
    cfg = get_config("llama3.2-1b", smoke=True)
    opt = make_optimizer(OptimizerConfig(total_steps=10, warmup_steps=1))
    state = make_train_state(jax.random.PRNGKey(0), cfg, CTX, opt)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, S), 0, cfg.vocab_size),
    }
    s1, m1 = build_train_step(cfg, CTX, opt, microbatches=1)(state, batch)
    s2, m2 = build_train_step(cfg, CTX, opt, microbatches=2)(state, batch)
    # same gradient (mean over microbatches) -> near-identical update
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        s1["params"], s2["params"],
    )
    assert max(jax.tree.leaves(d)) < 5e-2
