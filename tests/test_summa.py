"""Distributed SUMMA correctness: single-device in-process + 8-device
subprocess (real shard_map semantics across a 2x4 / 2x2x2 mesh)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DistributedMatmul,
    multi_issue_limit,
    reference_matmul,
)
from repro.launch.mesh import make_host_mesh


def test_eq1_multi_issue_limit():
    """Paper Eq. (1)."""
    assert multi_issue_limit(1, 8, 100) == 2
    assert multi_issue_limit(8, 1, 100) == 2
    assert multi_issue_limit(16, 16, 8) == 8  # P >= K -> K
    assert multi_issue_limit(16, 8, 100) == 8  # min(Prow, Pcol)
    assert multi_issue_limit(4, 12, 100) == 4
    # degenerate grids/schedules still give a usable (>= 1) window
    assert multi_issue_limit(1, 1, 8) == 2  # 1x1 grid
    assert multi_issue_limit(1, 1, 1) == 2
    assert multi_issue_limit(4, 4, 1) == 1  # P >= K -> K, even K=1
    assert multi_issue_limit(4, 4, 0) == 0  # raw Eq. 1; resolve_ clamps


def test_resolve_lookahead_edge_cases():
    """SummaConfig.resolve_lookahead: always in [1, max(k_steps, 1)]."""
    from repro.sched import abstract_summa_config

    cfg = abstract_summa_config(4, 4)
    assert cfg.resolve_lookahead(0) == 1  # empty schedule -> still valid
    assert cfg.resolve_lookahead(1) == 1
    assert cfg.resolve_lookahead(8) == 4  # Eq. (1): min(p_row, p_col)...
    assert abstract_summa_config(1, 1).resolve_lookahead(8) == 2
    assert abstract_summa_config(1, 1).resolve_lookahead(1) == 1
    # explicit lookahead larger than the panel count must clamp
    assert abstract_summa_config(4, 4, lookahead=64).resolve_lookahead(8) == 8
    assert abstract_summa_config(4, 4, lookahead=64).resolve_lookahead(0) == 1
    assert abstract_summa_config(4, 4, lookahead=0).resolve_lookahead(8) == 1
    # the per-plan override (set by the tuner) wins, with the same clamp
    from repro.core.plan import plan_matmul
    import dataclasses

    plan = plan_matmul(64, 64, 64, abstract_summa_config(4, 4, k_blocks=4))
    assert plan.resolve_lookahead() == plan.cfg.resolve_lookahead(4)
    tuned = dataclasses.replace(plan, lookahead=99)
    assert tuned.resolve_lookahead() == 4  # clamped to k_steps
    assert dataclasses.replace(plan, lookahead=2).resolve_lookahead() == 2


@pytest.mark.parametrize("strategy", ["procedural", "taskbased", "allgather"])
def test_summa_single_device_mesh(strategy):
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    mm = DistributedMatmul(mesh, strategy=strategy, k_blocks=4)
    out = np.asarray(mm(a, b))
    want = np.asarray(reference_matmul(a, b))
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)


SUBPROC_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import (DistributedMatmul, NonuniformMatmul, reference_matmul,
                        reference_blocksparse_matmul, random_block_mask,
                        nonuniform_tiling)
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
M, K, N = 64, 128, 96
a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
ref = np.asarray(reference_matmul(a, b))
for strat in ["procedural", "taskbased", "allgather"]:
    for kb in [None, 8, 16]:
        mm = DistributedMatmul(mesh, strategy=strat, k_blocks=kb)
        for la in ([None, 1, 3] if strat == "taskbased" else [None]):
            mm.lookahead = la
            out = np.asarray(mm(a, b))
            err = np.abs(out - ref).max()
            assert err < 1e-4, (strat, kb, la, err)
am = random_block_mask(8, 8, 0.4, seed=1)
bm = random_block_mask(8, 8, 0.4, seed=2)
mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=8)
out = np.asarray(mm(a, b, a_mask=am, b_mask=bm))
ref_bs = np.asarray(reference_blocksparse_matmul(a, b, am, bm))
assert np.abs(out - ref_bs).max() < 1e-4
rt = nonuniform_tiling(100, 7, seed=3)
it = nonuniform_tiling(120, 5, seed=4)
ct = nonuniform_tiling(90, 6, seed=5)
a2 = jnp.asarray(rng.normal(size=(100, 120)), jnp.float32)
b2 = jnp.asarray(rng.normal(size=(120, 90)), jnp.float32)
nmm = NonuniformMatmul(DistributedMatmul(mesh, strategy="taskbased"), rt, it, ct, tile=16)
assert np.abs(np.asarray(nmm(a2, b2)) - np.asarray(reference_matmul(a2, b2))).max() < 1e-3
# multi-pod style 3-axis mesh with tuple row axis
from repro.core.summa import SummaConfig, summa_matmul, summa_25d_matmul
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg3 = SummaConfig(mesh=mesh3, row_axis=("pod", "data"), col_axis="model",
                   strategy="taskbased", k_blocks=4)
out3 = np.asarray(summa_matmul(a, b, cfg3))
assert np.abs(out3 - ref).max() < 1e-4, "tuple-axis summa"
# 2.5D: replicate over pod, split K iterations across replicas
for kb in (4, 8):
    cfg4 = SummaConfig(mesh=mesh3, row_axis="data", col_axis="model",
                       strategy="taskbased", k_blocks=kb)
    out4 = np.asarray(summa_25d_matmul(a, b, cfg4))
    assert np.abs(out4 - ref).max() < 1e-4, ("2.5d", kb)
print("SUBPROC_SUMMA_OK")
"""


def test_summa_8dev_subprocess(subproc):
    out = subproc(SUBPROC_CODE, devices=8)
    assert "SUBPROC_SUMMA_OK" in out


BLOCKSPARSE_COMM_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import DistributedMatmul, random_block_mask
from repro.core.summa import SummaConfig, summa_blocksparse_matmul, summa_matmul
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
cfg = SummaConfig(mesh=mesh, strategy="taskbased", k_blocks=8)
a = jnp.ones((64, 128), jnp.float32)
b = jnp.ones((128, 64), jnp.float32)
am = random_block_mask(8, 8, 0.5, seed=0)
bm = random_block_mask(8, 8, 0.5, seed=1)
am[:, 2] = False  # dead K panels (screened-out interaction blocks)
am[:, 5] = False
bm[6, :] = False
from repro.analysis.hlo import analyze_hlo
sparse_txt = jax.jit(
    lambda a, b: summa_blocksparse_matmul(a, b, am, bm, cfg)
).lower(a, b).compile().as_text()
full_txt = jax.jit(
    lambda a, b: summa_blocksparse_matmul(
        a, b, np.ones_like(am), np.ones_like(bm), cfg)
).lower(a, b).compile().as_text()
alive = [k for k in range(8) if am[:, k].any() and bm[k, :].any()]
assert len(alive) == 5, alive
cs = analyze_hlo(sparse_txt)
cf = analyze_hlo(full_txt)
# communication AND compute scale with the number of live panels
assert cs.coll_bytes <= cf.coll_bytes * (len(alive) / 8 + 0.05), (
    cs.coll_bytes, cf.coll_bytes)
assert cs.flops <= cf.flops * (len(alive) / 8 + 0.05)
# correctness of the sparse result
from repro.core import reference_blocksparse_matmul
got = np.asarray(summa_blocksparse_matmul(a, b, am, bm, cfg))
want = np.asarray(reference_blocksparse_matmul(a, b, am, bm))
assert np.abs(got - want).max() < 1e-4
print("SUBPROC_BS_OK")
"""


def test_blocksparse_skips_dead_panels(subproc):
    out = subproc(BLOCKSPARSE_COMM_CODE, devices=4)
    assert "SUBPROC_BS_OK" in out


LOOKAHEAD_DEGRADE_CODE = r"""
import numpy as np, jax.numpy as jnp
from repro.core import DistributedMatmul
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
b = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
proc = DistributedMatmul(mesh, strategy="procedural", k_blocks=8)
task1 = DistributedMatmul(mesh, strategy="taskbased", k_blocks=8, lookahead=1)
o_proc = np.asarray(proc(a, b))
o_task = np.asarray(task1(a, b))
# lookahead=1 is procedural SUMMA: same panel order, same accumulation
# order, so the float results must agree BITWISE, not just approximately.
assert np.array_equal(o_proc, o_task), np.abs(o_proc - o_task).max()
# an over-large explicit lookahead clamps to k_steps (the allgather-like
# fully-unrolled pipeline) and still matches within fp tolerance
big = DistributedMatmul(mesh, strategy="taskbased", k_blocks=8, lookahead=999)
assert np.abs(np.asarray(big(a, b)) - o_proc).max() < 1e-4
print("SUBPROC_LOOKAHEAD_OK")
"""


def test_lookahead_one_degrades_to_procedural_exactly(subproc):
    """Satellite of the sched PR: I=1 multiple-issue == the procedural
    baseline bit-for-bit; explicit lookahead > k_steps clamps."""
    out = subproc(LOOKAHEAD_DEGRADE_CODE, devices=4)
    assert "SUBPROC_LOOKAHEAD_OK" in out
