"""repro.contract: spec parsing, matricization invariants, chain scheduling.

Deterministic unit tests for the einsum front-end (parse classification,
mask/rank matricize-unmatricize round trips, fill preservation, bitwise
all-True-mask == dense, inferred output masks, batch modes) plus the
chained-contraction golden trace (fingerprint-pinned, like the sched
trace) and the joint-vs-sequential makespan guarantee.  The hypothesis
block at the bottom property-tests the matricization layer over random
(possibly nonuniform) tilings; it needs the ``[dev]`` extra and is
marked ``slow`` (the full-sweep CI job runs it).
"""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    BlockSparseTensor,
    DistributedMatmul,
    contract,
    contract_chain,
    nonuniform_tiling,
    parse_contraction,
    uniform_tiling,
)
from repro.core.contract import (
    expand_block_mask,
    matricize_mask,
    merge_tilings,
    unmatricize_mask,
)
from repro.launch.mesh import make_host_mesh
from repro.sched import chain_graphs, from_tilings, simulate, tune_chain

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # dev extra not installed: plain tests still run
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# spec parsing
# ---------------------------------------------------------------------------


def test_parse_classifies_modes():
    s = parse_contraction("abc,cd->abd")
    assert s.batch == ()
    assert s.contracted == ("c",)
    assert s.free_x == ("a", "b")
    assert s.free_y == ("d",)
    s = parse_contraction("sab,sbc->sac")
    assert s.batch == ("s",)
    assert s.contracted == ("b",)
    s = parse_contraction("abc,bcd->ad")
    assert s.contracted == ("b", "c")
    s = parse_contraction("ab,ca->cb")  # contracted mode first in x
    assert s.contracted == ("a",)
    assert s.free_x == ("b",) and s.free_y == ("c",)


def test_parse_rejects_bad_specs():
    with pytest.raises(ValueError, match="explicit output"):
        parse_contraction("ab,bc")
    with pytest.raises(ValueError, match="exactly two"):
        parse_contraction("ab,bc,cd->ad")
    with pytest.raises(ValueError, match="repeated mode"):
        parse_contraction("aab,bc->ac")
    with pytest.raises(ValueError, match="appear in no input"):
        parse_contraction("ab,bc->az")
    with pytest.raises(ValueError, match="sum-reductions"):
        parse_contraction("abz,bc->ac")
    with pytest.raises(ValueError, match="contracts no mode"):
        parse_contraction("ab,cd->abcd")


# ---------------------------------------------------------------------------
# matricization invariants (deterministic)
# ---------------------------------------------------------------------------


def test_merge_tilings_blocks_are_contiguous():
    """Every merged block occupies one contiguous range whose length is
    the product of its mode block sizes, in lexicographic block order."""
    t1 = nonuniform_tiling(30, 3, seed=1)
    t2 = nonuniform_tiling(20, 4, seed=2)
    merged, perm = merge_tilings((t1, t2))
    assert merged.extent == t1.extent * t2.extent
    assert merged.num_blocks == t1.num_blocks * t2.num_blocks
    sizes = np.multiply.outer(t1.sizes, t2.sizes).ravel()
    assert merged.sizes == tuple(int(s) for s in sizes)
    # permuted elements of merged block (b1, b2) are exactly the flat
    # row-major indices of the tensor block's cartesian product
    off = 0
    for b1 in range(t1.num_blocks):
        for b2 in range(t2.num_blocks):
            n = t1.sizes[b1] * t2.sizes[b2]
            got = set(perm[off : off + n].tolist())
            r0 = t1.offsets[b1]
            c0 = t2.offsets[b2]
            want = {
                (r0 + i) * t2.extent + (c0 + j)
                for i in range(t1.sizes[b1])
                for j in range(t2.sizes[b2])
            }
            assert got == want, (b1, b2)
            off += n


def test_merge_tilings_trailing_single_block_is_identity():
    t1 = nonuniform_tiling(24, 4, seed=0)
    merged, perm = merge_tilings((t1, uniform_tiling(7, 7)))
    assert perm is None
    assert merged.sizes == tuple(s * 7 for s in t1.sizes)


def test_mask_matricize_round_trip_and_fill():
    rng = np.random.default_rng(0)
    modes = ("a", "b", "c")
    grids = {"a": 3, "b": 2, "c": 4}
    mask = rng.random((3, 2, 4)) < 0.5
    m2 = matricize_mask(mask, modes, ("a", "b"), ("c",))
    assert m2.shape == (6, 4)
    back = unmatricize_mask(m2, ("a", "b"), ("c",), grids, modes)
    np.testing.assert_array_equal(back, mask)
    # any output permutation round-trips too
    back2 = unmatricize_mask(m2, ("a", "b"), ("c",), grids, ("c", "a", "b"))
    np.testing.assert_array_equal(back2, np.transpose(mask, (2, 0, 1)))


def test_matricized_fill_equals_tensor_fill():
    """Merging modes must preserve the live-element fraction exactly,
    uniform or not (areas weight the nonuniform case)."""
    rng = np.random.default_rng(1)
    t1, t2, t3 = (
        nonuniform_tiling(18, 3, seed=2),
        uniform_tiling(12, 4),
        nonuniform_tiling(10, 2, seed=3),
    )
    mask = rng.random((3, 3, 2)) < 0.4
    x = BlockSparseTensor(
        data=jnp.zeros((18, 12, 10), jnp.float32),
        tilings=(t1, t2, t3),
        mask=mask,
    )
    row_t, _ = merge_tilings((t1, t2))
    m2 = matricize_mask(mask, ("a", "b", "c"), ("a", "b"), ("c",))
    x2 = BlockSparseTensor(
        data=jnp.zeros((row_t.extent, t3.extent), jnp.float32),
        tilings=(row_t, t3),
        mask=m2,
    )
    assert x2.fill() == pytest.approx(x.fill(), abs=0)
    # and the element-resolution expansions agree up to the permutation
    assert expand_block_mask(m2, (row_t, t3)).sum() == expand_block_mask(
        mask, (t1, t2, t3)
    ).sum()


def test_all_true_mask_matches_dense_bitwise():
    """An all-True mask must not perturb numerics at all: same panel
    decomposition => the masked DAG accumulates the identical panel dots
    in the identical order as the dense pipeline."""
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(2)
    a = rng.normal(size=(48, 64)).astype(np.float32)
    b = rng.normal(size=(64, 40)).astype(np.float32)
    mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=4)
    x = BlockSparseTensor.from_dense(
        jnp.asarray(a), block_shape=(12, 16), mask=np.ones((4, 4), bool)
    )
    y = BlockSparseTensor.from_dense(
        jnp.asarray(b), block_shape=(16, 10), mask=np.ones((4, 4), bool)
    )
    got_masked = np.asarray(contract("ab,bc->ac", x, y, mm=mm).data)
    got_dense = np.asarray(
        contract("ab,bc->ac", jnp.asarray(a), jnp.asarray(b), mm=mm).data
    )
    assert np.array_equal(got_masked, got_dense)


def test_inferred_output_mask_is_exact():
    """The inferred C mask is the boolean mask product, and every block
    outside it is identically zero in the computed result."""
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(3)
    am = rng.random((4, 6)) < 0.3
    bm = rng.random((6, 5)) < 0.3
    x = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(32, 48)).astype(np.float32)),
        block_shape=(8, 8), mask=am,
    )
    y = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(48, 40)).astype(np.float32)),
        block_shape=(8, 8), mask=bm,
    )
    out = contract("ab,bc->ac", x, y, mm=DistributedMatmul(mesh))
    want_mask = (am.astype(int) @ bm.astype(int)) > 0
    np.testing.assert_array_equal(out.mask, want_mask)
    dead = ~expand_block_mask(want_mask, out.tilings)
    assert np.all(np.asarray(out.data)[dead] == 0.0)


def test_raw_array_operand_adopts_partner_blocking():
    """A structureless raw-array operand contracts against a masked
    tensor by adopting its blocking on the shared modes."""
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(7)
    a = rng.normal(size=(48, 64)).astype(np.float32)
    b = rng.normal(size=(64, 40)).astype(np.float32)
    am = rng.random((4, 4)) < 0.5
    x = BlockSparseTensor.from_dense(
        jnp.asarray(a), block_shape=(12, 16), mask=am
    )
    out = contract("ab,bc->ac", x, jnp.asarray(b), mm=DistributedMatmul(mesh))
    ref = np.einsum(
        "ab,bc->ac", x.to_dense().astype(np.float64), b.astype(np.float64)
    )
    np.testing.assert_allclose(
        np.asarray(out.data), ref, atol=5e-4, rtol=1e-4
    )
    assert out.mask.shape == (4, 1)  # x's row blocks x y's trivial column


def test_batch_mode_mismatch_raises():
    """Batch extents must agree, and a structured second operand must
    block batch modes like the first — silent mask mis-slicing is a bug
    class this pins (previously corrupted instead of raising)."""
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh)
    rng = np.random.default_rng(8)
    x = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(4, 8, 8)).astype(np.float32)),
        block_shape=(2, 4, 4), mask=rng.random((2, 2, 2)) < 0.7,
    )
    y_short = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(2, 8, 8)).astype(np.float32)),
        block_shape=(2, 4, 4),
    )
    with pytest.raises(ValueError, match="extents disagree"):
        contract("sab,sbc->sac", x, y_short, mm=mm)
    y_reblocked = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(4, 8, 8)).astype(np.float32)),
        block_shape=(1, 4, 4), mask=rng.random((4, 2, 2)) < 0.7,
    )
    with pytest.raises(ValueError, match="block batch modes"):
        contract("sab,sbc->sac", x, y_reblocked, mm=mm)
    # a *plain* y with different batch blocking is fine (nothing
    # block-granular of y is sliced)
    y_plain = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(4, 8, 8)).astype(np.float32)),
        block_shape=(1, 4, 4),
    )
    out = contract("sab,sbc->sac", x, y_plain, mm=mm)
    ref = np.einsum(
        "sab,sbc->sac", x.to_dense().astype(np.float64),
        np.asarray(y_plain.data, np.float64),
    )
    np.testing.assert_allclose(
        np.asarray(out.data), ref, atol=5e-4, rtol=1e-4
    )


def test_scalar_full_contraction():
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(4)
    a = rng.normal(size=(12, 8)).astype(np.float32)
    b = rng.normal(size=(12, 8)).astype(np.float32)
    out = contract(
        "ab,ab->", jnp.asarray(a), jnp.asarray(b.T).T,
        mm=DistributedMatmul(mesh),
    )
    assert out.ndim == 0
    np.testing.assert_allclose(
        float(out.data), float((a.astype(np.float64) * b).sum()),
        rtol=1e-5,
    )


def test_rank_csr_operand_requires_identity_matricization():
    mesh = make_host_mesh(1, 1)
    from repro.core import decay_rank_map, synthesize_rank_csr

    rcsr = synthesize_rank_csr(decay_rank_map(2, 2, 8, 8, max_rank=4))
    x = BlockSparseTensor.from_rank_csr(rcsr)
    y = BlockSparseTensor.from_dense(
        jnp.zeros((16, 16), jnp.float32), block_shape=(8, 8)
    )
    with pytest.raises(NotImplementedError, match="densify"):
        contract("ab,ca->cb", x, y, mm=DistributedMatmul(mesh))


# ---------------------------------------------------------------------------
# chained contractions: union graph + golden trace
# ---------------------------------------------------------------------------

GOLDEN_CHAIN_TRACE = (
    __file__.rsplit("/", 1)[0] + "/golden/contract_chain_trace.json"
)


def _chain_golden_graphs(lookaheads=(None, None)):
    """The committed chain workload: D = (A.B).C, nonuniform blocks on a
    2x2 grid (small enough to eyeball in a trace viewer)."""
    rt = nonuniform_tiling(256, 8, seed=1)
    it = nonuniform_tiling(256, 8, seed=2)
    ct = nonuniform_tiling(256, 8, seed=3)
    dt = nonuniform_tiling(256, 8, seed=4)
    g1 = from_tilings(2, 2, rt, it, ct, lookahead=lookaheads[0])
    g2 = from_tilings(2, 2, rt, ct, dt, lookahead=lookaheads[1])
    return [g1, g2]


def test_chain_graph_structure():
    graphs = _chain_golden_graphs()
    union = chain_graphs(graphs)
    union.validate()
    assert len(union.tasks) == sum(len(g.tasks) for g in graphs)
    # step-2 A broadcasts carry exactly one cross edge (the producing
    # device's final accumulate); step-2 B broadcasts carry none
    n1 = len(graphs[0].tasks)
    last_accums = {
        t.devices[0]: t.tid
        for t in union.tasks[:n1] if t.kind == "accum"
    }
    for t2, (tu, du) in zip(
        graphs[1].tasks, zip(union.tasks[n1:], union.deps[n1:])
    ):
        own = [d for d in du if d < n1]
        if tu.kind == "bcast_a":
            assert len(own) == 1 and own[0] in last_accums.values()
        else:
            assert not own


def test_chain_joint_never_worse_than_sequential():
    graphs = _chain_golden_graphs()
    seq = sum(simulate(g).makespan_s for g in graphs)
    joint = simulate(chain_graphs(graphs)).makespan_s
    assert joint <= seq * (1 + 1e-12)


def test_chain_matches_golden_trace():
    """Pins the chained schedule end to end: any change to the union
    graph builder, window edges, or simulator moves the committed
    makespan and fingerprint (regen_contract_chain_trace.py)."""
    with open(GOLDEN_CHAIN_TRACE) as f:
        golden = json.load(f)
    sim = simulate(chain_graphs(_chain_golden_graphs()), trace=True)
    assert sim.fingerprint() == golden["fingerprint"]
    assert sim.makespan_s == golden["makespan_s"]
    # the invariant the chain exists for, pinned alongside the trace
    assert golden["joint_makespan_s"] <= golden["sequential_makespan_s"]


def test_tune_chain_never_worse_than_default():
    builders = [
        lambda la: _chain_golden_graphs((la, None))[0],
        lambda la: _chain_golden_graphs((None, la))[1],
    ]
    las, sim, record = tune_chain(builders)
    default = simulate(chain_graphs(_chain_golden_graphs()))
    assert sim.makespan_s <= default.makespan_s * (1 + 1e-12)
    assert record["lookaheads"] == [int(x) for x in las]


def test_contract_chain_end_to_end_matches_einsum():
    """contract_chain executes the jointly planned schedule and still
    matches the composed float64 reference; masks propagate through the
    chain via the inferred output masks."""
    from repro.core import decay_block_mask

    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased")
    rng = np.random.default_rng(5)
    am = decay_block_mask(4, 4, decay=0.6, threshold=5e-2)
    x = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32)),
        block_shape=(16, 16), mask=am,
    )
    y1 = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32)),
        block_shape=(16, 16), mask=am,
    )
    y2 = BlockSparseTensor.from_dense(
        jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32)),
        block_shape=(16, 12),
    )
    res, report = contract_chain(
        [("ab,bc->ac", x, y1), ("ab,bc->ac", y2)], mm=mm, tune=True
    )
    ref = (
        x.to_dense().astype(np.float64) @ y1.to_dense().astype(np.float64)
    ) @ np.asarray(y2.data, np.float64)
    np.testing.assert_allclose(
        np.asarray(res.data), ref, atol=5e-4, rtol=1e-4
    )
    assert report["joint_makespan_s"] <= report["sequential_makespan_s"]
    assert len(report["lookaheads"]) == 2
    assert res.mask is not None  # step-1 mask propagated through


# ---------------------------------------------------------------------------
# hypothesis: matricization properties over random (nonuniform) tilings
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def _tiling(draw, max_extent=18):
        extent = draw(st.integers(2, max_extent))
        nblocks = draw(st.integers(1, min(4, extent)))
        seed = draw(st.integers(0, 2**16))
        if draw(st.booleans()):
            block = max(1, extent // nblocks)
            return uniform_tiling(extent, block)
        return nonuniform_tiling(extent, nblocks, seed=seed)

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(
        tilings=st.lists(_tiling(), min_size=1, max_size=3),
        seed=st.integers(0, 2**16),
    )
    def test_hyp_merge_round_trip(tilings, seed):
        """matricize -> unmatricize is the identity on data and masks,
        and preserves fill exactly, for any mode split."""
        rng = np.random.default_rng(seed)
        tilings = tuple(tilings)
        grid = tuple(t.num_blocks for t in tilings)
        mask = rng.random(grid) < 0.5
        modes = tuple("abcd"[: len(tilings)])
        for cut in range(len(tilings) + 1):
            row_modes, col_modes = modes[:cut], modes[cut:]
            m2 = matricize_mask(mask, modes, row_modes, col_modes)
            back = unmatricize_mask(
                m2, row_modes, col_modes,
                dict(zip(modes, grid)), modes,
            )
            np.testing.assert_array_equal(back, mask.reshape(grid or (1,)))
            # fill preservation (area-weighted)
            row_t, _ = merge_tilings(tilings[:cut])
            col_t, _ = merge_tilings(tilings[cut:])
            x = BlockSparseTensor(
                data=jnp.zeros(tuple(t.extent for t in tilings)),
                tilings=tilings, mask=mask,
            )
            x2 = BlockSparseTensor(
                data=jnp.zeros((row_t.extent, col_t.extent)),
                tilings=(row_t, col_t), mask=m2,
            )
            assert x2.fill() == pytest.approx(x.fill(), abs=1e-12)

    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    @given(
        t1=_tiling(max_extent=12),
        t2=_tiling(max_extent=12),
        seed=st.integers(0, 2**16),
    )
    def test_hyp_merged_perm_is_block_gather(t1, t2, seed):
        """The merged permutation maps every tensor block to one
        contiguous matrix range (the contract() correctness kernel)."""
        rng = np.random.default_rng(seed)
        merged, perm = merge_tilings((t1, t2))
        data = rng.normal(size=(t1.extent, t2.extent))
        flat = data.ravel()
        flat = flat[perm] if perm is not None else flat
        off = 0
        for b1 in range(t1.num_blocks):
            r0 = t1.offsets[b1]
            for b2 in range(t2.num_blocks):
                c0 = t2.offsets[b2]
                blk = data[
                    r0 : r0 + t1.sizes[b1], c0 : c0 + t2.sizes[b2]
                ]
                n = blk.size
                np.testing.assert_array_equal(
                    np.sort(flat[off : off + n]), np.sort(blk.ravel())
                )
                off += n
