"""Property tests (hypothesis) for blocking/sparsity invariants —
over-decomposition load-balance is the paper's central quantitative claim.

hypothesis is a dev extra (pyproject ``[dev]``); without it this module
skips instead of breaking tier-1 collection."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import blocking as bk
from repro.core import sparsity as sp


@given(
    extent=st.integers(8, 4096),
    block=st.integers(1, 512),
)
def test_uniform_tiling_covers_extent(extent, block):
    t = bk.uniform_tiling(extent, block)
    assert t.extent == extent
    assert all(s == block for s in t.sizes[:-1])
    assert 0 < t.sizes[-1] <= block


@given(
    extent=st.integers(16, 8192),
    num_blocks=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_nonuniform_tiling_paper_procedure(extent, num_blocks, seed):
    """§4.1: total rows preserved, every block nonempty, count preserved."""
    num_blocks = min(num_blocks, extent)
    t = bk.nonuniform_tiling(extent, num_blocks, seed=seed)
    assert t.extent == extent
    assert t.num_blocks == num_blocks
    assert all(s >= 1 for s in t.sizes)


@given(
    extent=st.integers(16, 2048),
    num_blocks=st.integers(1, 32),
    tile=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(0, 1000),
)
def test_bucketize_invariants(extent, num_blocks, tile, seed):
    num_blocks = min(num_blocks, extent)
    t = bk.nonuniform_tiling(extent, num_blocks, seed=seed)
    b = bk.bucketize(t, tile)
    # all real elements appear exactly once, in order
    idx = b.gather_indices()
    valid = idx[idx >= 0]
    assert len(valid) == extent
    assert np.array_equal(np.sort(valid), np.arange(extent))
    # waste bounded by (tile-1) per logical block
    assert 0 <= b.padding_waste < 1
    assert b.padded_extent - extent <= (tile - 1) * num_blocks
    # per-tile valid counts match logical sizes
    per_block = {}
    for bid, v in zip(b.block_id, b.valid):
        per_block[bid] = per_block.get(bid, 0) + v
    assert per_block == {i: s for i, s in enumerate(t.sizes)}


@given(seed=st.integers(0, 100))
@settings(deadline=None)
def test_overdecomposition_shrinks_imbalance(seed):
    """The paper's §4.4 claim: cyclic embedding of many blocks per process
    reduces effective imbalance far below block-level imbalance."""
    n, blocks = 8192, 64
    rt = bk.nonuniform_tiling(n, blocks, seed=seed)
    ct = bk.nonuniform_tiling(n, blocks, seed=seed + 1)
    block_stats = bk.load_stats(rt, ct)
    proc_stats = bk.load_stats(rt, ct, grid=(4, 4))
    assert proc_stats.memory_min_max <= block_stats.memory_min_max
    assert proc_stats.work_min_max <= block_stats.work_min_max


@given(
    mb=st.integers(1, 24),
    nb=st.integers(1, 24),
    fill=st.floats(0.05, 1.0),
    seed=st.integers(0, 1000),
)
def test_block_csr_roundtrip(mb, nb, fill, seed):
    mask = sp.random_block_mask(mb, nb, fill, seed=seed)
    csr = sp.block_csr_from_mask(mask)
    assert np.array_equal(csr.to_dense(), mask)
    assert csr.nnz == mask.sum()
    padded = csr.padded_cols()
    lengths = csr.row_lengths()
    for i in range(mb):
        row = padded[i]
        assert np.all(row[: lengths[i]] >= 0)
        assert np.all(row[lengths[i]:] == -1)


@given(
    mb=st.integers(1, 12),
    kb=st.integers(1, 12),
    nb=st.integers(1, 12),
    fill=st.floats(0.1, 1.0),
)
def test_mask_flops_bounds(mb, kb, nb, fill):
    a = sp.random_block_mask(mb, kb, fill, seed=1)
    b = sp.random_block_mask(kb, nb, fill, seed=2)
    sparse, dense = sp.mask_matmul_flops(a, b, 8, 8, 8)
    assert 0 <= sparse <= dense
    if fill == 1.0:
        assert sparse == dense


def test_paper_table1_regime():
    """Table 1: block-level min:max for the paper's sizes lands in the
    reported band (memory ~1:3-1:4, work ~1:4.5-1:7.2)."""
    mems, works = [], []
    for n in (32768, 65536):
        rt = bk.nonuniform_tiling(n, n // 256, seed=n)
        ct = bk.nonuniform_tiling(n, n // 256, seed=n + 7)
        s = bk.load_stats(rt, ct)
        mems.append(s.memory_min_max)
        works.append(s.work_min_max)
    assert all(1.5 < m < 8.0 for m in mems), mems
    assert all(2.0 < w < 12.0 for w in works), works
