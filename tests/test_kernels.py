"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparsity import random_block_mask
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _arr(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,k,n", [(64, 64, 64), (128, 256, 64), (96, 160, 224), (100, 60, 36)]
)
def test_tiled_matmul(m, k, n, dtype):
    a, b = _arr((m, k), dtype), _arr((k, n), dtype)
    out = ops.tiled_matmul(a, b, bm=64, bk=64, bn=64)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype) * k ** 0.5,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("fill", [0.1, 0.4, 1.0])
@pytest.mark.parametrize("mb,kb", [(4, 8), (2, 2), (8, 4)])
def test_bsmm(fill, mb, kb, dtype):
    m, k, n = mb * 32, kb * 32, 96
    a, b = _arr((m, k), dtype), _arr((k, n), dtype)
    mask = random_block_mask(mb, kb, fill, seed=int(fill * 10) + mb)
    out = ops.bsmm(a, b, mask, bn=32)
    want = ref.bsmm_ref(a, b, mask)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype) * k ** 0.5,
    )


def test_bsmm_empty_rows_give_zero():
    mask = np.zeros((4, 4), dtype=bool)
    mask[0, 0] = True  # only one live block
    a, b = _arr((128, 128), jnp.float32), _arr((128, 64), jnp.float32)
    out = np.asarray(ops.bsmm(a, b, mask, bn=32))
    assert np.all(out[32:] == 0.0)
    assert np.any(out[:32] != 0.0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d,f,e,bt", [(256, 64, 96, 4, 64), (512, 128, 64, 8, 128)])
def test_grouped_gemm(t, d, f, e, bt, dtype):
    x = _arr((t, d), dtype)
    w = _arr((e, d, f), dtype)
    te = jnp.asarray(RNG.integers(0, e, size=t // bt), jnp.int32)
    out = ops.grouped_gemm(x, w, te, bt=bt, bk=64, bn=32)
    want = ref.grouped_gemm_ref(x, w, te, bt)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype) * d ** 0.5,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "h,hkv,s,causal,window",
    [
        (4, 2, 256, True, None),
        (4, 1, 256, True, 64),
        (2, 2, 128, False, None),
        (8, 4, 512, True, 128),
    ],
)
def test_flash_attention(h, hkv, s, causal, window, dtype):
    b, dh = 2, 64
    q = _arr((b, h, s, dh), dtype)
    k = _arr((b, hkv, s, dh), dtype)
    v = _arr((b, hkv, s, dh), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, bq=128, bk=128)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 2e-3,
        atol=2e-2 if dtype == jnp.bfloat16 else 2e-3,
    )


def test_flash_attention_fully_masked_rows():
    """window smaller than block: early rows attend to <= window keys."""
    b, h, s, dh = 1, 2, 256, 64
    q, k, v = (_arr((b, h, s, dh), jnp.float32) for _ in range(3))
    out = ops.flash_attention(q, k, v, causal=True, window=8, bq=128, bk=128)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-3, atol=2e-3)
