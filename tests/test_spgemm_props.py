"""Property tests (hypothesis) for the SpGEMM stationarity chooser and
the symbolic output-structure pass — random shape/grid/structure triples
against the modeled-comm argmin contract and the reachability semantics.

hypothesis is a dev extra (pyproject ``[dev]``); without it this module
skips rather than fails (CI installs ``[dev]`` and asserts it imports).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import plan_matmul, random_block_mask  # noqa: E402
from repro.core.summa import SummaConfig  # noqa: E402
from repro.spgemm import (  # noqa: E402
    STATIONARITIES,
    choose_stationarity,
    output_mask,
)


class FakeMesh:
    def __init__(self, sizes):
        self.shape = sizes


def _grid_cfg(p_row, p_col, **kw):
    return SummaConfig(
        mesh=FakeMesh({"data": p_row, "model": p_col}),
        row_axis="data",
        col_axis="model",
        **kw,
    )


_dims = st.integers(min_value=32, max_value=512).map(lambda v: v // 32 * 32)
_grid = st.integers(min_value=1, max_value=8)


@settings(max_examples=60, deadline=None)
@given(m=_dims, k=_dims, n=_dims, p_row=_grid, p_col=_grid)
def test_chosen_stationarity_minimizes_modeled_comm(m, k, n, p_row, p_col):
    best, vols = choose_stationarity(
        None, None, m=m, k=k, n=n, p_row=p_row, p_col=p_col, itemsize=4
    )
    assert vols[best] <= min(vols.values())
    # strict-< argmin: on a tie the earlier of ("C", "A", "B") wins, so
    # "C" survives every all-zero-volume (single-device) grid
    for s in STATIONARITIES:
        if vols[s] < vols[best]:
            raise AssertionError((best, vols))


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    fill=st.floats(min_value=0.1, max_value=0.9),
)
def test_chosen_stationarity_minimizes_masked_comm(seed, fill):
    am = random_block_mask(4, 4, fill, seed=seed)
    bm = random_block_mask(4, 4, fill, seed=seed + 1)
    best, vols = choose_stationarity(
        am, bm, m=128, k=128, n=128, p_row=2, p_col=4, itemsize=4,
        c_structure=output_mask(am, bm),
    )
    assert vols[best] <= min(vols.values())


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    fill=st.floats(min_value=0.2, max_value=0.8),
)
def test_c_stationary_choice_preserves_plan_digest(seed, fill):
    """When "auto" resolves to C-stationary, the plan must be bitwise
    the default plan (same digest): today's behaviour is reproduced
    exactly whenever the chooser does not move."""
    cfg = _grid_cfg(2, 2)
    am = random_block_mask(4, 4, fill, seed=seed)
    bm = random_block_mask(4, 4, fill, seed=seed + 1)
    auto = plan_matmul(
        128, 128, 128, cfg, a_mask=am, b_mask=bm, stationarity="auto"
    )
    if auto.stationarity == "C":
        default = plan_matmul(128, 128, 128, cfg, a_mask=am, b_mask=bm)
        assert auto.digest() == default.digest()


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    mb=st.integers(min_value=1, max_value=8),
    kb=st.integers(min_value=1, max_value=8),
    nb=st.integers(min_value=1, max_value=8),
)
def test_output_mask_never_misses_reachable_blocks(seed, mb, kb, nb):
    rng = np.random.default_rng(seed)
    am = rng.random((mb, kb)) < 0.5
    bm = rng.random((kb, nb)) < 0.5
    cm = output_mask(am, bm)
    for i in range(mb):
        for j in range(nb):
            reachable = any(am[i, kk] and bm[kk, j] for kk in range(kb))
            assert cm[i, j] == reachable
