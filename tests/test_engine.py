"""Compiled execution engine: plan-digest keys, executable caching,
and the zero-retrace guarantee.

The engine's contract (core/summa.py executable cache + the compiled
step programs in core/contract.py) is that a *repeat* call with
identical geometry performs zero retraces and zero cache misses — the
whole hot path is one cached dispatch.  These tests pin that contract
via the observable counters (``DistributedMatmul.cache_stats()`` /
``executable_cache_stats()``) instead of timing, so they are stable on
any machine.
"""
import dataclasses

import numpy as np
import pytest

from conftest import contract_case
from repro.core import (
    DistributedMatmul,
    clear_executable_cache,
    executable_cache_stats,
    warm_plan_executable,
)
from repro.launch.mesh import make_host_mesh


def _delta(before: dict, after: dict) -> dict:
    return {k: after[k] - before[k] for k in after if k in before}


def _mm():
    return DistributedMatmul(make_host_mesh(1, 1), strategy="taskbased")


# ---------------------------------------------------------------------------
# plan digests: the executable cache key must be stable and sensitive
# ---------------------------------------------------------------------------


def test_plan_digest_stable_across_calls():
    mm = _mm()
    rng = np.random.default_rng(0)
    plan = mm.plan(64, 96, 80, b_mask=rng.random((8, 4)) < 0.6)
    assert plan.digest() == plan.digest()
    # an identically-built plan hashes identically
    mm2 = _mm()
    plan2 = mm2.plan(64, 96, 80, b_mask=plan.b_mask)
    assert plan2.digest() == plan.digest()


def test_plan_digest_sensitive_to_execution_fields():
    mm = _mm()
    rng = np.random.default_rng(1)
    mask = rng.random((8, 4)) < 0.6
    plan = mm.plan(64, 96, 80, b_mask=mask)
    # lookahead changes the issue schedule => must change the digest
    bumped = dataclasses.replace(
        plan, lookahead=plan.resolve_lookahead() + 1
    )
    assert bumped.digest() != plan.digest()
    # a different mask changes the task DAG => must change the digest
    other = mm.plan(64, 96, 80, b_mask=~mask)
    assert other.digest() != plan.digest()
    # a different geometry => different digest
    wider = mm.plan(64, 96, 160)
    assert wider.digest() != plan.digest()


# ---------------------------------------------------------------------------
# executable cache: warm => hit; retraces never exceed misses
# ---------------------------------------------------------------------------


def test_warm_plan_executable_populates_cache():
    import jax.numpy as jnp

    from repro.core import summa as sm

    clear_executable_cache()
    mm = _mm()
    rng = np.random.default_rng(2)
    mask = rng.random((4, 4)) < 0.7
    plan = mm.plan(64, 64, 64, a_mask=mask)
    assert warm_plan_executable(plan, jnp.float32)
    warmed = executable_cache_stats()
    assert warmed["misses"] >= 1 and warmed["size"] >= 1
    a = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    out = sm.execute_plan(a, b, plan)
    after = executable_cache_stats()
    d = _delta(warmed, after)
    assert d["hits"] == 1 and d["misses"] == 0 and d["retraces"] == 0
    a_np = np.asarray(a) * np.kron(mask, np.ones((16, 16), np.float32))
    np.testing.assert_allclose(
        np.asarray(out), a_np @ np.asarray(b), atol=5e-4, rtol=1e-4,
    )


def test_executable_retraces_never_exceed_misses():
    """A retrace without a miss means a cache key failed to capture
    something the trace depends on — the core invariant of the cache."""
    stats = executable_cache_stats()
    assert stats["retraces"] <= stats["misses"]


# ---------------------------------------------------------------------------
# contract(): repeat call with identical geometry => 100% hit, 0 retrace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["free2", "batch", "rank_sparse",
                                    "nonuniform"])
def test_contract_repeat_is_all_hits(family):
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased")
    case = contract_case(family, seed=13)
    out1 = mm.contract(case["spec"], case["x"], case["y"], tile=case["tile"])
    before = mm.cache_stats()
    out2 = mm.contract(case["spec"], case["x"], case["y"], tile=case["tile"])
    after = mm.cache_stats()
    d = _delta(before["contract"], after["contract"])
    assert d["step_misses"] == 0, d
    assert d["step_retraces"] == 0, d
    assert d["step_hits"] >= 1, d
    assert d["geom_misses"] == 0, d
    exec_d = _delta(before["executable"], after["executable"])
    assert exec_d.get("retraces", 0) == 0, exec_d
    np.testing.assert_array_equal(
        np.asarray(out1.data), np.asarray(out2.data)
    )


def test_contract_repeat_fresh_data_same_geometry_is_all_hits():
    """New operand *values* with the same block structure must reuse the
    compiled program (data is a runtime argument, not a baked constant)
    and still produce correct results."""
    import jax.numpy as jnp

    from repro.core import BlockSparseTensor

    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased")
    rng = np.random.default_rng(17)
    mask = rng.random((4, 8)) < 0.6

    def operands(seed):
        r = np.random.default_rng(seed)
        x = BlockSparseTensor.from_dense(
            jnp.asarray(r.normal(size=(64, 96)).astype(np.float32)),
            block_shape=(16, 12), mask=mask,
        )
        y = BlockSparseTensor.from_dense(
            jnp.asarray(r.normal(size=(96, 80)).astype(np.float32)),
            block_shape=(12, 20),
        )
        return x, y

    x1, y1 = operands(1)
    mm.contract("ab,bc->ac", x1, y1, tile=64)
    before = mm.cache_stats()
    x2, y2 = operands(2)
    out = mm.contract("ab,bc->ac", x2, y2, tile=64)
    d = _delta(before["contract"], mm.cache_stats()["contract"])
    assert d["step_misses"] == 0 and d["step_retraces"] == 0, d
    ref = np.einsum(
        "ab,bc->ac",
        np.asarray(x2.to_dense(), np.float64),
        np.asarray(y2.to_dense(), np.float64),
    )
    np.testing.assert_allclose(
        np.asarray(out.data), ref, atol=5e-4, rtol=1e-4
    )


def test_contract_chain_repeat_is_all_hits():
    import jax.numpy as jnp

    from repro.core import BlockSparseTensor, contract_chain

    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased")
    rng = np.random.default_rng(23)

    def dense(shape, block):
        return BlockSparseTensor.from_dense(
            jnp.asarray(rng.normal(size=shape).astype(np.float32)),
            block_shape=block,
        )

    x = dense((64, 96), (16, 12))
    y1 = dense((96, 80), (12, 20))
    y2 = dense((80, 48), (20, 12))
    steps = [("ab,bc->ac", x, y1), ("ab,bc->ac", y2)]
    out1, _ = contract_chain(steps, mm=mm, tile=64)
    before = mm.cache_stats()
    out2, _ = contract_chain(steps, mm=mm, tile=64)
    d = _delta(before["contract"], mm.cache_stats()["contract"])
    assert d["step_misses"] == 0, d
    assert d["step_retraces"] == 0, d
    assert d["step_hits"] >= 1, d
    np.testing.assert_array_equal(
        np.asarray(out1.data), np.asarray(out2.data)
    )
    ref = (
        np.asarray(x.to_dense(), np.float64)
        @ np.asarray(y1.to_dense(), np.float64)
        @ np.asarray(y2.to_dense(), np.float64)
    )
    np.testing.assert_allclose(
        np.asarray(out2.data), ref, atol=5e-3, rtol=1e-3
    )


# ---------------------------------------------------------------------------
# cache_stats(): shape of the observability surface
# ---------------------------------------------------------------------------


def test_cache_stats_shape_and_reset():
    mm = _mm()
    stats = mm.cache_stats()
    assert set(stats) == {"plan", "contract", "executable"}
    assert set(stats["plan"]) == {"size", "hits", "misses"}
    assert {"geom_hits", "geom_misses", "step_hits", "step_misses",
            "step_retraces"} <= set(stats["contract"])
    assert {"hits", "misses", "retraces", "size"} <= set(stats["executable"])
    rng = np.random.default_rng(3)
    mm.plan(64, 64, 64, b_mask=rng.random((4, 4)) < 0.5)
    assert mm.cache_stats()["plan"]["misses"] == 1
    mm.reset_cache_stats()
    s = mm.cache_stats()
    assert s["plan"]["hits"] == 0 and s["plan"]["misses"] == 0
