"""Norm filtering, stationary task graphs, rank pull, and the kernel
autotune cache (the DBCSR-style runtime-sparsity layer).

Covers the four legs of the on-the-fly filtering PR:

* ``filter_keep`` / plan-level screening — monotone task reduction in
  ``filter_eps``, the additive error bound, and the ``filter_eps=0``
  bitwise digest no-op;
* executed filtering — measured error within the documented bound on the
  host mesh, and the filtered ``contract_chain`` propagating *filtered*
  predecessor structure (not the symbolic product) into later steps;
* the A-/B-stationary task graphs and the tuner searching them (an
  explicitly A-stationary plan must tune without silently falling back
  to a C-stationary DAG);
* the one-sided pull schedule for rank-sparse operands — fetch tasks
  sized by the U/V factors, pinned bitwise against the broadcast rank
  path on a real 2x2 mesh;
* the kernel autotune cache — lookup-only consults, winner-never-loses,
  JSON persistence, and the empty-cache fingerprint contract that keeps
  executable cache keys bitwise pre-autotune.
"""
import numpy as np
import pytest

from repro.core.plan import plan_matmul
from repro.core.sparsity import BlockRankMap, block_norms
from repro.sched import abstract_summa_config, from_plan, simulate
from repro.sched.tuner import tune_plan
from repro.spgemm import filter_keep, output_norms


def _decay_norms(blocks: int, decay: float = 0.8) -> np.ndarray:
    i = np.arange(blocks)
    return np.exp(-decay * np.abs(i[:, None] - i[None, :])) + 1e-9


# ---------------------------------------------------------------------------
# filter_keep / output_norms units
# ---------------------------------------------------------------------------


def test_filter_keep_monotone_and_bound():
    an = _decay_norms(8)
    bn = _decay_norms(8, 0.5)
    prev_kept = None
    for eps in (0.0, 0.05, 0.2, 1.0, 10.0):
        keep, bound = filter_keep(an, bn, eps)
        assert keep.shape == (8, 8, 8)
        kept = int(keep.sum())
        if prev_kept is not None:
            assert kept <= prev_kept, (eps, kept, prev_kept)
        prev_kept = kept
        # the bound is exactly the mass of what was dropped
        prods = an[:, :, None] * bn[None, :, :]
        assert bound == pytest.approx(float(prods[~keep].sum()))
    # eps=0 keeps every nonzero product
    keep0, bound0 = filter_keep(an, bn, 0.0)
    assert keep0.all() and bound0 == 0.0


def test_output_norms_respects_keep():
    an = _decay_norms(4)
    bn = _decay_norms(4)
    keep, _ = filter_keep(an, bn, 0.3)
    cn = output_norms(an, bn, keep)
    full = output_norms(an, bn, None)
    assert (cn <= full + 1e-12).all()
    # a C block with no surviving addends bounds to zero
    dead = ~keep.any(axis=1)
    assert (cn[dead] == 0.0).all()


# ---------------------------------------------------------------------------
# plan-level screening
# ---------------------------------------------------------------------------


def _gemms(graph) -> int:
    return sum(1 for t in graph.tasks if t.kind == "gemm" and t.flops > 0)


def test_plan_filter_monotone_tasks_and_digest_noop():
    blocks, n = 8, 256
    cfg = abstract_summa_config(2, 2, strategy="taskbased", k_blocks=blocks)
    an = _decay_norms(blocks)
    bn = _decay_norms(blocks, 0.5)
    base = plan_matmul(n, n, n, cfg)
    # eps=0 with norms is a strict no-op: bitwise-identical digest
    eps0 = plan_matmul(n, n, n, cfg, a_norms=an, b_norms=bn, filter_eps=0.0)
    assert eps0.digest() == base.digest()
    assert eps0.filter_bound == 0.0

    prev = None
    prev_bound = 0.0
    base_ms = simulate(from_plan(base)).makespan_s
    for eps in (0.05, 0.2, 1.0):
        p = plan_matmul(n, n, n, cfg, a_norms=an, b_norms=bn, filter_eps=eps)
        g = from_plan(p)
        ng = _gemms(g)
        assert ng <= (prev if prev is not None else _gemms(from_plan(base)))
        prev = ng
        assert p.filter_bound >= prev_bound
        prev_bound = p.filter_bound
        # filtered structure enters the digest: distinct eps, distinct key
        assert p.digest() != base.digest()
        # and the filtered schedule never simulates slower
        assert simulate(g).makespan_s <= base_ms * (1 + 1e-9)


def test_plan_filter_requires_norm_pair():
    cfg = abstract_summa_config(2, 2, k_blocks=4)
    an = _decay_norms(4)
    with pytest.raises(ValueError, match="pairs"):
        plan_matmul(64, 64, 64, cfg, a_norms=an)
    with pytest.raises(ValueError, match="needs per-block norms"):
        plan_matmul(64, 64, 64, cfg, filter_eps=0.5)


def test_executed_filter_error_within_bound():
    from repro.core import DistributedMatmul
    from repro.launch.mesh import make_host_mesh

    blocks, n = 8, 128
    bs = n // blocks
    rng = np.random.default_rng(3)
    decay = _decay_norms(blocks)

    def mat():
        x = rng.standard_normal((n, n))
        return (
            x.reshape(blocks, bs, blocks, bs) * decay[:, None, :, None]
        ).reshape(n, n)

    a64, b64 = mat(), mat()
    an = block_norms(a64, blocks, blocks)
    bn = block_norms(b64, blocks, blocks)
    ref = a64 @ b64
    mm = DistributedMatmul(
        make_host_mesh(1, 1), strategy="taskbased", k_blocks=blocks
    )
    import jax.numpy as jnp

    a32, b32 = jnp.asarray(a64, jnp.float32), jnp.asarray(b64, jnp.float32)
    pmax = float(np.max(an[:, :, None] * bn[None, :, :]))
    for frac in (1e-3, 1e-2, 0.1):
        eps = frac * pmax
        p = mm.plan(n, n, n, a_norms=an, b_norms=bn, filter_eps=eps)
        out = np.asarray(
            mm(a32, b32, a_norms=an, b_norms=bn, filter_eps=eps), np.float64
        )
        err = float(np.linalg.norm(out - ref))
        slack = 1e-5 * float(np.linalg.norm(ref))  # f32 execution noise
        assert err <= p.filter_bound + slack, (eps, err, p.filter_bound)
    # eps=0 returns the unfiltered product bitwise
    out0 = np.asarray(mm(a32, b32, a_norms=an, b_norms=bn, filter_eps=0.0))
    plain = np.asarray(mm(a32, b32))
    assert np.array_equal(out0, plain)


# ---------------------------------------------------------------------------
# filtered contract / contract_chain (filtered predecessor propagation)
# ---------------------------------------------------------------------------


def _chain_operands(n=128, blocks=8, seed=5):
    import jax.numpy as jnp

    from repro.core.contract import BlockSparseTensor

    bs = n // blocks
    rng = np.random.default_rng(seed)
    decay = _decay_norms(blocks, 1.0)

    def mk():
        x = rng.standard_normal((n, n)).astype(np.float32)
        fine = (
            x.reshape(blocks, bs, blocks, bs) * decay[:, None, :, None]
        ).reshape(n, n)
        return BlockSparseTensor.from_dense(
            jnp.asarray(fine), block_shape=(bs, bs)
        )

    return mk(), mk(), mk()


def test_contract_filter_error_and_structure():
    from repro.core import DistributedMatmul
    from repro.launch.mesh import make_host_mesh

    xa, xb, _ = _chain_operands()
    mm = DistributedMatmul(make_host_mesh(1, 1), strategy="taskbased")
    exact = np.asarray(xa.to_dense(), np.float64) @ np.asarray(
        xb.to_dense(), np.float64
    )
    an = xa.block_norms()
    bn = xb.block_norms()
    eps = 0.05 * float(np.max(an[:, :, None] * bn[None, :, :]))
    out = mm.contract("ik,kj->ij", xa, xb, filter_eps=eps)
    n = exact.shape[0]
    p = mm.plan(n, n, n, a_norms=an, b_norms=bn, filter_eps=eps)
    err = float(np.linalg.norm(np.asarray(out.data, np.float64) - exact))
    assert err <= p.filter_bound + 1e-5 * float(np.linalg.norm(exact))
    # the filtered result carries its refined structure + norm bounds
    assert out.mask is not None and not out.mask.all()
    assert out.norms is not None
    assert (np.asarray(out.norms)[~np.asarray(out.mask)] == 0.0).all()
    # unfiltered contract of dense operands stays structure-free
    out0 = mm.contract("ik,kj->ij", xa, xb)
    assert out0.mask is None


def test_contract_chain_filtered_propagation():
    """Satellite regression: step 2 must plan against the *filtered*
    step-1 structure, so chains get progressively sparser with eps."""
    from repro.core import DistributedMatmul
    from repro.launch.mesh import make_host_mesh

    xa, xb, xc = _chain_operands()
    mm = DistributedMatmul(make_host_mesh(1, 1), strategy="taskbased")
    steps = [("ik,kj->ij", xa, xb), ("ik,kj->ij", xc)]
    _, rep0 = mm.contract_chain(steps)
    prev_fill = rep0["plans"][1]["fill_in"]
    an = xa.block_norms()
    bn = xb.block_norms()
    pmax = float(np.max(an[:, :, None] * bn[None, :, :]))
    for frac in (1e-3, 1e-2, 0.1):
        res, rep = mm.contract_chain(steps, filter_eps=frac * pmax)
        fill2 = rep["plans"][1]["fill_in"]
        assert fill2 <= prev_fill + 1e-12, (frac, fill2, prev_fill)
        prev_fill = fill2
        assert len(rep["filter_bounds"]) == 2
        assert all(b >= 0.0 for b in rep["filter_bounds"])
        assert res.mask is not None
    # the tightest sweep entry must have strictly pruned step 2
    assert prev_fill < rep0["plans"][1]["fill_in"]


# ---------------------------------------------------------------------------
# A-/B-stationary task graphs + tuner search (satellite bugfix)
# ---------------------------------------------------------------------------


def _masked_plan(stationarity="C", p_row=2, p_col=2, blocks=8, n=256):
    from repro.core.sparsity import banded_block_mask

    cfg = abstract_summa_config(
        p_row, p_col, strategy="taskbased", k_blocks=blocks
    )
    mask = banded_block_mask(blocks, blocks, 2)
    return plan_matmul(
        n, n, n, cfg, a_mask=mask, b_mask=mask, stationarity=stationarity
    )


@pytest.mark.parametrize("stat", ["A", "B"])
def test_stationary_taskgraph_materializes(stat):
    plan = _masked_plan(stat)
    g = from_plan(plan)
    g.validate()
    assert g.meta["strategy"] == "stationary"
    assert g.meta["stationarity"] == stat
    kinds = {t.kind for t in g.tasks}
    relay = "bcast_b" if stat == "A" else "bcast_a"
    assert relay in kinds, kinds
    assert "reduce" in kinds and "gemm" in kinds and "accum" in kinds
    # one local dot per device, one reduce per stationary-operand group
    assert sum(1 for t in g.tasks if t.kind == "gemm") == 4
    n_reduce = sum(1 for t in g.tasks if t.kind == "reduce")
    assert n_reduce == (plan.p_row if stat == "A" else plan.p_col)
    # the schedule simulates (simulator is kind-agnostic)
    assert simulate(g).makespan_s > 0


def test_stationary_flops_conserve_work():
    """The transposed schedules shard K differently but the total dense
    local-dot work must match the C-stationary gemm total."""
    flops = {}
    for stat in ("C", "A", "B"):
        g = from_plan(_masked_plan(stat))
        flops[stat] = sum(t.flops for t in g.tasks if t.kind == "gemm")
    # C-stationary prunes masked-out panel products; the stationary
    # schedules run dense local dots, so they bound it from above and
    # agree with each other exactly.
    assert flops["A"] == pytest.approx(flops["B"])
    assert flops["A"] >= flops["C"]


@pytest.mark.parametrize("stat", ["A", "B"])
def test_tuner_does_not_fall_back_on_stationary_plans(stat):
    """tune=True on an explicitly A-/B-stationary plan must simulate that
    schedule, not silently re-tune a C-stationary DAG."""
    tuned = tune_plan(_masked_plan(stat))
    assert tuned.stationarity == stat
    assert tuned.tuned["stationarity"] == stat
    assert tuned.tuned["n_candidates"] == 1


def test_tuner_searches_stationarity_for_masked_plans():
    tuned = tune_plan(_masked_plan("C"))
    rec = tuned.tuned
    # C-candidates (broadcast + pull windows) plus one A and one B
    assert rec["n_candidates"] >= 4
    assert rec["stationarity"] in ("C", "A", "B")
    assert tuned.stationarity == rec["stationarity"]


# ---------------------------------------------------------------------------
# rank-sparse pull schedule (satellite bugfix)
# ---------------------------------------------------------------------------


def _rank_pull_plans(blocks=8, n=256, rank=2):
    cfg = abstract_summa_config(2, 2, strategy="taskbased", k_blocks=blocks)
    bs = n // blocks
    ranks = np.full((blocks, blocks), rank, np.int32)
    rank_plan = plan_matmul(
        n, n, n, cfg,
        a_ranks=BlockRankMap(ranks=ranks, bm=bs, bk=bs),
        comm_mode="pull",
    )
    dense_mask_plan = plan_matmul(
        n, n, n, cfg, a_mask=np.ones((blocks, blocks), bool),
        b_mask=np.ones((blocks, blocks), bool), comm_mode="pull",
    )
    return rank_plan, dense_mask_plan


def test_rank_pull_fetches_factor_bytes():
    rank_plan, mask_plan = _rank_pull_plans()
    assert rank_plan.local_impl == "ranksparse"
    g_rank = from_plan(rank_plan)
    g_mask = from_plan(mask_plan)
    for g in (g_rank, g_mask):
        g.validate()
        assert {"fetch_a", "fetch_b"} <= {t.kind for t in g.tasks}

    def fetch_a_bytes(g):
        return sum(t.bytes for t in g.tasks if t.kind == "fetch_a")

    # low-rank factor panels (U rows + V panel) are far smaller than the
    # dense A panels the masked pull graph moves
    assert fetch_a_bytes(g_rank) < 0.5 * fetch_a_bytes(g_mask)


def test_rank_pull_tuner_considers_pull():
    rank_plan, _ = _rank_pull_plans()
    tuned = tune_plan(rank_plan)
    assert tuned.tuned["comm_mode"] in ("broadcast", "pull")
    # both modes were simulated (lookahead sweep per mode)
    assert tuned.tuned["n_candidates"] >= 2


def test_rank_pull_matches_broadcast_bitwise(subproc):
    """The factor-fetching pull executor is pinned bitwise against the
    broadcast rank path — same local arithmetic, different transport."""
    subproc(
        """
import numpy as np
import jax.numpy as jnp
from conftest import spgemm_case
from repro.core import DistributedMatmul
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ("data", "model"))
case = spgemm_case("rank_random", seed=13)
mm = DistributedMatmul(mesh, strategy="taskbased")
outs = {}
for mode in ("broadcast", "pull"):
    outs[mode] = np.asarray(mm(
        None, jnp.asarray(case["b"]), a_ranks=case["a_ranks"],
        b_mask=case["b_mask"], c_mask=case["c_mask"], comm_mode=mode,
    ))
assert np.array_equal(outs["broadcast"], outs["pull"]), (
    float(np.abs(outs["broadcast"] - outs["pull"]).max()))
err = float(np.abs(outs["pull"] - case["ref"]).max())
assert err < 5e-4, err
print("RANK_PULL_PIN_OK")
""",
        devices=4,
    )


# ---------------------------------------------------------------------------
# kernel autotune cache
# ---------------------------------------------------------------------------


@pytest.fixture
def clean_autotune():
    from repro.kernels.autotune import set_autotune_cache

    set_autotune_cache(None)
    yield
    set_autotune_cache(None)


def test_autotune_tune_lookup_persist(tmp_path, clean_autotune):
    from repro.kernels.autotune import KernelAutotuner, bucket_key

    t = KernelAutotuner()
    entry = t.tune(48, 48, 48, repeats=1, routes=("xla", "pallas"))
    # the generic route is always a candidate, so the recorded winner
    # never loses to it on its own bucket
    assert entry["times_s"][entry["winner"]] <= entry["times_s"]["xla"]
    # shape-neighborhood lookups hit the same bucket; misses stay misses
    assert t.lookup(60, 50, 33) is entry
    assert bucket_key(60, 50, 33) == bucket_key(48, 48, 48)
    assert t.lookup(200, 200, 200) is None
    # persistence roundtrip is fingerprint-stable
    path = tmp_path / "autotune.json"
    t.save(str(path))
    r = KernelAutotuner()
    assert r.load(str(path)) == 1
    assert r.fingerprint() == t.fingerprint() != ""


def test_autotune_disabled_and_empty_are_bitwise_off(
    monkeypatch, clean_autotune
):
    from repro.core.summa import _autotune_key_suffix
    from repro.kernels.autotune import KernelAutotuner, set_autotune_cache

    # empty cache: no key suffix (executable keys bitwise pre-autotune)
    assert _autotune_key_suffix() == ()
    # populated but disabled via env: also off
    t = KernelAutotuner()
    t.table[(64, 64, 64, 0, "float32")] = {
        "winner": "xla", "times_s": {"xla": 1.0}, "tiles": None,
    }
    set_autotune_cache(t)
    assert _autotune_key_suffix() != ()
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert t.lookup(64, 64, 64) is None
    assert _autotune_key_suffix() == ()


def test_autotune_winner_steers_local_dot(clean_autotune):
    """A cached pallas winner reroutes ``_local_dot`` and re-keys the
    executable cache — with identical numerics."""
    import jax.numpy as jnp

    from repro.core import DistributedMatmul
    from repro.core import summa as sm
    from repro.kernels.autotune import (
        KernelAutotuner,
        bucket_key,
        set_autotune_cache,
    )
    from repro.launch.mesh import make_host_mesh

    mm = DistributedMatmul(make_host_mesh(1, 1), strategy="taskbased")
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    cold = np.asarray(mm(a, b))
    plan = mm.plan(64, 64, 64)
    m_loc = plan.m_pad // plan.p_row
    n_loc = plan.n_pad // plan.p_col
    warm = KernelAutotuner()
    warm.table[bucket_key(m_loc, plan.kb_width, n_loc)] = {
        "winner": "pallas",
        "times_s": {"pallas": 1e-6, "xla": 2e-6},
        "tiles": [64, 64, 64],
    }
    set_autotune_cache(warm)
    hot = np.asarray(mm(a, b))
    np.testing.assert_allclose(hot, cold, atol=1e-5)
    fp = warm.fingerprint()
    assert any(
        k[-1] == fp for k in sm._EXEC_CACHE if isinstance(k[-1], str)
    )


def test_nonuniform_matmul_auto_tile(clean_autotune):
    from repro.core.api import DistributedMatmul, NonuniformMatmul
    from repro.core.blocking import nonuniform_tiling
    from repro.kernels.autotune import (
        KernelAutotuner,
        bucket_key,
        set_autotune_cache,
    )
    from repro.launch.mesh import make_host_mesh

    mm = DistributedMatmul(make_host_mesh(1, 1), strategy="taskbased")
    rt = nonuniform_tiling(300, 3, seed=1)
    it = nonuniform_tiling(280, 3, seed=2)
    ct = nonuniform_tiling(260, 3, seed=3)
    # cold cache: "auto" falls back to the static default
    nm_cold = NonuniformMatmul(mm, rt, it, ct, tile="auto")
    assert nm_cold.tile == 256
    # a measured 128-bucket winner steers the physical tile choice
    t = KernelAutotuner()
    t.table[bucket_key(128, 128, 128)] = {
        "winner": "xla", "times_s": {"xla": 1e-7}, "tiles": None,
    }
    set_autotune_cache(t)
    nm = NonuniformMatmul(mm, rt, it, ct, tile="auto")
    assert nm.tile == 128
