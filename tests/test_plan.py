"""MatmulPlan: planner accounting, cost model, cache, and the planned
block-sparse execution paths (masked DAG + per-device BSMM kernel).

The hypothesis block at the bottom property-tests the plan invariants
(cost monotonicity in fill and rank, lookahead clamping, per-device
pruning accounting, cache-key stability); like tests/test_blocking.py it
needs the ``[dev]`` extra and simply contributes no tests without it.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockRankMap,
    DistributedMatmul,
    NonuniformMatmul,
    banded_block_mask,
    mask_key,
    nonuniform_tiling,
    plan_matmul,
    rank_key,
    reference_blocksparse_matmul,
    reference_matmul,
)
from repro.core.summa import SummaConfig, summa_25d_matmul
from repro.launch.mesh import make_host_mesh

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # dev extra not installed: plain tests still run
    HAVE_HYPOTHESIS = False


class FakeMesh:
    """Planner/validation only consult ``mesh.shape``."""

    def __init__(self, sizes):
        self.shape = sizes


def _grid_cfg(p_row, p_col, **kw):
    return SummaConfig(
        mesh=FakeMesh({"data": p_row, "model": p_col}),
        row_axis="data",
        col_axis="model",
        **kw,
    )


# ---------------------------------------------------------------------------
# planner accounting
# ---------------------------------------------------------------------------


def test_per_device_pruning_beats_global_on_banded_2x2():
    """Acceptance: on a banded mask over a 2x2 grid the planner's
    per-device skipped-panel counts are strictly greater than the global
    (trace-time) pruning for every device."""
    mask = banded_block_mask(8, 8, 1)
    plan = plan_matmul(64, 64, 64, _grid_cfg(2, 2), a_mask=mask, b_mask=mask)
    assert plan.skipped_panels_global == 0  # every band column is nonzero
    per_dev = plan.skipped_panels_per_device()
    assert per_dev.shape == (2, 2)
    assert (per_dev > plan.skipped_panels_global).all()
    # off-diagonal devices see the fewest live panels on a band
    assert per_dev[0, 1] > per_dev[0, 0]
    assert per_dev[1, 0] > per_dev[1, 1]


def test_dense_plan_has_no_pruning():
    plan = plan_matmul(64, 64, 64, _grid_cfg(2, 2, k_blocks=4))
    assert plan.local_impl == "dense"
    assert plan.skipped_panels_global == 0
    assert (plan.skipped_panels_per_device() == 0).all()
    assert plan.cost.fill_in == 1.0


def test_plan_cost_model_tracks_liveness():
    """Modeled broadcast bytes shrink with dead panels (that is what the
    executors actually skip); the bulk-gather and ring schedules are
    sparsity-blind, so masks never reduce their modeled cost."""
    dense = plan_matmul(64, 128, 64, _grid_cfg(2, 2))
    mask = np.ones((8, 8), dtype=bool)
    mask[:, ::2] = False  # kill half the K panels on the A side
    sparse = plan_matmul(
        64, 128, 64, _grid_cfg(2, 2), a_mask=mask, b_mask=np.ones((8, 8), bool)
    )
    assert sparse.skipped_panels_global == 4
    for strat in ("procedural", "taskbased"):
        assert sparse.cost.comm_bytes[strat] < dense.cost.comm_bytes[strat]
    for strat in ("allgather", "ring"):
        assert sparse.cost.comm_bytes[strat] == dense.cost.comm_bytes[strat]
    assert sparse.cost.flops_sparse < sparse.cost.flops_dense
    assert dense.cost.best_strategy(("taskbased", "allgather")) == "allgather"
    # heavy pruning undercuts even the bandwidth-optimal bulk gather
    # (broadcast-as-allreduce pays 2x per byte, so it needs < 1/4 live)
    mask1 = np.zeros((8, 8), dtype=bool)
    mask1[:, 0] = True
    very_sparse = plan_matmul(
        64, 128, 64, _grid_cfg(2, 2), a_mask=mask1, b_mask=np.ones((8, 8), bool)
    )
    assert (
        very_sparse.cost.best_strategy(("taskbased", "allgather"))
        == "taskbased"
    )


def test_plan_padding_is_block_and_grid_aligned():
    mask_a = np.ones((3, 5), dtype=bool)
    mask_b = np.ones((5, 3), dtype=bool)
    plan = plan_matmul(
        30, 50, 27, _grid_cfg(2, 2), a_mask=mask_a, b_mask=mask_b
    )
    mp, kp, np_ = plan.m_pad, plan.k_pad, plan.n_pad
    assert mp % 2 == 0 and np_ % 2 == 0 and mp % 10 == 0 and np_ % 9 == 0
    assert kp % (10 * 2) == 0  # block size 10 x lcm(grid)
    assert plan.a_mask.shape == (mp // 10, kp // 10)
    assert plan.b_mask.shape == (kp // 10, np_ // 9)


def test_plan_cache_hits_per_shape_and_mask():
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=4)
    p1 = mm.plan(32, 64, 48)
    assert mm.plan(32, 64, 48) is p1
    mask = banded_block_mask(4, 4, 1)
    p2 = mm.plan(32, 64, 48, b_mask=mask)
    assert p2 is not p1
    assert mm.plan(32, 64, 48, b_mask=mask.copy()) is p2  # content-keyed
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    mm(a, b)
    assert len(mm._plan_cache) == 2  # the call reused the cached plan


# ---------------------------------------------------------------------------
# planned execution paths (single-device mesh)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("local_matmul", ["xla", "pallas"])
def test_one_sided_mask_matches_oracle(local_matmul):
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(
        mesh, strategy="taskbased", local_matmul=local_matmul
    )
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    bm = banded_block_mask(8, 8, 2)
    got = np.asarray(mm(a, b, b_mask=bm))
    want = np.asarray(
        reference_blocksparse_matmul(a, b, np.ones((1, 8), bool), bm)
    )
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_bsmm_local_impl_selected_and_correct():
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased", local_matmul="pallas")
    am = banded_block_mask(8, 8, 1)
    bm = banded_block_mask(8, 8, 1)
    plan = mm.plan(64, 64, 64, a_mask=am, b_mask=bm)
    assert plan.local_impl == "bsmm"
    assert plan.local_cols is not None
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    got = np.asarray(mm(a, b, a_mask=am, b_mask=bm))
    want = np.asarray(reference_blocksparse_matmul(a, b, am, bm))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_nonuniform_roundtrip_rectangular_nondivisible():
    """Expand -> compact is the identity on rectangular tilings whose
    extents do not divide the physical tile."""
    mesh = make_host_mesh(1, 1)
    mm = DistributedMatmul(mesh, strategy="taskbased")
    rt = nonuniform_tiling(101, 7, seed=3)
    it = nonuniform_tiling(118, 5, seed=4)
    ct = nonuniform_tiling(93, 6, seed=5)
    nm = NonuniformMatmul(mm, rt, it, ct, tile=16)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(101, 118)), jnp.float32)
    a_p = nm._expand(nm._expand(a, nm.row_b, 0), nm.inner_b, 1)
    assert a_p.shape == (nm.row_b.padded_extent, nm.inner_b.padded_extent)
    # compact inverts expand (C-shaped: rows x cols), so round-trip a
    # C-shaped array through the row/col bucketizations
    c = jnp.asarray(rng.normal(size=(101, 93)), jnp.float32)
    c_p = nm._expand(nm._expand(c, nm.row_b, 0), nm.col_b, 1)
    np.testing.assert_array_equal(np.asarray(nm._compact(c_p)), np.asarray(c))
    # and the full product agrees with the oracle
    b = jnp.asarray(rng.normal(size=(118, 93)), jnp.float32)
    got = np.asarray(nm(a, b))
    want = np.asarray(reference_matmul(a, b))
    np.testing.assert_allclose(got, want, atol=1e-3)
    assert nm.plan().local_impl == "dense"


# ---------------------------------------------------------------------------
# 2.5D validation (satellite: the inverted error message)
# ---------------------------------------------------------------------------


def test_25d_rejects_unknown_rep_axis():
    cfg = _grid_cfg(2, 2, k_blocks=4)
    a = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(ValueError, match="rep_axis 'pod' is not a mesh axis"):
        summa_25d_matmul(a, a, cfg, rep_axis="pod")


def test_25d_error_message_direction():
    """k_blocks=4 on 3 replicas: the *replica count* must divide
    k_blocks, and the message must say so (it used to claim the
    reverse)."""
    cfg = SummaConfig(
        mesh=FakeMesh({"pod": 3, "data": 2, "model": 2}),
        row_axis="data",
        col_axis="model",
        k_blocks=4,
    )
    a = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(
        ValueError, match=r"replica count 3 .* must divide k_blocks=4"
    ):
        summa_25d_matmul(a, a, cfg, rep_axis="pod")


# ---------------------------------------------------------------------------
# multi-device: BSMM distributed path + 2.5D oracle on (2,2,2)
# ---------------------------------------------------------------------------

BSMM_DIST_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import (DistributedMatmul, banded_block_mask,
                        random_block_mask, reference_blocksparse_matmul)
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
b = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
for am, bm in [
    (random_block_mask(8, 8, 0.4, seed=1), random_block_mask(8, 8, 0.5, seed=2)),
    (banded_block_mask(8, 8, 1), banded_block_mask(8, 8, 1)),
]:
    mm = DistributedMatmul(mesh, strategy="taskbased", local_matmul="pallas")
    plan = mm.plan(64, 128, 64, a_mask=am, b_mask=bm)
    assert plan.local_impl == "bsmm", plan.local_impl
    got = np.asarray(jax.jit(
        lambda a, b, am=am, bm=bm: mm(a, b, a_mask=am, b_mask=bm))(a, b))
    want = np.asarray(reference_blocksparse_matmul(a, b, am, bm))
    err = np.abs(got - want).max()
    assert err < 1e-4, err
    # per-device pruning is at least as strong as global, and strictly
    # stronger somewhere on the banded structure
    per_dev = plan.skipped_panels_per_device()
    assert (per_dev >= plan.skipped_panels_global).all()
assert (per_dev > plan.skipped_panels_global).any()
print("BSMM_DIST_OK")
"""


def test_bsmm_distributed_matches_reference(subproc):
    """Acceptance: with masks and local_matmul='pallas' the distributed
    path runs the scalar-prefetch BSMM kernel on per-device CSR maps and
    matches the block-sparse oracle."""
    out = subproc(BSMM_DIST_CODE, devices=4)
    assert "BSMM_DIST_OK" in out


SUMMA_25D_222_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import reference_matmul
from repro.core.summa import SummaConfig, summa_25d_matmul
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)
a = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
b = jnp.asarray(rng.normal(size=(128, 96)), jnp.float32)
ref = np.asarray(reference_matmul(a, b))
for kb in (2, 4, 8):
    cfg = SummaConfig(mesh=mesh, row_axis="data", col_axis="model",
                      strategy="taskbased", k_blocks=kb)
    out = np.asarray(summa_25d_matmul(a, b, cfg))
    err = np.abs(out - ref).max()
    assert err < 1e-4, (kb, err)
print("SUMMA_25D_222_OK")
"""


def test_summa_25d_oracle_on_222_mesh(subproc):
    """2.5D correctness vs the dense oracle on a (2,2,2) mesh across
    replica-divisible k_blocks."""
    out = subproc(SUMMA_25D_222_CODE, devices=8)
    assert "SUMMA_25D_222_OK" in out


# ---------------------------------------------------------------------------
# hypothesis property tests: plan invariants (satellite of the rank PR)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(
        f_lo=st.floats(0.05, 0.5),
        f_hi=st.floats(0.5, 1.0),
        seed=st.integers(0, 500),
    )
    @settings(deadline=None, max_examples=40)
    def test_plan_cost_monotone_in_fill(f_lo, f_hi, seed):
        """Growing a mask (superset of blocks) never shrinks planned FLOPs
        or broadcast bytes: threshold one random field at two fills so the
        low-fill mask is nested in the high-fill one."""
        rng = np.random.default_rng(seed)
        field = rng.random((8, 8))
        cfg = _grid_cfg(2, 2)
        plans = [
            plan_matmul(
                64, 64, 64, cfg,
                a_mask=field < f, b_mask=np.ones((8, 8), bool),
            )
            for f in (f_lo, f_hi)
        ]
        lo, hi = plans
        assert lo.cost.flops_sparse <= hi.cost.flops_sparse
        for strat in ("procedural", "taskbased"):
            assert lo.cost.comm_bytes[strat] <= hi.cost.comm_bytes[strat]
        assert lo.cost.fill_in <= hi.cost.fill_in + 1e-12

    @given(
        seed=st.integers(0, 500),
        bump=st.integers(1, 8),
    )
    @settings(deadline=None, max_examples=40)
    def test_plan_cost_monotone_in_rank(seed, bump):
        """Raising any block's rank (same mask) never shrinks planned
        FLOPs or factor-broadcast bytes."""
        rng = np.random.default_rng(seed)
        ranks = rng.integers(0, 9, size=(8, 8)).astype(np.int32)
        if not ranks.any():
            ranks[0, 0] = 1
        hi = np.minimum(ranks + bump * (ranks > 0), 16).astype(np.int32)
        cfg = _grid_cfg(2, 2)
        p_lo = plan_matmul(
            128, 128, 128, cfg, a_ranks=BlockRankMap(ranks, 16, 16)
        )
        p_hi = plan_matmul(
            128, 128, 128, cfg, a_ranks=BlockRankMap(hi, 16, 16)
        )
        assert p_lo.cost.flops_sparse <= p_hi.cost.flops_sparse
        assert p_lo.cost.flops_sparse <= p_lo.cost.flops_mask
        for strat in ("procedural", "taskbased"):
            assert p_lo.cost.comm_bytes[strat] <= p_hi.cost.comm_bytes[strat]

    @given(
        p_row=st.integers(1, 16),
        p_col=st.integers(1, 16),
        k_steps=st.integers(0, 64),
        lookahead=st.one_of(st.none(), st.integers(-4, 128)),
    )
    @settings(deadline=None, max_examples=100)
    def test_resolve_lookahead_always_in_range(p_row, p_col, k_steps, lookahead):
        from repro.core.summa import resolve_multi_issue

        got = resolve_multi_issue(p_row, p_col, k_steps, lookahead)
        assert 1 <= got <= max(k_steps, 1)

    @given(
        fill=st.floats(0.1, 1.0),
        seed=st.integers(0, 500),
        p=st.sampled_from([(1, 1), (2, 2), (2, 4), (4, 4)]),
    )
    @settings(deadline=None, max_examples=40)
    def test_per_device_pruning_accounting(fill, seed, p):
        """Per-device skipped panels dominate the global count on every
        device, and the per-device live totals agree with the plan's
        device-liveness table exactly."""
        from repro.core import random_block_mask

        p_row, p_col = p
        am = random_block_mask(8, 8, fill, seed=seed)
        bm = random_block_mask(8, 8, fill, seed=seed + 1)
        plan = plan_matmul(
            64, 64, 64, _grid_cfg(p_row, p_col), a_mask=am, b_mask=bm
        )
        skipped = plan.skipped_panels_per_device()
        assert skipped.shape == (p_row, p_col)
        assert (skipped >= plan.skipped_panels_global).all()
        live_total = plan.device_live.sum()
        assert skipped.sum() == p_row * p_col * plan.k_steps - live_total
        # every globally-live panel is live on at least one device
        assert (
            plan.device_live.any(axis=(0, 1)).sum() == len(plan.live_panels)
        )

    @given(seed=st.integers(0, 500))
    @settings(deadline=None, max_examples=40)
    def test_mask_and_rank_keys_stable_under_copies_and_views(seed):
        rng = np.random.default_rng(seed)
        mask = rng.random((6, 9)) < 0.5
        assert mask_key(mask) == mask_key(mask.copy())
        assert mask_key(mask) == mask_key(mask[:])  # view
        assert mask_key(np.asfortranarray(mask)) == mask_key(mask)
        flipped = mask.copy()
        flipped[0, 0] ^= True
        assert mask_key(flipped) != mask_key(mask)
        ranks = (rng.integers(0, 5, size=(6, 9))).astype(np.int32)
        rm = BlockRankMap(ranks, 8, 8)
        rm2 = BlockRankMap(ranks.copy(), 8, 8)
        assert rank_key(rm) == rank_key(rm2)
        assert rank_key(rm) != rank_key(BlockRankMap(ranks, 8, 16))
