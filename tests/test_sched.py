"""repro.sched: task-graph structure, discrete-event simulation invariants,
the paper's multi-issue imbalance-absorption result, and the autotuner's
never-worse-than-static guarantee."""
import json

import numpy as np
import pytest

from repro.core.blocking import nonuniform_tiling, uniform_tiling
from repro.core.plan import plan_matmul
from repro.sched import (
    DEFAULT_MACHINE,
    MachineModel,
    abstract_summa_config,
    eq1_lookahead,
    from_plan,
    from_tilings,
    lookahead_candidates,
    ring_makespan,
    simulate,
    simulate_plan,
    tune_plan,
)


def _nonuniform_tilings(extent=2048, blocks=64, seed=1):
    return [nonuniform_tiling(extent, blocks, seed=seed + s) for s in range(3)]


# ---------------------------------------------------------------------------
# task graph structure
# ---------------------------------------------------------------------------


def test_taskgraph_structure_and_window_edges():
    graph = from_tilings(2, 4, *_nonuniform_tilings(512, 8), lookahead=2)
    graph.validate()
    counts = graph.counts()
    # 8 iterations x (2 row-group A broadcasts + 4 col-group B broadcasts)
    assert counts["bcast_a"] == 8 * 2
    assert counts["bcast_b"] == 8 * 4
    # every device computes every iteration (dense nonuniform product)
    assert counts["gemm"] == counts["accum"] == 8 * 2 * 4
    by_tid = {t.tid: t for t in graph.tasks}
    for task, deps in zip(graph.tasks, graph.deps):
        if task.kind == "gemm":
            kinds = {by_tid[d].kind for d in deps}
            assert "bcast_a" in kinds and "bcast_b" in kinds
        if task.kind.startswith("bcast") and task.step >= graph.lookahead:
            # the multiple-issue window: iteration t's broadcast waits on
            # the accumulate of iteration t - I (per paper Eq. 1)
            assert any(
                by_tid[d].kind == "accum"
                and by_tid[d].step == task.step - graph.lookahead
                for d in deps
            )
        # broadcasts before the window fills have no accum dependencies
        if task.kind.startswith("bcast") and task.step < graph.lookahead:
            assert not any(by_tid[d].kind == "accum" for d in deps)


def test_taskgraph_from_plan_costs_match_plan():
    cfg = abstract_summa_config(4, 4, strategy="taskbased")
    plan = plan_matmul(1024, 1024, 1024, cfg)
    graph = from_plan(plan)
    graph.validate()
    gemm_flops = sum(t.flops for t in graph.tasks if t.kind == "gemm")
    assert gemm_flops == pytest.approx(plan.cost.flops_dense)
    # per-panel broadcast bytes match the PlanCost broadcast model in total
    comm = sum(t.bytes * len(t.devices) for t in graph.tasks
               if t.kind.startswith("bcast")) / graph.n_devices
    assert comm == pytest.approx(plan.cost.comm_bytes["taskbased"])


def test_taskgraph_from_masked_plan_prunes_and_uses_csr():
    from repro.core.sparsity import banded_block_mask

    cfg = abstract_summa_config(4, 4, strategy="taskbased", local_matmul="pallas")
    am = banded_block_mask(16, 16, 1)
    bm = banded_block_mask(16, 16, 1)
    plan = plan_matmul(512, 512, 512, cfg, a_mask=am, b_mask=bm)
    assert plan.local_impl == "bsmm"
    graph = from_plan(plan)
    graph.validate()
    assert graph.n_steps == len(plan.live_panels)
    # per-device FLOPs follow the BlockCSR maps: a banded mask on a
    # multi-row grid gives devices different work per panel
    per_dev = np.zeros(graph.n_devices)
    for t in graph.tasks:
        if t.kind == "gemm":
            per_dev[t.devices[0]] += t.flops
    assert per_dev.max() > per_dev.min()


# ---------------------------------------------------------------------------
# simulator invariants
# ---------------------------------------------------------------------------


def test_simulator_serial_schedule_sums_everything():
    """On a 1x1 grid there is no comm: makespan == total compute time."""
    cfg = abstract_summa_config(1, 1, strategy="taskbased", k_blocks=4)
    plan = plan_matmul(256, 256, 256, cfg)
    sim = simulate_plan(plan)
    assert sim.makespan_s == pytest.approx(float(sim.busy_compute_s.sum()))
    assert sim.busy_comm_s.sum() == 0.0
    assert sim.imbalance_ratio == 1.0


def test_simulator_lookahead_monotone_and_comm_overlap():
    tilings = _nonuniform_tilings()
    machine = DEFAULT_MACHINE
    spans = {}
    for la in (1, 2, 4, 8):
        sim = simulate(from_tilings(8, 8, *tilings, lookahead=la), machine)
        spans[la] = sim.makespan_s
    # a deeper window can only help (more overlap freedom)
    assert spans[2] <= spans[1]
    assert spans[4] <= spans[2]
    assert spans[8] <= spans[4]
    # and with any window, makespan is at least the compute lower bound
    sim8 = simulate(from_tilings(8, 8, *tilings, lookahead=8), machine)
    assert sim8.makespan_s >= sim8.busy_compute_s.max()


GOLDEN_TRACE = __file__.rsplit("/", 1)[0] + "/golden/sched_trace_small.json"


def _golden_graph():
    """Small fixed schedule for the golden/determinism gate: nonuniform
    2x2 grid, 4 K blocks, fixed seeds — regenerate the committed JSON
    with ``python tests/golden/regen_sched_trace.py`` after an
    *intentional* schedule change."""
    tilings = [nonuniform_tiling(64, 4, seed=7 + s) for s in range(3)]
    return from_tilings(2, 2, *tilings, lookahead=2)


def test_simulator_bitwise_deterministic():
    """Same graph + machine => bitwise-identical makespan, fingerprint,
    and Chrome trace (the simulator is pure list scheduling; any
    nondeterminism is a bug)."""
    r1 = simulate(_golden_graph(), trace=True)
    r2 = simulate(_golden_graph(), trace=True)
    assert r1.makespan_s == r2.makespan_s  # bitwise, not approx
    assert np.array_equal(r1.busy_compute_s, r2.busy_compute_s)
    assert r1.fingerprint() == r2.fingerprint()
    assert r1.chrome_trace() == r2.chrome_trace()


def test_simulator_matches_golden_trace():
    """sched refactors must diff loudly: the simulated schedule of the
    fixed small graph must reproduce the committed golden Chrome trace
    and fingerprint exactly."""
    with open(GOLDEN_TRACE) as f:
        golden = json.load(f)
    sim = simulate(_golden_graph(), trace=True)
    assert sim.fingerprint() == golden["fingerprint"]
    assert sim.makespan_s == golden["makespan_s"]
    assert sim.chrome_trace() == golden["trace"]


def test_rank_plan_taskgraph_costs_follow_ranks():
    """Rank-sparse plans put per-block-rank gemm costs and factor-sized
    broadcast bytes on the task graph; rank nonuniformity shows up as
    per-device load the multi-issue window then absorbs."""
    from repro.core.sparsity import BlockRankMap

    cfg = abstract_summa_config(4, 4, strategy="taskbased")
    rng = np.random.default_rng(0)
    # heavily nonuniform ranks (all below the dense-fallback threshold
    # r* = 32 for 64x64 blocks): a few heavy blocks, many tiny ones
    ranks = np.where(
        rng.random((16, 16)) < 0.2,
        rng.integers(16, 25, size=(16, 16)),
        rng.integers(1, 5, size=(16, 16)),
    ).astype(np.int32)
    rank_plan = plan_matmul(
        1024, 1024, 1024, cfg, a_ranks=BlockRankMap(ranks, 64, 64)
    )
    assert rank_plan.local_impl == "ranksparse"
    mask_plan = plan_matmul(1024, 1024, 1024, cfg, a_mask=ranks > 0)
    g_rank = from_plan(rank_plan)
    g_mask = from_plan(mask_plan)
    # graph costs follow ranks: strictly less work and fewer bytes moved
    assert g_rank.total_flops() < g_mask.total_flops()
    assert g_rank.total_bytes() < g_mask.total_bytes()
    gemm_rank = sum(t.flops for t in g_rank.tasks if t.kind == "gemm")
    assert gemm_rank == pytest.approx(rank_plan.cost.flops_sparse, rel=1e-9)
    # the imbalance-absorption claim extends to rank-nonuniform inputs
    s1 = simulate(from_plan(rank_plan, lookahead=1))
    se = simulate(from_plan(rank_plan))
    assert se.makespan_s <= s1.makespan_s
    assert s1.makespan_s / se.makespan_s >= 1.1, (
        s1.makespan_s, se.makespan_s
    )


def test_multi_issue_absorbs_nonuniform_imbalance():
    """The acceptance bar: on the EXPERIMENTS.md §Simulated-scaling
    workload (16x16 grid, N=4096, 64 nonuniform blocks/dim, seeds 1/2/3),
    lookahead I = Eq. (1) achieves >= 1.3x lower simulated makespan than
    serial issue I = 1 — and the same holds on a smaller 8x8 grid."""
    accept = _nonuniform_tilings(4096, 64)
    a1 = simulate(from_tilings(16, 16, *accept, lookahead=1))
    aeq = simulate(from_tilings(16, 16, *accept))
    assert aeq.graph_meta["lookahead"] == eq1_lookahead(16, 16, 64)
    assert a1.makespan_s / aeq.makespan_s >= 1.3

    tilings = _nonuniform_tilings(2048, 64)
    s1 = simulate(from_tilings(8, 8, *tilings, lookahead=1))
    seq = simulate(from_tilings(8, 8, *tilings))  # Eq. (1) window
    assert seq.graph_meta["lookahead"] == eq1_lookahead(8, 8, 64)
    assert s1.makespan_s / seq.makespan_s >= 1.3
    # multi-issue recovers most of the ground lost to nonuniform blocks:
    # closer to the uniform schedule than serial issue is, by 2x or more
    uni = [uniform_tiling(2048, 32) for _ in range(3)]
    u = simulate(from_tilings(8, 8, *uni))
    gap_serial = s1.makespan_s / u.makespan_s
    gap_multi = seq.makespan_s / u.makespan_s
    assert gap_multi < gap_serial / 2 + 0.5


def test_chrome_trace_export(tmp_path):
    tilings = _nonuniform_tilings(512, 8)
    sim = simulate(from_tilings(2, 2, *tilings), trace=True)
    path = tmp_path / "trace.json"
    sim.write_chrome_trace(str(path))
    trace = json.loads(path.read_text())
    events = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert events, "no duration events in trace"
    end = max(e["ts"] + e["dur"] for e in events)
    assert end <= sim.makespan_s * 1e6 + 1.0
    assert {e["pid"] for e in events} == {0, 1, 2, 3}
    # untraced simulation refuses to export
    with pytest.raises(ValueError):
        simulate(from_tilings(2, 2, *tilings)).chrome_trace()


# ---------------------------------------------------------------------------
# tuner
# ---------------------------------------------------------------------------


def test_tuner_never_worse_than_static_pick():
    for pr, pc, n in ((2, 2, 512), (4, 4, 1024), (8, 4, 2048)):
        cfg = abstract_summa_config(pr, pc, strategy="taskbased")
        tuned = tune_plan(plan_matmul(n, n, n, cfg))
        t = tuned.tuned
        assert t["makespan_s"] <= t["static_makespan_s"] * (1 + 1e-9), (
            pr, pc, n, t,
        )
        assert t["strategy"] in ("procedural", "taskbased", "allgather")
        assert tuned.lookahead == t["lookahead"]
        assert tuned.resolve_lookahead() == min(t["lookahead"], tuned.k_steps)


def test_tuner_prefers_overlap_when_comm_dominates():
    """With an artificially slow wire, the bulk allgather (one latency,
    same bytes as the 2x-cost broadcasts halved) should win; with an
    artificially slow MXU every strategy ties on compute and the tuner
    must still return a valid schedule."""
    cfg = abstract_summa_config(4, 4, strategy="procedural")
    plan = plan_matmul(1024, 1024, 1024, cfg)
    slow_wire = MachineModel(flops_per_s=1e15, bytes_per_s=1e8, name="wire")
    t = tune_plan(plan, machine=slow_wire).tuned
    assert t["strategy"] == "allgather"
    slow_mxu = MachineModel(flops_per_s=1e9, bytes_per_s=1e12, name="mxu")
    t2 = tune_plan(plan, machine=slow_mxu).tuned
    assert t2["makespan_s"] <= t2["static_makespan_s"] * (1 + 1e-9)


def test_tuned_masked_plan_keeps_schedule_and_tunes_window():
    from repro.core.sparsity import random_block_mask

    cfg = abstract_summa_config(2, 2, strategy="taskbased")
    am = random_block_mask(8, 8, 0.4, seed=1)
    bm = random_block_mask(8, 8, 0.4, seed=2)
    plan = plan_matmul(256, 256, 256, cfg, a_mask=am, b_mask=bm)
    tuned = tune_plan(plan)
    # masked plans keep their liveness/pruning; only the window is tuned
    assert tuned.live_panels == plan.live_panels
    assert tuned.local_impl == plan.local_impl
    assert tuned.tuned["lookahead"] in lookahead_candidates(
        2, 2, len(plan.live_panels)
    )


def test_ring_makespan_scales_with_grid():
    cfg1 = abstract_summa_config(1, 4, strategy="taskbased")
    cfg2 = abstract_summa_config(1, 8, strategy="taskbased")
    p1 = plan_matmul(512, 512, 512, cfg1)
    p2 = plan_matmul(512, 512, 512, cfg2)
    assert ring_makespan(p1) > 0
    # same product split over more devices: less compute per device
    assert (
        simulate_plan(p2).busy_compute_s.max()
        < simulate_plan(p1).busy_compute_s.max()
    )


def test_tuned_plan_executes_correctly():
    """End-to-end: a tuner-modified plan (strategy/k_blocks/lookahead all
    potentially different) still computes the right product."""
    import jax.numpy as jnp

    from repro.core import DistributedMatmul, reference_matmul
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(48, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    mm = DistributedMatmul(mesh, strategy="taskbased", k_blocks=4)
    got = np.asarray(mm(a, b, tune=True))
    want = np.asarray(reference_matmul(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
    plan = mm.plan(48, 64, 32, tune=True)
    assert plan.tuned is not None
    assert plan.tuned["makespan_s"] <= plan.tuned["static_makespan_s"] * (
        1 + 1e-9
    )


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_sched_cli_smoke(tmp_path, capsys):
    from repro.sched.__main__ import main

    trace = tmp_path / "trace.json"
    out = tmp_path / "sim.json"
    main([
        "--grid", "2", "2", "--extent", "256", "--blocks", "4",
        "--nonuniform", "--compare",
        "--trace", str(trace), "--json", str(out),
    ])
    captured = capsys.readouterr().out
    assert "multi_issue_speedup" in captured
    payload = json.loads(out.read_text())
    assert payload["sim"]["makespan_s"] > 0
    assert json.loads(trace.read_text())["traceEvents"]
